"""L2 building blocks: differentiable layers over the L1 Pallas kernels.

``pallas_call`` is not differentiable by default, so every Pallas-backed
op used under ``jax.grad`` is wrapped in a ``custom_vjp`` whose backward
pass is *also* built from Pallas kernels (the matmul transposes reuse the
same tiled kernel; the loss backwards are hand-written kernels in
``kernels.losses``). This mirrors the paper's production setting where
both the "ten forward" and the "one backward" run the same optimized
kernels.

Each public layer takes a ``flavour`` argument:
  * ``"pallas"`` — L1 kernels (interpret-mode on CPU, MXU-shaped on TPU);
  * ``"jnp"``    — the pure-jnp oracle path (XLA-native fusion), the
    ablation/perf baseline (DESIGN.md `abl-kernel`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import losses as klosses
from .kernels import matmul as kmatmul
from .kernels import ref as kref
from .kernels import update as kupdate

FLAVOURS = ("pallas", "jnp")


def _check_flavour(flavour: str) -> None:
    if flavour not in FLAVOURS:
        raise ValueError(f"unknown flavour {flavour!r}; expected one of {FLAVOURS}")


# ---------------------------------------------------------------------------
# Dense layer: act(x @ w + b), pallas fwd + pallas bwd via custom_vjp
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dense_pallas(x, w, b, act):
    return kmatmul.matmul_bias_act(x, w, b, act)


def _dense_pallas_fwd(x, w, b, act):
    out = kmatmul.matmul_bias_act(x, w, b, act)
    # Residuals: inputs plus the post-activation output (the relu mask is
    # recovered from out > 0, avoiding a pre-activation save).
    return out, (x, w, out)


def _dense_pallas_bwd(act, res, dy):
    x, w, out = res
    if act == "relu":
        dy = dy * (out > 0.0).astype(dy.dtype)
    dx = kmatmul.matmul(dy, w.T)
    dw = kmatmul.matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


_dense_pallas.defvjp(_dense_pallas_fwd, _dense_pallas_bwd)


def dense(x, w, b, act: str = "none", *, flavour: str = "pallas"):
    """Differentiable fused dense layer ``act(x @ w + b)``."""
    _check_flavour(flavour)
    if flavour == "pallas":
        return _dense_pallas(x, w, b, act)
    return kref.matmul_bias_act(x, w, b, act)


# ---------------------------------------------------------------------------
# Per-example softmax cross-entropy
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _xent_pallas(logits, labels):
    return klosses.softmax_xent(logits, labels)


def _xent_pallas_fwd(logits, labels):
    return klosses.softmax_xent(logits, labels), (logits, labels)


def _xent_pallas_bwd(res, dloss):
    logits, labels = res
    dlogits = klosses.softmax_xent_grad(logits, labels, dloss)
    return dlogits, None


_xent_pallas.defvjp(_xent_pallas_fwd, _xent_pallas_bwd)


def softmax_xent(logits, labels, *, flavour: str = "pallas"):
    """Differentiable per-example cross-entropy ``[n, c]`` × ``[n]`` → ``[n]``."""
    _check_flavour(flavour)
    if flavour == "pallas":
        return _xent_pallas(logits, labels)
    return kref.softmax_xent(logits, labels)


# ---------------------------------------------------------------------------
# Per-example squared error
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _mse_pallas(pred, target):
    return klosses.mse(pred, target)


def _mse_pallas_fwd(pred, target):
    return klosses.mse(pred, target), (pred, target)


def _mse_pallas_bwd(res, dloss):
    pred, target = res
    return klosses.mse_grad(pred, target, dloss), None


_mse_pallas.defvjp(_mse_pallas_fwd, _mse_pallas_bwd)


def mse(pred, target, *, flavour: str = "pallas"):
    """Differentiable per-example squared error ``[n]`` × ``[n]`` → ``[n]``."""
    _check_flavour(flavour)
    if flavour == "pallas":
        return _mse_pallas(pred, target)
    return kref.mse(pred, target)


# ---------------------------------------------------------------------------
# SGD update (no grad needed — applied outside the autodiff region)
# ---------------------------------------------------------------------------


def sgd_update(w, g, lr, *, flavour: str = "pallas"):
    """``w - lr * g`` for one parameter tensor."""
    _check_flavour(flavour)
    if flavour == "pallas":
        return kupdate.sgd_update(w, g, lr)
    return kref.sgd_update(w, g, lr)


def sgd_update_tree(params, grads, lr, *, flavour: str = "pallas"):
    """Apply :func:`sgd_update` across a parameter pytree."""
    return jax.tree_util.tree_map(
        lambda w, g: sgd_update(w, g, lr, flavour=flavour), params, grads
    )


def masked_mean(values, mask):
    """Mean over the selected subset: ``Σ mask·v / max(Σ mask, 1)``."""
    return kref.masked_mean(values, mask)
