"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``). They are also the
building blocks of the ``jnp`` artifact flavour emitted by ``aot.py`` —
the ablation axis DESIGN.md §4 calls ``abl-kernel``.

Everything here is shape-polymorphic, differentiable jnp code with no
Pallas dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Dense / matmul
# ---------------------------------------------------------------------------


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain ``x @ w`` in f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def matmul_bias_act(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """Fused ``act(x @ w + b)``; ``act`` is ``"none"`` or ``"relu"``."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return out


# ---------------------------------------------------------------------------
# Per-example losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example softmax cross-entropy.

    Args:
      logits: ``[n, c]`` f32.
      labels: ``[n]`` i32 class indices in ``[0, c)``.

    Returns:
      ``[n]`` f32 losses ``logsumexp(logits_i) - logits_i[labels_i]``.
    """
    m = jnp.max(logits, axis=1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=1))
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    return lse - picked


def softmax_xent_grad(
    logits: jax.Array, labels: jax.Array, dloss: jax.Array
) -> jax.Array:
    """VJP of :func:`softmax_xent` w.r.t. ``logits``.

    ``dlogits = (softmax(logits) - onehot(labels)) * dloss[:, None]``.
    """
    p = jax.nn.softmax(logits, axis=1)
    onehot = jax.nn.one_hot(labels, logits.shape[1], dtype=logits.dtype)
    return (p - onehot) * dloss[:, None]


def mse(pred: jax.Array, target: jax.Array) -> jax.Array:
    """Per-example squared error; ``pred``/``target`` are ``[n]`` f32."""
    d = pred - target
    return d * d


def mse_grad(pred: jax.Array, target: jax.Array, dloss: jax.Array) -> jax.Array:
    """VJP of :func:`mse` w.r.t. ``pred``: ``2 (pred - target) * dloss``."""
    return 2.0 * (pred - target) * dloss


# ---------------------------------------------------------------------------
# Optimizer update
# ---------------------------------------------------------------------------


def sgd_update(w: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    """``w - lr * g`` (lr is a scalar or ``[1]`` array)."""
    return w - jnp.reshape(lr, ()) * g


# ---------------------------------------------------------------------------
# Masked reductions (used by the masked train step)
# ---------------------------------------------------------------------------


def masked_mean(values: jax.Array, mask: jax.Array) -> jax.Array:
    """``sum(values * mask) / max(sum(mask), 1)`` — the "one backward"
    objective: the mean loss over the *selected* subset only."""
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(values * mask) / denom
