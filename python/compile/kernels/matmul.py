"""L1 Pallas kernels: tiled matmul and fused matmul+bias+activation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is (M/bm, N/bn,
K/bk); each grid step moves one (bm, bk) block of ``x`` and one (bk, bn)
block of ``w`` from HBM into VMEM (expressed by the BlockSpec index maps)
and feeds the MXU with an f32 ``dot``. The output block is accumulated in
VMEM across the K axis of the grid and the epilogue (bias + activation)
runs once, on the last K step, while the block is still resident.

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls — so wallclock here is *not* a TPU proxy; the
optimization target is BlockSpec structure (VMEM footprint, MXU-aligned
tiles), estimated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile edge. Actual block edges are the largest
# divisor of each dim that is <= this (shapes in this repo are chosen so
# divisors are reasonable: 128, 256, 784 -> 112, 100, 10, ...).
_TILE = 128


def _block(dim: int, target: int = _TILE) -> int:
    """Largest divisor of ``dim`` that is ``<= target``.

    Degenerate dims (primes just above ``target``) would tile into 1-wide
    blocks; fall back to a single whole-axis block instead, which keeps
    the grid small and the VMEM footprint bounded (dim ≤ 8·target).
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    best = 1
    for cand in range(min(dim, target), 0, -1):
        if dim % cand == 0:
            best = cand
            break
    if best < 8 and dim > best and dim <= 8 * target:
        return dim
    return best


def vmem_bytes(m: int, n: int, k: int, itemsize: int = 4) -> int:
    """Per-grid-step VMEM footprint of the matmul kernel for given dims.

    Used by the perf pass (and ``aot.py --report``) to check blocks fit
    the ~16 MiB/core VMEM budget with headroom for double buffering.
    """
    bm, bn, bk = _block(m), _block(n), _block(k)
    return (bm * bk + bk * bn + bm * bn) * itemsize


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Accumulating matmul body; zero the block on the first K step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """Accumulating matmul with a fused bias+activation epilogue."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled Pallas ``x @ w`` for 2-D f32 operands.

    Shapes: ``x [m, k]``, ``w [k, n]`` → ``[m, n]``.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")
    bm, bn, bk = _block(m), _block(n), _block(k)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_bias_act(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """Fused tiled Pallas ``act(x @ w + b)``.

    Shapes: ``x [m, k]``, ``w [k, n]``, ``b [n]`` → ``[m, n]``.
    ``act`` is ``"none"`` or ``"relu"`` (static).
    """
    if act not in ("none", "relu"):
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: {x.shape} @ {w.shape} + {b.shape}")
    bm, bn, bk = _block(m), _block(n), _block(k)
    nk = k // bk
    # Bias enters as [1, n] so its BlockSpec can tile the n axis alongside
    # the output block.
    b2 = b.reshape(1, n)
    return pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, nk=nk, act=act),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b2)
