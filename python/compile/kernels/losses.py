"""L1 Pallas kernels: per-example losses (forward AND backward).

The paper's selection signal is the *per-example* loss recorded from the
forward pass ("ten forward"); these kernels produce exactly that vector.
Backward kernels are hand-written (Pallas ``pallas_call`` is not
differentiable by default) and wired up via ``custom_vjp`` in
``compile.layers``.

TPU mapping: grid over batch blocks; each block holds ``(bn, c)`` logits
rows in VMEM, the row-reduction (logsumexp / softmax) stays inside the
block — no cross-block communication, so blocks pipeline cleanly over the
HBM→VMEM stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _block


# ---------------------------------------------------------------------------
# Softmax cross-entropy
# ---------------------------------------------------------------------------


def _xent_kernel(logits_ref, labels_ref, loss_ref):
    logits = logits_ref[...]  # [bn, c]
    labels = labels_ref[...]  # [bn]
    m = jnp.max(logits, axis=1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=1))
    c = logits.shape[1]
    onehot = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[0], c), 1) == labels[:, None]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=1)
    loss_ref[...] = lse - picked


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example softmax cross-entropy, ``[n, c]`` × ``[n]`` → ``[n]``."""
    n, c = logits.shape
    bn = _block(n)
    return pl.pallas_call(
        _xent_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(logits, labels)


def _xent_grad_kernel(logits_ref, labels_ref, dloss_ref, dlogits_ref):
    logits = logits_ref[...]
    labels = labels_ref[...]
    dloss = dloss_ref[...]
    m = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=1, keepdims=True)
    c = logits.shape[1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (logits.shape[0], c), 1) == labels[:, None]
    ).astype(jnp.float32)
    dlogits_ref[...] = (p - onehot) * dloss[:, None]


def softmax_xent_grad(
    logits: jax.Array, labels: jax.Array, dloss: jax.Array
) -> jax.Array:
    """Backward of :func:`softmax_xent` w.r.t. logits."""
    n, c = logits.shape
    bn = _block(n)
    return pl.pallas_call(
        _xent_grad_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(logits, labels, dloss)


# ---------------------------------------------------------------------------
# Per-example squared error
# ---------------------------------------------------------------------------


def _mse_kernel(pred_ref, target_ref, loss_ref):
    d = pred_ref[...] - target_ref[...]
    loss_ref[...] = d * d


def mse(pred: jax.Array, target: jax.Array) -> jax.Array:
    """Per-example squared error, ``[n]`` × ``[n]`` → ``[n]``."""
    (n,) = pred.shape
    bn = _block(n)
    return pl.pallas_call(
        _mse_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(pred, target)


def _mse_grad_kernel(pred_ref, target_ref, dloss_ref, dpred_ref):
    dpred_ref[...] = 2.0 * (pred_ref[...] - target_ref[...]) * dloss_ref[...]


def mse_grad(pred: jax.Array, target: jax.Array, dloss: jax.Array) -> jax.Array:
    """Backward of :func:`mse` w.r.t. ``pred``."""
    (n,) = pred.shape
    bn = _block(n)
    return pl.pallas_call(
        _mse_grad_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(pred, target, dloss)
