"""L1 Pallas kernel: SGD parameter update ``w - lr * g``.

Works for parameters of any rank: the L2 wrapper flattens, pads to a
block multiple, runs the 1-D tiled kernel, and slices back. The learning
rate rides along as a ``[1]`` array whose BlockSpec pins every grid step
to the same block (broadcast).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D tile for the elementwise update; large enough that grid overhead is
# negligible, small enough that padding waste is bounded.
_UPDATE_BLOCK = 512


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def _sgd_flat(w: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    (n,) = w.shape
    assert n % _UPDATE_BLOCK == 0
    return pl.pallas_call(
        _sgd_kernel,
        grid=(n // _UPDATE_BLOCK,),
        in_specs=[
            pl.BlockSpec((_UPDATE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_UPDATE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_UPDATE_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w, g, lr)


def sgd_update(w: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    """``w - lr * g`` for an arbitrary-shape f32 parameter tensor.

    Args:
      w: parameter tensor, any shape.
      g: gradient, same shape as ``w``.
      lr: scalar or ``[1]`` f32 learning rate.
    """
    if w.shape != g.shape:
        raise ValueError(f"shape mismatch: w {w.shape} vs g {g.shape}")
    lr1 = jnp.reshape(lr, (1,)).astype(jnp.float32)
    flat = w.reshape(-1)
    gflat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _UPDATE_BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
        gflat = jnp.pad(gflat, (0, pad))
    out = _sgd_flat(flat, gflat, lr1)
    if pad:
        out = out[:n]
    return out.reshape(w.shape)
