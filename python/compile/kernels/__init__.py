"""L1: Pallas kernels for the OBFTF compute hot-spots.

Modules:
  matmul — tiled matmul / fused matmul+bias+activation (MXU-shaped blocks)
  losses — per-example softmax-xent and MSE, forward + hand-written backward
  update — elementwise SGD parameter update
  ref    — pure-jnp oracles for all of the above (test ground truth and
           the `jnp` artifact flavour)
"""

from . import losses, matmul, ref, update  # noqa: F401
