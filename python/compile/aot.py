"""AOT lowering driver: jax/pallas (L2/L1) → HLO text + manifest.json.

This is the ONLY place python touches the pipeline; it runs at build time
(``make artifacts``) and never again. The interchange format is HLO
*text*, not a serialized ``HloModuleProto``: jax ≥ 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model × executable × flavour:

    artifacts/{model}_{exe}.{flavour}.hlo.txt

plus ``artifacts/manifest.json`` describing shapes/dtypes/param layout so
the rust runtime can validate and marshal buffers without guessing.

Usage:
    python -m compile.aot --out-dir ../artifacts [--models mlp,cnn]
                          [--flavours pallas,jnp] [--report]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.matmul import vmem_bytes


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(mdl: M.ModelDef, exe: str, flavour: str, batch: int = M.BATCH) -> str:
    fn = M.build(mdl, exe, flavour)
    args = M.example_args(mdl, exe, batch=batch)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def _dtype_tag(dt) -> str:
    import numpy as np

    return {np.dtype("float32"): "f32", np.dtype("int32"): "i32"}[np.dtype(dt)]


def manifest_entry(mdl: M.ModelDef, flavours) -> dict:
    return {
        "task": mdl.task,
        "x_shape": list(mdl.x_shape),
        "num_classes": mdl.num_classes,
        "y_dtype": "i32" if mdl.task == "classification" else "f32",
        "params": [
            {"name": p.name, "shape": list(p.shape)} for p in mdl.params
        ],
        "executables": {
            **{
                f"{exe}:{fl}": f"{mdl.name}_{exe}.{fl}.hlo.txt"
                for exe in M.EXECUTABLES
                for fl in flavours
            },
            **{
                f"train_step_b{bb}:{fl}": f"{mdl.name}_train_step_b{bb}.{fl}.hlo.txt"
                for bb in M.GATHER_SIZES
                for fl in flavours
            },
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(M.MODELS))
    ap.add_argument("--flavours", default="pallas,jnp")
    ap.add_argument(
        "--report",
        action="store_true",
        help="print the L1 VMEM/MXU block report (DESIGN.md §Perf) and exit",
    )
    args = ap.parse_args()

    models = [M.MODELS[m] for m in args.models.split(",") if m]
    flavours = [f for f in args.flavours.split(",") if f]
    for fl in flavours:
        if fl not in ("pallas", "jnp"):
            raise SystemExit(f"unknown flavour {fl!r}")

    if args.report:
        print("L1 block report (per-grid-step VMEM, f32):")
        for name, dims in (
            ("mlp L1 784x256", (M.BATCH, 256, 784)),
            ("mlp L2 256x256", (M.BATCH, 256, 256)),
            ("mlp head 256x10", (M.BATCH, 10, 256)),
            ("cnn head 128x100", (M.BATCH, 100, 128)),
        ):
            m, n, k = dims
            print(f"  {name:<20} vmem={vmem_bytes(m, n, k) / 1024:.1f} KiB")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "batch": M.BATCH, "models": {}}
    t_all = time.time()
    for mdl in models:
        manifest["models"][mdl.name] = manifest_entry(mdl, flavours)
        for exe in M.EXECUTABLES:
            lowered_flavours = flavours if exe != "init" else flavours[:1]
            for fl in flavours:
                fname = f"{mdl.name}_{exe}.{fl}.hlo.txt"
                path = os.path.join(args.out_dir, fname)
                if fl not in lowered_flavours:
                    # init is flavour-independent (no kernels on its path);
                    # reuse the first flavour's lowering for the others.
                    src = os.path.join(
                        args.out_dir, f"{mdl.name}_{exe}.{lowered_flavours[0]}.hlo.txt"
                    )
                    with open(src) as f:
                        text = f.read()
                    with open(path, "w") as f:
                        f.write(text)
                    continue
                t0 = time.time()
                text = lower_one(mdl, exe, fl)
                with open(path, "w") as f:
                    f.write(text)
                print(
                    f"lowered {fname:<40} {len(text) / 1024:8.1f} KiB"
                    f"  {time.time() - t0:5.1f}s",
                    file=sys.stderr,
                )
        # sub-batch backward variants (see model.GATHER_SIZES)
        for bb in M.GATHER_SIZES:
            for fl in flavours:
                fname = f"{mdl.name}_train_step_b{bb}.{fl}.hlo.txt"
                path = os.path.join(args.out_dir, fname)
                text = lower_one(mdl, "train_step", fl, batch=bb)
                with open(path, "w") as f:
                    f.write(text)
                print(
                    f"lowered {fname:<40} {len(text) / 1024:8.1f} KiB",
                    file=sys.stderr,
                )
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(
        f"wrote {mpath} ({len(models)} models × {len(M.EXECUTABLES)} exes ×"
        f" {len(flavours)} flavours) in {time.time() - t_all:.1f}s",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
