"""L2: the paper's models and their AOT-exported executables.

Four models (DESIGN.md §4):

  * ``linreg``   — 1-feature linear regression (paper §4.1, Fig 1);
  * ``mlp``      — 784-256-256-10 MLP (paper §4.2, Fig 2 / MNIST);
  * ``cnn``      — conv stack on 16×16×3, 100 classes (Table 3,
                   ResNet50-role proxy);
  * ``cnn_lite`` — smaller conv stack (Table 3, MobileNetV2-role proxy).

Every model exports six executables (lowered by ``aot.py``), each in two
kernel flavours (``pallas`` / ``jnp``):

  init(seed)                          -> (params...,)
  fwd_loss(params..., x, y)           -> (loss[n],)            # ten forward
  train_step(params..., x, y, m, lr)  -> (params'..., sel_loss) # one backward
  grads(params..., x, y, m)           -> (grads..., sel_loss)
  apply(params..., grads..., lr)      -> (params'...,)
  eval(params..., x, y, m)            -> (sum_loss, sum_metric, count)

``m`` is the 0/1 f32 selection mask produced by the rust L3 sampler; the
backward objective is the *masked mean* loss — exactly the paper's
Algorithm 1 line 8 ("train the model using the selected data").
Convolutions stay at the L2 (lax) level; all dense layers, per-example
losses and SGD updates go through the L1 Pallas kernels (see
``compile.layers``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import layers

# Global training/eval batch size baked into the artifacts. The rust
# loader pads the final partial batch and masks it out in eval.
BATCH = 128


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model: shapes, parameter inventory, and the forward computation."""

    name: str
    task: str  # "classification" | "regression"
    x_shape: tuple  # without the batch dim
    num_classes: int  # 0 for regression
    params: tuple  # tuple[ParamSpec, ...]
    predict: Callable  # (params_tuple, x, flavour) -> logits [n,c] | pred [n]

    @property
    def y_dtype(self):
        return jnp.int32 if self.task == "classification" else jnp.float32

    @property
    def n_params(self) -> int:
        return len(self.params)

    def per_example_loss(self, params, x, y, flavour):
        out = self.predict(params, x, flavour)
        if self.task == "classification":
            return layers.softmax_xent(out, y, flavour=flavour)
        return layers.mse(out, y, flavour=flavour)

    def metric_terms(self, params, x, y, flavour):
        """Per-example (loss, metric): metric is 1.0-if-correct for
        classification, squared error for regression."""
        out = self.predict(params, x, flavour)
        if self.task == "classification":
            loss = layers.softmax_xent(out, y, flavour=flavour)
            correct = (jnp.argmax(out, axis=1).astype(jnp.int32) == y).astype(
                jnp.float32
            )
            return loss, correct
        loss = layers.mse(out, y, flavour=flavour)
        return loss, loss

    def init_params(self, key):
        out = []
        for spec in self.params:
            key, sub = jax.random.split(key)
            if len(spec.shape) == 1:  # biases
                out.append(jnp.zeros(spec.shape, jnp.float32))
            else:
                # He initialization (relu nets); fan_in = prod(shape[:-1]).
                fan_in = 1
                for d in spec.shape[:-1]:
                    fan_in *= d
                scale = jnp.sqrt(2.0 / fan_in)
                out.append(scale * jax.random.normal(sub, spec.shape, jnp.float32))
        return tuple(out)


# --- linreg -----------------------------------------------------------------

LINREG_D = 1  # paper §4.1: y = 2x + 1 + noise


def _linreg_predict(params, x, flavour):
    w, b = params
    return layers.dense(x, w, b, "none", flavour=flavour)[:, 0]


LINREG = ModelDef(
    name="linreg",
    task="regression",
    x_shape=(LINREG_D,),
    num_classes=0,
    params=(ParamSpec("w", (LINREG_D, 1)), ParamSpec("b", (1,))),
    predict=_linreg_predict,
)


# --- mlp (MNIST-role) --------------------------------------------------------

MLP_DIMS = (784, 256, 256, 10)  # paper §4.2 training settings


def _mlp_predict(params, x, flavour):
    w1, b1, w2, b2, w3, b3 = params
    h = layers.dense(x, w1, b1, "relu", flavour=flavour)
    h = layers.dense(h, w2, b2, "relu", flavour=flavour)
    return layers.dense(h, w3, b3, "none", flavour=flavour)


MLP = ModelDef(
    name="mlp",
    task="classification",
    x_shape=(MLP_DIMS[0],),
    num_classes=MLP_DIMS[-1],
    params=(
        ParamSpec("w1", (MLP_DIMS[0], MLP_DIMS[1])),
        ParamSpec("b1", (MLP_DIMS[1],)),
        ParamSpec("w2", (MLP_DIMS[1], MLP_DIMS[2])),
        ParamSpec("b2", (MLP_DIMS[2],)),
        ParamSpec("w3", (MLP_DIMS[2], MLP_DIMS[3])),
        ParamSpec("b3", (MLP_DIMS[3],)),
    ),
    predict=_mlp_predict,
)


# --- cnn / cnn_lite (ImageNet-role) ------------------------------------------

IMG_HW = 16
IMG_C = 3
IMG_CLASSES = 100


def _conv(x, k, stride):
    return jax.lax.conv_general_dilated(
        x,
        k,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _cnn_predict_generic(params, x, flavour, convs: Sequence[int]):
    """Conv stack (stride schedule in ``convs``) + GAP + pallas dense head."""
    i = 0
    h = x
    for stride in convs:
        k = params[i]
        bias = params[i + 1]
        h = jnp.maximum(_conv(h, k, stride) + bias[None, None, None, :], 0.0)
        i += 2
    h = jnp.mean(h, axis=(1, 2))  # global average pool -> [n, c_last]
    w, b = params[i], params[i + 1]
    return layers.dense(h, w, b, "none", flavour=flavour)


def _make_cnn(name: str, widths: Sequence[int], strides: Sequence[int]) -> ModelDef:
    specs = []
    cin = IMG_C
    for li, (cout, _s) in enumerate(zip(widths, strides)):
        specs.append(ParamSpec(f"k{li+1}", (3, 3, cin, cout)))
        specs.append(ParamSpec(f"cb{li+1}", (cout,)))
        cin = cout
    specs.append(ParamSpec("wh", (cin, IMG_CLASSES)))
    specs.append(ParamSpec("bh", (IMG_CLASSES,)))
    predict = functools.partial(_cnn_predict_generic, convs=tuple(strides))

    def _predict(params, x, flavour, _p=predict):
        return _p(params, x, flavour)

    return ModelDef(
        name=name,
        task="classification",
        x_shape=(IMG_HW, IMG_HW, IMG_C),
        num_classes=IMG_CLASSES,
        params=tuple(specs),
        predict=_predict,
    )


CNN = _make_cnn("cnn", widths=(32, 64, 128), strides=(1, 2, 2))
CNN_LITE = _make_cnn("cnn_lite", widths=(16, 32), strides=(2, 2))

MODELS = {m.name: m for m in (LINREG, MLP, CNN, CNN_LITE)}


# ---------------------------------------------------------------------------
# Executable builders — flat-argument closures suitable for jit + lowering
# ---------------------------------------------------------------------------


def build_init(model: ModelDef):
    def init(seed):
        key = jax.random.PRNGKey(seed)
        return model.init_params(key)

    return init


def build_fwd_loss(model: ModelDef, flavour: str):
    p = model.n_params

    def fwd_loss(*args):
        params, x, y = args[:p], args[p], args[p + 1]
        return (model.per_example_loss(params, x, y, flavour),)

    return fwd_loss


def _masked_loss_fn(model: ModelDef, flavour: str):
    def fn(params, x, y, mask):
        loss = model.per_example_loss(params, x, y, flavour)
        return layers.masked_mean(loss, mask)

    return fn


def build_train_step(model: ModelDef, flavour: str):
    p = model.n_params
    loss_fn = _masked_loss_fn(model, flavour)

    def train_step(*args):
        params = args[:p]
        x, y, mask, lr = args[p], args[p + 1], args[p + 2], args[p + 3]
        sel_loss, grads = jax.value_and_grad(loss_fn)(params, x, y, mask)
        new_params = layers.sgd_update_tree(params, grads, lr, flavour=flavour)
        return tuple(new_params) + (sel_loss,)

    return train_step


def build_grads(model: ModelDef, flavour: str):
    p = model.n_params
    loss_fn = _masked_loss_fn(model, flavour)

    def grads_fn(*args):
        params = args[:p]
        x, y, mask = args[p], args[p + 1], args[p + 2]
        sel_loss, grads = jax.value_and_grad(loss_fn)(params, x, y, mask)
        return tuple(grads) + (sel_loss,)

    return grads_fn


def build_apply(model: ModelDef, flavour: str):
    p = model.n_params

    def apply_fn(*args):
        params, grads, lr = args[:p], args[p : 2 * p], args[2 * p]
        return tuple(layers.sgd_update_tree(params, grads, lr, flavour=flavour))

    return apply_fn


def build_eval(model: ModelDef, flavour: str):
    p = model.n_params

    def eval_fn(*args):
        params = args[:p]
        x, y, mask = args[p], args[p + 1], args[p + 2]
        loss, metric = model.metric_terms(params, x, y, flavour)
        return (
            jnp.sum(loss * mask),
            jnp.sum(metric * mask),
            jnp.sum(mask),
        )

    return eval_fn


EXECUTABLES = ("init", "fwd_loss", "train_step", "grads", "apply", "eval")

# Sub-batch train_step variants: the coordinator gathers the selected
# rows into the smallest compiled size ≥ b so the backward pass costs
# O(b), not O(n) — the paper's "one backward" savings made real on
# wallclock, not just in example counts. (The masked full-batch
# train_step remains the numerically-identical fallback.)
GATHER_SIZES = (16, 32, 64)

_BUILDERS = {
    "fwd_loss": build_fwd_loss,
    "train_step": build_train_step,
    "grads": build_grads,
    "apply": build_apply,
    "eval": build_eval,
}


def build(model: ModelDef, exe: str, flavour: str):
    """Return the python callable for executable ``exe`` of ``model``."""
    if exe == "init":
        return build_init(model)
    return _BUILDERS[exe](model, flavour)


def example_args(model: ModelDef, exe: str, batch: int = BATCH):
    """ShapeDtypeStructs matching each executable's flat signature."""
    f32 = jnp.float32
    ps = [jax.ShapeDtypeStruct(s.shape, f32) for s in model.params]
    x = jax.ShapeDtypeStruct((batch,) + model.x_shape, f32)
    y = jax.ShapeDtypeStruct((batch,), model.y_dtype)
    mask = jax.ShapeDtypeStruct((batch,), f32)
    lr = jax.ShapeDtypeStruct((), f32)
    if exe == "init":
        return [jax.ShapeDtypeStruct((), jnp.int32)]
    if exe == "fwd_loss":
        return ps + [x, y]
    if exe == "train_step":
        return ps + [x, y, mask, lr]
    if exe == "grads":
        return ps + [x, y, mask]
    if exe == "apply":
        return ps + ps + [lr]
    if exe == "eval":
        return ps + [x, y, mask]
    raise ValueError(f"unknown executable {exe!r}")
