"""Pytest bootstrap for the python/ tree.

Makes the ``compile`` package importable when pytest is invoked from the
repository root (``pytest python/tests``): pytest only inserts the
*rootdir-adjacent* directory for package-less layouts, so we add
``python/`` explicitly.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
