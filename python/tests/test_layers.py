"""L2 layer correctness: custom_vjp (Pallas bwd kernels) vs jax autodiff.

The jnp flavour is differentiated by jax's own autodiff; the pallas
flavour uses our hand-written backward kernels. Their gradients must
agree — this validates the backward kernels end-to-end.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="the L2 layers need jax")
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="layer sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from compile import layers

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([2, 4, 8, 10, 16, 100, 128])


def _rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=15, deadline=None)
@given(
    m=DIMS,
    k=st.sampled_from([2, 8, 16]),
    n=st.sampled_from([2, 8, 16]),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_grads_match_autodiff(m, k, n, act, seed):
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(r.standard_normal((k, n)).astype(np.float32))
    b = jnp.asarray(r.standard_normal((n,)).astype(np.float32))

    def f(flavour):
        def inner(x, w, b):
            return jnp.sum(layers.dense(x, w, b, act, flavour=flavour) ** 2)

        return jax.grad(inner, argnums=(0, 1, 2))(x, w, b)

    gp = f("pallas")
    gj = f("jnp")
    for a, bb in zip(gp, gj):
        np.testing.assert_allclose(a, bb, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=DIMS, c=st.sampled_from([2, 10, 100]), seed=st.integers(0, 2**31 - 1))
def test_xent_grads_match_autodiff(n, c, seed):
    r = _rng(seed)
    logits = jnp.asarray((2 * r.standard_normal((n, c))).astype(np.float32))
    labels = jnp.asarray(r.integers(0, c, size=(n,)).astype(np.int32))
    weights = jnp.asarray(r.standard_normal((n,)).astype(np.float32))

    def f(flavour):
        def inner(logits):
            return jnp.sum(layers.softmax_xent(logits, labels, flavour=flavour) * weights)

        return jax.grad(inner)(logits)

    np.testing.assert_allclose(f("pallas"), f("jnp"), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_mse_grads_match_autodiff(n, seed):
    r = _rng(seed)
    pred = jnp.asarray(r.standard_normal((n,)).astype(np.float32))
    tgt = jnp.asarray(r.standard_normal((n,)).astype(np.float32))

    def f(flavour):
        def inner(pred):
            return jnp.sum(layers.mse(pred, tgt, flavour=flavour) * 0.5)

        return jax.grad(inner)(pred)

    np.testing.assert_allclose(f("pallas"), f("jnp"), rtol=1e-5, atol=1e-5)


def test_unknown_flavour_raises():
    with pytest.raises(ValueError):
        layers.dense(jnp.ones((2, 2)), jnp.ones((2, 2)), jnp.ones((2,)), flavour="torch")


def test_sgd_update_tree_applies_elementwise():
    params = (jnp.ones((4, 4)), jnp.full((4,), 2.0))
    grads = (jnp.full((4, 4), 0.5), jnp.ones((4,)))
    out = layers.sgd_update_tree(params, grads, jnp.float32(0.1), flavour="pallas")
    np.testing.assert_allclose(out[0], np.full((4, 4), 0.95), rtol=1e-6)
    np.testing.assert_allclose(out[1], np.full((4,), 1.9), rtol=1e-6)
