"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and loss/label distributions); assert_allclose
against ``kernels.ref``. This is the CORE correctness signal for the
Pallas layer — if these pass, the ``pallas`` artifact flavour computes
the same numbers as the ``jnp`` flavour.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="the Pallas kernels need jax")
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="kernel sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import losses as klosses
from compile.kernels import matmul as kmatmul
from compile.kernels import ref
from compile.kernels import update as kupdate

jax.config.update("jax_platform_name", "cpu")

# Dims are drawn from realistic divisor structures (the models use 128,
# 256, 784, 100, 10, 1) plus awkward primes to exercise _block fallback.
DIMS = st.sampled_from([1, 2, 3, 5, 7, 8, 10, 16, 100, 128, 256])
SMALL_DIMS = st.sampled_from([1, 2, 3, 5, 8, 10, 16, 32])


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=SMALL_DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = r.standard_normal((m, k)).astype(np.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    got = kmatmul.matmul(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=DIMS,
    k=SMALL_DIMS,
    n=SMALL_DIMS,
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    r = _rng(seed)
    x = r.standard_normal((m, k)).astype(np.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    b = r.standard_normal((n,)).astype(np.float32)
    got = kmatmul.matmul_bias_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    want = ref.matmul_bias_act(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_shape_mismatch_raises():
    with pytest.raises(ValueError):
        kmatmul.matmul(jnp.ones((4, 3)), jnp.ones((2, 4)))


def test_matmul_bias_act_unknown_act_raises():
    with pytest.raises(ValueError):
        kmatmul.matmul_bias_act(jnp.ones((4, 4)), jnp.ones((4, 4)), jnp.ones((4,)), "gelu")


def test_block_picks_largest_divisor():
    assert kmatmul._block(784) == 112
    assert kmatmul._block(256) == 128
    assert kmatmul._block(128) == 128
    assert kmatmul._block(100) == 100
    assert kmatmul._block(13) == 13
    assert kmatmul._block(257) == 257  # prime > target: single block
    with pytest.raises(ValueError):
        kmatmul._block(0)


def test_vmem_bytes_within_budget():
    # Every dense shape used by the models must fit VMEM comfortably
    # (≤ 4 MiB per grid step leaves headroom for double buffering).
    for m, n, k in [(128, 256, 784), (128, 256, 256), (128, 10, 256), (128, 100, 128)]:
        assert kmatmul.vmem_bytes(m, n, k) <= 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=DIMS, c=st.sampled_from([2, 3, 10, 100]), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(n, c, seed):
    r = _rng(seed)
    logits = (5 * r.standard_normal((n, c))).astype(np.float32)
    labels = r.integers(0, c, size=(n,)).astype(np.int32)
    got = klosses.softmax_xent(jnp.asarray(logits), jnp.asarray(labels))
    want = ref.softmax_xent(jnp.asarray(logits), jnp.asarray(labels))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=DIMS, c=st.sampled_from([2, 10, 100]), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_grad_matches_ref(n, c, seed):
    r = _rng(seed)
    logits = (3 * r.standard_normal((n, c))).astype(np.float32)
    labels = r.integers(0, c, size=(n,)).astype(np.int32)
    dloss = r.standard_normal((n,)).astype(np.float32)
    got = klosses.softmax_xent_grad(
        jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(dloss)
    )
    want = ref.softmax_xent_grad(
        jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(dloss)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_softmax_xent_is_nonnegative_and_extreme_logits_stable():
    logits = jnp.asarray([[1000.0, -1000.0], [-1000.0, 1000.0]], jnp.float32)
    labels = jnp.asarray([0, 0], jnp.int32)
    loss = klosses.softmax_xent(logits, labels)
    assert np.all(np.isfinite(np.asarray(loss)))
    np.testing.assert_allclose(loss[0], 0.0, atol=1e-6)
    assert float(loss[1]) > 100.0


# ---------------------------------------------------------------------------
# mse
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_mse_matches_ref(n, seed):
    r = _rng(seed)
    pred = r.standard_normal((n,)).astype(np.float32)
    tgt = r.standard_normal((n,)).astype(np.float32)
    got = klosses.mse(jnp.asarray(pred), jnp.asarray(tgt))
    want = ref.mse(jnp.asarray(pred), jnp.asarray(tgt))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_mse_grad_matches_ref(n, seed):
    r = _rng(seed)
    pred = r.standard_normal((n,)).astype(np.float32)
    tgt = r.standard_normal((n,)).astype(np.float32)
    dl = r.standard_normal((n,)).astype(np.float32)
    got = klosses.mse_grad(jnp.asarray(pred), jnp.asarray(tgt), jnp.asarray(dl))
    want = ref.mse_grad(jnp.asarray(pred), jnp.asarray(tgt), jnp.asarray(dl))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sgd update
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from([(1,), (7,), (512,), (513,), (16, 16), (3, 3, 3, 8), (784, 256)]),
    lr=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(shape, lr, seed):
    r = _rng(seed)
    w = r.standard_normal(shape).astype(np.float32)
    g = r.standard_normal(shape).astype(np.float32)
    got = kupdate.sgd_update(jnp.asarray(w), jnp.asarray(g), jnp.float32(lr))
    want = ref.sgd_update(jnp.asarray(w), jnp.asarray(g), jnp.float32(lr))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sgd_update_shape_mismatch_raises():
    with pytest.raises(ValueError):
        kupdate.sgd_update(jnp.ones((4,)), jnp.ones((5,)), jnp.float32(0.1))


def test_sgd_update_zero_lr_identity():
    w = jnp.arange(600, dtype=jnp.float32)
    g = jnp.ones((600,), jnp.float32)
    out = kupdate.sgd_update(w, g, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


# ---------------------------------------------------------------------------
# masked mean
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=DIMS, p=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_masked_mean(n, p, seed):
    r = _rng(seed)
    v = r.standard_normal((n,)).astype(np.float32)
    m = (r.random((n,)) < p).astype(np.float32)
    got = float(ref.masked_mean(jnp.asarray(v), jnp.asarray(m)))
    k = m.sum()
    want = float((v * m).sum() / max(k, 1.0))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_masked_mean_empty_mask_is_zero():
    v = jnp.ones((8,), jnp.float32)
    m = jnp.zeros((8,), jnp.float32)
    assert float(ref.masked_mean(v, m)) == 0.0
