"""L2 model tests: shapes, flavour equivalence, executable contracts."""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="the L2 models need jax")
import jax.numpy as jnp

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

N = M.BATCH


def _batch(mdl, seed=0):
    kx, ky, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (N,) + mdl.x_shape, jnp.float32)
    if mdl.task == "classification":
        y = jax.random.randint(ky, (N,), 0, mdl.num_classes, jnp.int32)
    else:
        y = jax.random.normal(ky, (N,), jnp.float32)
    mask = (jax.random.uniform(km, (N,)) < 0.3).astype(jnp.float32)
    return x, y, mask


@pytest.fixture(scope="module", params=sorted(M.MODELS))
def mdl(request):
    return M.MODELS[request.param]


def test_init_shapes(mdl):
    params = M.build(mdl, "init", "pallas")(jnp.int32(7))
    assert len(params) == mdl.n_params
    for p, spec in zip(params, mdl.params):
        assert p.shape == spec.shape, spec.name
        assert p.dtype == jnp.float32
    # biases start at zero; weights do not
    for p, spec in zip(params, mdl.params):
        if len(spec.shape) == 1:
            assert float(jnp.abs(p).max()) == 0.0
        else:
            assert float(jnp.abs(p).max()) > 0.0


def test_init_deterministic_per_seed(mdl):
    a = M.build(mdl, "init", "pallas")(jnp.int32(3))
    b = M.build(mdl, "init", "pallas")(jnp.int32(3))
    c = M.build(mdl, "init", "pallas")(jnp.int32(4))
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc))
        for pa, pc in zip(a, c)
        if pa.ndim > 1
    )


def test_fwd_loss_shape_and_flavour_equivalence(mdl):
    params = mdl.init_params(jax.random.PRNGKey(0))
    x, y, _ = _batch(mdl)
    lp = M.build(mdl, "fwd_loss", "pallas")(*params, x, y)[0]
    lj = M.build(mdl, "fwd_loss", "jnp")(*params, x, y)[0]
    assert lp.shape == (N,)
    assert np.all(np.isfinite(np.asarray(lp)))
    if mdl.task == "classification":
        assert float(lp.min()) >= 0.0
    np.testing.assert_allclose(lp, lj, rtol=3e-5, atol=3e-5)


def test_train_step_flavour_equivalence(mdl):
    params = mdl.init_params(jax.random.PRNGKey(0))
    x, y, mask = _batch(mdl)
    tp = M.build(mdl, "train_step", "pallas")(*params, x, y, mask, jnp.float32(0.05))
    tj = M.build(mdl, "train_step", "jnp")(*params, x, y, mask, jnp.float32(0.05))
    assert len(tp) == mdl.n_params + 1
    for a, b in zip(tp, tj):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_train_step_reduces_selected_loss(mdl):
    """A few masked steps must reduce the masked mean loss (descent)."""
    params = mdl.init_params(jax.random.PRNGKey(1))
    x, y, mask = _batch(mdl, seed=5)
    step = jax.jit(M.build(mdl, "train_step", "jnp"))
    lr = jnp.float32(0.05 if mdl.task == "classification" else 0.01)
    first = None
    for _ in range(10):
        out = step(*params, x, y, mask, lr)
        params, loss = out[:-1], float(out[-1])
        if first is None:
            first = loss
    assert loss < first, f"{mdl.name}: loss did not descend ({first} -> {loss})"


def test_grads_then_apply_equals_train_step(mdl):
    """grads + apply (the data-parallel path) == fused train_step."""
    params = mdl.init_params(jax.random.PRNGKey(2))
    x, y, mask = _batch(mdl, seed=9)
    lr = jnp.float32(0.1)
    fused = M.build(mdl, "train_step", "jnp")(*params, x, y, mask, lr)
    gout = M.build(mdl, "grads", "jnp")(*params, x, y, mask)
    grads, gloss = gout[:-1], gout[-1]
    applied = M.build(mdl, "apply", "jnp")(*params, *grads, lr)
    np.testing.assert_allclose(float(gloss), float(fused[-1]), rtol=1e-6)
    for a, b in zip(applied, fused[:-1]):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_eval_masked_sums(mdl):
    params = mdl.init_params(jax.random.PRNGKey(3))
    x, y, mask = _batch(mdl, seed=11)
    sum_loss, sum_metric, count = M.build(mdl, "eval", "jnp")(*params, x, y, mask)
    per = M.build(mdl, "fwd_loss", "jnp")(*params, x, y)[0]
    np.testing.assert_allclose(
        float(sum_loss), float(jnp.sum(per * mask)), rtol=1e-5
    )
    assert float(count) == float(jnp.sum(mask))
    if mdl.task == "classification":
        assert 0.0 <= float(sum_metric) <= float(count)


def test_eval_zero_mask(mdl):
    params = mdl.init_params(jax.random.PRNGKey(3))
    x, y, _ = _batch(mdl)
    out = M.build(mdl, "eval", "jnp")(*params, x, y, jnp.zeros((N,), jnp.float32))
    assert [float(v) for v in out] == [0.0, 0.0, 0.0]


def test_example_args_match_build_signature(mdl):
    """Every executable must trace successfully with its declared args."""
    for exe in M.EXECUTABLES:
        fn = M.build(mdl, exe, "jnp")
        args = M.example_args(mdl, exe)
        jax.eval_shape(fn, *args)  # raises on mismatch


def test_train_step_traces_at_gather_sizes(mdl):
    """Sub-batch variants (GATHER_SIZES) must trace for every model."""
    for bb in M.GATHER_SIZES:
        fn = M.build(mdl, "train_step", "jnp")
        args = M.example_args(mdl, "train_step", batch=bb)
        jax.eval_shape(fn, *args)


def test_gathered_subbatch_equals_masked_fullbatch(mdl):
    """Masked mean over gathered rows == masked mean over the full batch
    (the numerical-identity contract of train_step_selected)."""
    params = mdl.init_params(jax.random.PRNGKey(4))
    x, y, _ = _batch(mdl, seed=13)
    lr = jnp.float32(0.05)
    # select 16 rows
    sel = jnp.arange(16) * 7 % N
    full_mask = jnp.zeros((N,), jnp.float32).at[sel].set(1.0)
    full = M.build(mdl, "train_step", "jnp")(*params, x, y, full_mask, lr)

    gx = x[sel]
    gy = y[sel]
    gmask = jnp.ones((16,), jnp.float32)
    gathered = M.build(mdl, "train_step", "jnp")(*params, gx, gy, gmask, lr)
    for a, b in zip(full, gathered):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_unknown_executable_raises(mdl):
    with pytest.raises(KeyError):
        M.build(mdl, "predict_proba", "jnp")
    with pytest.raises(ValueError):
        M.example_args(mdl, "predict_proba")
