"""AOT pipeline tests: HLO text emission and manifest schema.

These validate the python→rust interchange contract without needing the
rust side: the emitted HLO text must parse back through the XLA client,
and the manifest must describe exactly the artifacts on disk.
"""

import json
import os

import pytest

jax = pytest.importorskip("jax", reason="the AOT pipeline needs jax")
import jax.numpy as jnp

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smoke():
    lowered = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_lower_one_linreg_fwd_loss_mentions_shapes():
    text = aot.lower_one(M.LINREG, "fwd_loss", "jnp")
    assert "HloModule" in text
    assert f"f32[{M.BATCH}" in text


def test_manifest_entry_schema():
    e = aot.manifest_entry(M.MLP, ["pallas", "jnp"])
    assert e["task"] == "classification"
    assert e["x_shape"] == [784]
    assert e["y_dtype"] == "i32"
    assert [p["name"] for p in e["params"]] == ["w1", "b1", "w2", "b2", "w3", "b3"]
    assert e["executables"]["fwd_loss:pallas"] == "mlp_fwd_loss.pallas.hlo.txt"
    # 6 core executables + the sub-batch train_step variants, × 2 flavours
    assert len(e["executables"]) == (len(M.EXECUTABLES) + len(M.GATHER_SIZES)) * 2
    assert e["executables"]["train_step_b16:jnp"] == "mlp_train_step_b16.jnp.hlo.txt"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert manifest["batch"] == M.BATCH
    for name, entry in manifest["models"].items():
        assert name in M.MODELS
        for key, fname in entry["executables"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), f"{key} -> {fname} missing"
            with open(path) as f:
                head = f.read(64)
            assert "HloModule" in head, fname


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_param_shapes_match_models():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, entry in manifest["models"].items():
        mdl = M.MODELS[name]
        got = [(p["name"], tuple(p["shape"])) for p in entry["params"]]
        want = [(p.name, p.shape) for p in mdl.params]
        assert got == want
