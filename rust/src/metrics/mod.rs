//! Metrics substrate: per-step records, latency histograms, throughput
//! and CSV/JSON export — the observability a production training
//! subsystem needs.

pub mod hist;
pub mod recorder;

pub use hist::Histogram;
pub use recorder::{EvalRecord, Recorder, StepRecord};
