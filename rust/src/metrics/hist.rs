//! Log-bucketed latency histogram with percentile queries.
//!
//! Buckets grow geometrically (×2 from 1µs), so p50/p90/p99 over
//! microsecond-to-second latencies cost 64 counters and no allocation
//! on the record path.

/// Geometric-bucket histogram for durations in nanoseconds.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; 64],
    total: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; 64], total: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket(ns: u64) -> usize {
        // bucket k covers [2^k, 2^{k+1}) microseconds-ish; work in ns
        // with bucket 0 = [0, 1024ns)
        (64 - ns.max(1).leading_zeros() as usize).min(63)
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.total as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Upper bound of the bucket containing quantile `q` ∈ [0, 1].
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank.max(1) {
                return 1u64 << k; // bucket upper bound
            }
        }
        self.max_ns
    }

    /// `(p50, p90, p99)` in microseconds — the summary line format.
    pub fn summary_us(&self) -> (f64, f64, f64) {
        (
            self.quantile_ns(0.50) as f64 / 1000.0,
            self.quantile_ns(0.90) as f64 / 1000.0,
            self.quantile_ns(0.99) as f64 / 1000.0,
        )
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn quantiles_are_monotone_and_bracket_values() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1µs .. 1ms
        }
        let p50 = h.quantile_ns(0.5);
        let p90 = h.quantile_ns(0.9);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // p50 of uniform 1µs..1ms is ~500µs; bucket bound within ×2
        assert!((250_000..=1_050_000).contains(&p50), "p50={p50}");
        assert!(h.max_ns() == 1_000_000);
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_ns(10_000);
        b.record_ns(20_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 20_000);
    }
}
