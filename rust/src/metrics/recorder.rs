//! Step/eval recording and CSV/JSON export.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::hist::Histogram;

/// One training step's record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub epoch: usize,
    /// Mean loss over the *selected* subset (what the backward saw).
    pub sel_loss: f32,
    /// Mean loss over the full batch (from the forward pass).
    pub batch_loss: f32,
    pub n_forward: usize,
    pub n_selected: usize,
    pub fwd_us: u64,
    pub sel_us: u64,
    pub bwd_us: u64,
    /// Cumulative loss-cache counters at record time (zero when the
    /// trainer runs without a cache). `cache_stale` ⊆ `cache_misses`:
    /// lookups that failed freshness although every row was recorded.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_stale: u64,
    /// Order-sensitive fingerprint of the selected indices
    /// ([`crate::sampling::selection_hash`]) — the compact observable
    /// the pipeline-vs-serial equivalence tests compare.
    pub sel_hash: u64,
    /// Inference-fleet workers alive at record time (0 when the driver
    /// has no fleet — serial and data-parallel modes).
    pub workers_alive: u32,
    /// Fleet workers relaunched so far (0 under the fail-fast policy).
    pub worker_restarts: u32,
    /// Wire frames the leader sent this step (0 without a proc fleet).
    pub frames_per_step: u64,
    /// `ParamUpdate` bytes broadcast this step — the number the bf16
    /// param-precision knob halves (0 without a proc fleet).
    pub publish_bytes: u64,
    /// Cumulative reshard events (mid-run worker joins + retirements;
    /// 0 without an elastic proc fleet).
    pub reshards: u64,
    /// Fleet members at record time under the current ownership map
    /// (0 when the driver has no fleet).
    pub n_workers: u32,
    /// Wall time the leader spent publishing the parameter snapshot
    /// this step. Under the overlapped leader this is the slowest
    /// writer thread's enqueue-to-flushed time, not hot-loop time
    /// (0 without a proc fleet).
    pub publish_us: u64,
    /// Round-trip time of the `CacheLookup` fan-out that served this
    /// step's losses. Under prefetch the clock starts at issue (during
    /// the previous backward), so this can exceed the hot-loop
    /// `fwd_us` it was hidden behind (0 without a proc fleet).
    pub lookup_rtt_us: u64,
}

/// One evaluation's record.
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub step: u64,
    pub epoch: usize,
    pub loss: f64,
    /// Accuracy for classification, MSE for regression.
    pub metric: f64,
}

/// Accumulates step + eval records and latency histograms.
#[derive(Default)]
pub struct Recorder {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub fwd_hist: Histogram,
    pub sel_hist: Histogram,
    pub bwd_hist: Histogram,
    /// Selection-to-apply latency: selection + backward + publish per
    /// step — the SLO axis of the production-soak roadmap item.
    pub apply_hist: Histogram,
    start: Option<std::time::Instant>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { start: Some(std::time::Instant::now()), ..Default::default() }
    }

    pub fn record_step(&mut self, rec: StepRecord) {
        self.fwd_hist.record_ns(rec.fwd_us * 1000);
        self.sel_hist.record_ns(rec.sel_us * 1000);
        self.bwd_hist.record_ns(rec.bwd_us * 1000);
        self.apply_hist.record_ns((rec.sel_us + rec.bwd_us + rec.publish_us) * 1000);
        self.steps.push(rec);
    }

    pub fn record_eval(&mut self, rec: EvalRecord) {
        self.evals.push(rec);
    }

    /// Total examples forwarded / selected (the paper's compute story).
    pub fn totals(&self) -> (u64, u64) {
        let fwd: u64 = self.steps.iter().map(|s| s.n_forward as u64).sum();
        let sel: u64 = self.steps.iter().map(|s| s.n_selected as u64).sum();
        (fwd, sel)
    }

    /// Steps per second since construction.
    pub fn throughput(&self) -> f64 {
        match self.start {
            Some(t0) => {
                let dt = t0.elapsed().as_secs_f64();
                if dt > 0.0 {
                    self.steps.len() as f64 / dt
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// Write per-step records as CSV (one header + one row per step).
    pub fn write_steps_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(
            f,
            "step,epoch,sel_loss,batch_loss,n_forward,n_selected,fwd_us,sel_us,bwd_us,\
             cache_hits,cache_misses,cache_stale,sel_hash,workers_alive,worker_restarts,\
             frames_per_step,publish_bytes,reshards,n_workers,publish_us,lookup_rtt_us"
        )?;
        for s in &self.steps {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.step,
                s.epoch,
                s.sel_loss,
                s.batch_loss,
                s.n_forward,
                s.n_selected,
                s.fwd_us,
                s.sel_us,
                s.bwd_us,
                s.cache_hits,
                s.cache_misses,
                s.cache_stale,
                s.sel_hash,
                s.workers_alive,
                s.worker_restarts,
                s.frames_per_step,
                s.publish_bytes,
                s.reshards,
                s.n_workers,
                s.publish_us,
                s.lookup_rtt_us
            )?;
        }
        Ok(())
    }

    /// Write eval records as CSV.
    pub fn write_evals_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(f, "step,epoch,loss,metric")?;
        for e in &self.evals {
            writeln!(f, "{},{},{},{}", e.step, e.epoch, e.loss, e.metric)?;
        }
        Ok(())
    }

    /// One-line latency summary for logs.
    pub fn latency_summary(&self) -> String {
        let (f50, f90, f99) = self.fwd_hist.summary_us();
        let (s50, s90, s99) = self.sel_hist.summary_us();
        let (b50, b90, b99) = self.bwd_hist.summary_us();
        let (a50, _, a99) = self.apply_hist.summary_us();
        format!(
            "fwd p50/p90/p99 {f50:.0}/{f90:.0}/{f99:.0}µs  \
             sel {s50:.0}/{s90:.0}/{s99:.0}µs  \
             bwd {b50:.0}/{b90:.0}/{b99:.0}µs  \
             sel→apply p50/p99 {a50:.0}/{a99:.0}µs"
        )
    }

    /// Selection-to-apply latency quantiles in µs: (p50, p99).
    pub fn apply_latency_us(&self) -> (f64, f64) {
        let (p50, _, p99) = self.apply_hist.summary_us();
        (p50, p99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: u64) -> StepRecord {
        StepRecord {
            step: i,
            epoch: 0,
            sel_loss: 1.0,
            batch_loss: 2.0,
            n_forward: 128,
            n_selected: 32,
            fwd_us: 100,
            sel_us: 10,
            bwd_us: 200,
            cache_hits: 1,
            cache_misses: 2,
            cache_stale: 0,
            sel_hash: 42,
            workers_alive: 4,
            worker_restarts: 0,
            frames_per_step: 6,
            publish_bytes: 512,
            reshards: 1,
            n_workers: 4,
            publish_us: 30,
            lookup_rtt_us: 90,
        }
    }

    #[test]
    fn totals_accumulate() {
        let mut r = Recorder::new();
        for i in 0..5 {
            r.record_step(step(i));
        }
        assert_eq!(r.totals(), (640, 160));
        assert_eq!(r.fwd_hist.count(), 5);
    }

    #[test]
    fn csv_export_roundtrip() {
        let mut r = Recorder::new();
        r.record_step(step(0));
        r.record_eval(EvalRecord { step: 0, epoch: 0, loss: 0.5, metric: 0.9 });
        let dir = crate::testkit::TempDir::new("recorder").unwrap();
        let sp = dir.path().join("steps.csv");
        let ep = dir.path().join("evals.csv");
        r.write_steps_csv(&sp).unwrap();
        r.write_evals_csv(&ep).unwrap();
        let steps = std::fs::read_to_string(&sp).unwrap();
        assert!(steps.lines().count() == 2);
        assert!(steps.contains("0,0,1,2,128,32,100,10,200,1,2,0,42,4,0,6,512,1,4,30,90"));
        assert!(steps.starts_with(
            "step,epoch,sel_loss,batch_loss,n_forward,n_selected,fwd_us,sel_us,bwd_us,\
             cache_hits,cache_misses,cache_stale,sel_hash,workers_alive,worker_restarts,\
             frames_per_step,publish_bytes,reshards,n_workers,publish_us,lookup_rtt_us"
        ));
        let evals = std::fs::read_to_string(&ep).unwrap();
        assert!(evals.contains("0,0,0.5,0.9"));
    }

    #[test]
    fn latency_summary_formats() {
        let mut r = Recorder::new();
        r.record_step(step(0));
        let s = r.latency_summary();
        assert!(s.contains("fwd") && s.contains("sel") && s.contains("bwd"));
        assert!(s.contains("sel→apply"), "summary: {s}");
    }

    /// Selection-to-apply aggregates sel + bwd + publish per step, so
    /// a single recorded step's quantiles bracket that sum.
    #[test]
    fn apply_latency_tracks_sel_bwd_publish() {
        let mut r = Recorder::new();
        r.record_step(step(0)); // 10 + 200 + 30 = 240 µs
        let (p50, p99) = r.apply_latency_us();
        assert!(p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
        assert_eq!(r.apply_hist.count(), 1);
    }
}
