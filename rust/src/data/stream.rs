//! Streaming ingestion substrate: the "continuous production data"
//! setting the paper's introduction motivates.
//!
//! A [`StreamSource`] produces an unbounded sequence of batches (with
//! optional concept drift); [`Prefetcher`] runs a source on its own
//! thread behind a **bounded** channel, giving the trainer backpressure
//! semantics: if selection + backward falls behind ingestion, the source
//! blocks instead of buffering unboundedly, and the stall time is
//! counted so the pipeline's health is observable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::dataset::{Batch, InMemoryDataset};
use super::rng::Rng;

/// An unbounded batch producer.
pub trait StreamSource: Send {
    /// Produce the next batch of exactly `batch` rows.
    fn next_batch(&mut self, batch: usize) -> Batch;
    /// Human-readable name for metrics.
    fn name(&self) -> &str;
}

/// Streams batches by resampling (with replacement) from an in-memory
/// dataset — the classic "infinite epoch" production simulation. With
/// `drift > 0`, feature values slowly scale over time, simulating
/// distribution shift in a production stream.
pub struct ResamplingStream {
    ds: InMemoryDataset,
    rng: Rng,
    drift: f32,
    step: u64,
    label: String,
}

impl ResamplingStream {
    pub fn new(ds: InMemoryDataset, seed: u64, drift: f32) -> Self {
        ResamplingStream {
            ds,
            rng: Rng::seed_from(seed),
            drift,
            step: 0,
            label: "resampling".to_string(),
        }
    }
}

impl StreamSource for ResamplingStream {
    fn next_batch(&mut self, batch: usize) -> Batch {
        let idx: Vec<usize> = (0..batch.min(self.ds.len()))
            .map(|_| self.rng.below(self.ds.len()))
            .collect();
        let mut b = self
            .ds
            .gather_batch(&idx, batch)
            .expect("resampled indices are in range");
        if self.drift > 0.0 {
            let scale = 1.0 + self.drift * (self.step as f32 / 1000.0).sin();
            if let crate::data::tensor::TensorData::F32(v) = &mut b.x.data {
                for x in v.iter_mut() {
                    *x *= scale;
                }
            }
        }
        self.step += 1;
        b
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Counters exported by the prefetcher for pipeline observability.
#[derive(Default, Debug)]
pub struct StreamStats {
    /// Batches produced by the source.
    pub produced: AtomicU64,
    /// Nanoseconds the producer spent blocked on the full channel
    /// (backpressure from the trainer).
    pub blocked_ns: AtomicU64,
    /// Nanoseconds the consumer spent blocked waiting for a batch
    /// (ingestion is the bottleneck when this dominates).
    pub consumer_blocked_ns: AtomicU64,
}

/// Bounded-channel prefetcher running a [`StreamSource`] on its own
/// thread. Dropping the `Prefetcher` (receiver) stops the producer.
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    pub stats: Arc<StreamStats>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// `depth` is the channel bound = how many batches may be in flight.
    pub fn spawn(mut source: Box<dyn StreamSource>, batch: usize, depth: usize) -> Self {
        assert!(depth > 0, "prefetch depth must be positive");
        let (tx, rx) = mpsc::sync_channel::<Batch>(depth);
        let stats = Arc::new(StreamStats::default());
        let pstats = stats.clone();
        let handle = std::thread::Builder::new()
            .name("obftf-prefetch".into())
            .spawn(move || loop {
                let b = source.next_batch(batch);
                pstats.produced.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                if tx.send(b).is_err() {
                    return; // consumer dropped: clean shutdown
                }
                pstats
                    .blocked_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx, stats, handle: Some(handle) }
    }

    /// Blocking fetch of the next batch.
    pub fn next(&self) -> Batch {
        let t0 = Instant::now();
        let b = self.rx.recv().expect("producer thread never closes first");
        self.stats
            .consumer_blocked_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        b
    }

    /// Non-blocking fetch.
    pub fn try_next(&self) -> Option<Batch> {
        self.rx.try_recv().ok()
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Close the channel first so the producer unblocks, then join.
        // Draining the receiver is implicit in dropping `rx` after us.
        let Prefetcher { rx, handle, .. } = self;
        // Explicitly drop rx by swapping in a dummy closed channel.
        let (_tx, dummy) = mpsc::sync_channel::<Batch>(1);
        let real = std::mem::replace(rx, dummy);
        drop(real);
        if let Some(h) = handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;

    fn toy_ds(n: usize) -> InMemoryDataset {
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        InMemoryDataset::new(vec![1], xs, Targets::F32(vec![0.0; n])).unwrap()
    }

    #[test]
    fn resampling_stream_fills_batches() {
        let mut s = ResamplingStream::new(toy_ds(10), 1, 0.0);
        let b = s.next_batch(8);
        assert_eq!(b.real, 8);
        assert!(b.x.as_f32().unwrap().iter().all(|&x| x < 10.0));
    }

    #[test]
    fn prefetcher_delivers_and_shuts_down() {
        let src = Box::new(ResamplingStream::new(toy_ds(16), 2, 0.0));
        let pf = Prefetcher::spawn(src, 4, 2);
        for _ in 0..10 {
            let b = pf.next();
            assert_eq!(b.batch_size(), 4);
        }
        assert!(pf.stats.produced.load(Ordering::Relaxed) >= 10);
        // consumer wait time was accounted (possibly zero, but the
        // counter must exist and never go backwards)
        let waited = pf.stats.consumer_blocked_ns.load(Ordering::Relaxed);
        let _ = pf.next();
        assert!(pf.stats.consumer_blocked_ns.load(Ordering::Relaxed) >= waited);
        drop(pf); // must not hang
    }

    #[test]
    fn backpressure_blocks_producer() {
        let src = Box::new(ResamplingStream::new(toy_ds(16), 3, 0.0));
        let pf = Prefetcher::spawn(src, 4, 1);
        std::thread::sleep(std::time::Duration::from_millis(50));
        // depth 1 + one in flight: producer can be at most a couple ahead
        let produced = pf.stats.produced.load(Ordering::Relaxed);
        assert!(produced <= 3, "producer ran unbounded: {produced}");
    }

    #[test]
    fn drift_changes_feature_scale() {
        let mut a = ResamplingStream::new(toy_ds(16), 4, 0.0);
        let mut b = ResamplingStream::new(toy_ds(16), 4, 0.5);
        // advance both far enough that sin() is non-zero
        for _ in 0..200 {
            a.next_batch(4);
            b.next_batch(4);
        }
        let xa = a.next_batch(4);
        let xb = b.next_batch(4);
        assert_ne!(xa.x.as_f32().unwrap(), xb.x.as_f32().unwrap());
    }
}
