//! Deterministic, splittable RNG substrate.
//!
//! The paper's pipeline is continuously fed by a production stream; for
//! reproducible experiments every data source, shuffler and sampler in
//! this crate draws from an explicitly seeded [`Rng`] (xoshiro256++,
//! seeded through SplitMix64 as recommended by the xoshiro authors).
//! `split()` derives statistically independent child streams so that
//! e.g. each epoch's shuffle and each worker's shard noise are decoupled
//! from the sampler's coin flips.

/// xoshiro256++ PRNG with SplitMix64 seeding and stream splitting.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (SplitMix64 expansion).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (consumes state from `self`).
    pub fn split(&mut self) -> Rng {
        let mut sm = self.next_u64() ^ 0xA5A5_A5A5_5A5A_5A5A;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our scales (n << 2^64): the
        // modulo bias at n <= 2^32 is < 2^-32, negligible for data gen.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-predictable — data generation is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent_of_parent_continuation() {
        let mut parent = Rng::seed_from(7);
        let mut child = parent.split();
        let c: Vec<u64> = (0..8).map(|_| child.next_u64()).collect();
        let p: Vec<u64> = (0..8).map(|_| parent.next_u64()).collect();
        assert_ne!(c, p);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct_in_range() {
        let mut r = Rng::seed_from(9);
        for _ in 0..50 {
            let got = r.choose_k(37, 12);
            assert_eq!(got.len(), 12);
            let mut s = got.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 12);
            assert!(got.iter().all(|&i| i < 37));
        }
    }

    #[test]
    fn choose_k_full_range() {
        let mut r = Rng::seed_from(9);
        let mut got = r.choose_k(8, 8);
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }
}
