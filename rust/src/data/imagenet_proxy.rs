//! ImageNet-proxy generator (Table 3 substitution — see DESIGN.md §3).
//!
//! 100-class synthetic 16×16×3 images: each class owns a low-frequency
//! 2-D pattern (random per-channel sinusoid mixture) plus a class color
//! bias; examples add pixel noise whose magnitude varies by class. This
//! gives a CNN-learnable signal with the heavy-tailed loss distribution
//! that Table 3's phenomenon (max-prob collapse, OBFTF ≥ uniform at low
//! ratios) depends on.

use super::dataset::{InMemoryDataset, Targets};
use super::rng::Rng;

pub const IMG_HW: usize = 16;
pub const IMG_C: usize = 3;
pub const IMG_CLASSES: usize = 100;
pub const IMG_DIM: usize = IMG_HW * IMG_HW * IMG_C;

/// Per-class pattern parameters.
#[derive(Clone, Debug)]
struct ClassPattern {
    /// Frequencies and phases per channel: (fx, fy, phase, amplitude).
    waves: Vec<(f32, f32, f32, f32)>,
    /// Constant per-channel color bias.
    color: [f32; IMG_C],
    /// Noise sigma for this class.
    sigma: f32,
}

/// Configuration for the ImageNet-proxy generator.
#[derive(Clone, Debug)]
pub struct ImagenetProxySpec {
    pub n_train: usize,
    pub n_test: usize,
    /// Base noise; per-class σ is drawn from `U(0.5, 1.5) · noise`.
    pub noise: f32,
    /// Fraction of training labels flipped.
    pub label_noise: f32,
}

impl Default for ImagenetProxySpec {
    fn default() -> Self {
        ImagenetProxySpec {
            n_train: 16384,
            n_test: 4096,
            noise: 0.6,
            label_noise: 0.0,
        }
    }
}

impl ImagenetProxySpec {
    fn patterns(&self, rng: &mut Rng) -> Vec<ClassPattern> {
        (0..IMG_CLASSES)
            .map(|_| ClassPattern {
                waves: (0..IMG_C)
                    .map(|_| {
                        (
                            rng.uniform_in(0.5, 3.0) as f32,
                            rng.uniform_in(0.5, 3.0) as f32,
                            rng.uniform_in(0.0, std::f64::consts::TAU) as f32,
                            rng.uniform_in(0.4, 1.0) as f32,
                        )
                    })
                    .collect(),
                color: [
                    rng.uniform_in(-0.5, 0.5) as f32,
                    rng.uniform_in(-0.5, 0.5) as f32,
                    rng.uniform_in(-0.5, 0.5) as f32,
                ],
                sigma: self.noise * rng.uniform_in(0.5, 1.5) as f32,
            })
            .collect()
    }

    fn render(&self, p: &ClassPattern, rng: &mut Rng, out: &mut Vec<f32>) {
        // NHWC layout to match the jax model (`[n, 16, 16, 3]`).
        for y in 0..IMG_HW {
            for x in 0..IMG_HW {
                for c in 0..IMG_C {
                    let (fx, fy, ph, amp) = p.waves[c];
                    let u = x as f32 / IMG_HW as f32;
                    let v = y as f32 / IMG_HW as f32;
                    let val = amp
                        * (std::f32::consts::TAU * (fx * u + fy * v) + ph).sin()
                        + p.color[c]
                        + p.sigma * rng.normal() as f32;
                    out.push(val);
                }
            }
        }
    }

    fn generate(
        &self,
        n: usize,
        patterns: &[ClassPattern],
        label_noise: f32,
        rng: &mut Rng,
    ) -> InMemoryDataset {
        // flip decisions on their own stream (see mnist_proxy::generate)
        let mut flip_rng = rng.split();
        let mut xs = Vec::with_capacity(n * IMG_DIM);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(IMG_CLASSES);
            self.render(&patterns[class], rng, &mut xs);
            let label = if label_noise > 0.0 && flip_rng.bernoulli(label_noise as f64) {
                let mut l = flip_rng.below(IMG_CLASSES - 1);
                if l >= class {
                    l += 1;
                }
                l as i32
            } else {
                class as i32
            };
            ys.push(label);
        }
        InMemoryDataset::new(vec![IMG_HW, IMG_HW, IMG_C], xs, Targets::I32(ys))
            .expect("generator produces consistent shapes")
    }

    /// Generate (train, test) with shared class patterns.
    pub fn build(&self, seed: u64) -> (InMemoryDataset, InMemoryDataset) {
        let mut rng = Rng::seed_from(seed ^ 0x696d675f70726f78); // "img_prox"
        let patterns = self.patterns(&mut rng);
        let mut train_rng = rng.split();
        let mut test_rng = rng.split();
        let train = self.generate(self.n_train, &patterns, self.label_noise, &mut train_rng);
        let test = self.generate(self.n_test, &patterns, 0.0, &mut test_rng);
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let spec = ImagenetProxySpec { n_train: 128, n_test: 32, ..Default::default() };
        let (tr, te) = spec.build(0);
        assert_eq!(tr.len(), 128);
        assert_eq!(te.len(), 32);
        assert_eq!(tr.x_shape, vec![IMG_HW, IMG_HW, IMG_C]);
        assert_eq!(tr.xs.len(), 128 * IMG_DIM);
        if let Targets::I32(ys) = &tr.ys {
            assert!(ys.iter().all(|&y| (0..IMG_CLASSES as i32).contains(&y)));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = ImagenetProxySpec { n_train: 16, n_test: 4, ..Default::default() };
        let (a, _) = spec.build(9);
        let (b, _) = spec.build(9);
        assert_eq!(a.xs, b.xs);
    }

    #[test]
    fn class_signal_exceeds_noise_floor() {
        // two samples of the same class should correlate more than two of
        // different classes, on average
        let spec = ImagenetProxySpec {
            n_train: 400,
            n_test: 4,
            noise: 0.3,
            ..Default::default()
        };
        let (tr, _) = spec.build(3);
        let Targets::I32(ys) = &tr.ys else { panic!() };
        let dot = |i: usize, j: usize| -> f64 {
            (0..IMG_DIM)
                .map(|d| tr.xs[i * IMG_DIM + d] as f64 * tr.xs[j * IMG_DIM + d] as f64)
                .sum()
        };
        let mut same = vec![];
        let mut diff = vec![];
        for i in 0..60 {
            for j in (i + 1)..60 {
                if ys[i] == ys[j] {
                    same.push(dot(i, j));
                } else {
                    diff.push(dot(i, j));
                }
            }
        }
        if same.is_empty() {
            return; // extremely unlikely with 60 draws over 100 classes; skip
        }
        let ms = same.iter().sum::<f64>() / same.len() as f64;
        let md = diff.iter().sum::<f64>() / diff.len() as f64;
        assert!(ms > md, "same-class corr {ms} <= diff-class {md}");
    }
}
