//! Paper §4.1 synthetic regression workloads (Fig 1).
//!
//! Clean:   `y = 2x + 1 + U(-5, 5)`, 1000 train / 10000 test points.
//! Outlier: same, plus an extra `U(-20, 20)` on 20 designated training
//! points — the robustness stressor that destabilizes the min-k and
//! selective-backprop baselines in Fig 1 (right).

use super::dataset::{InMemoryDataset, Targets};
use super::rng::Rng;

/// Configuration for the Fig 1 generator. Defaults match the paper.
#[derive(Clone, Debug)]
pub struct RegressionSpec {
    pub n_train: usize,
    pub n_test: usize,
    /// Ground-truth slope/intercept (`y = slope·x + intercept + noise`).
    pub slope: f32,
    pub intercept: f32,
    /// Observation noise `U(-noise, noise)`.
    pub noise: f32,
    /// Number of outlier points in the *training* split.
    pub n_outliers: usize,
    /// Outlier perturbation `U(-outlier_mag, outlier_mag)`.
    pub outlier_mag: f32,
    /// Covariate range `x ~ U(-x_range, x_range)`.
    pub x_range: f32,
}

impl Default for RegressionSpec {
    fn default() -> Self {
        RegressionSpec {
            n_train: 1000,
            n_test: 10000,
            slope: 2.0,
            intercept: 1.0,
            noise: 5.0,
            n_outliers: 0,
            outlier_mag: 20.0,
            x_range: 10.0,
        }
    }
}

impl RegressionSpec {
    /// The paper's outlier variant: 20 points get `+U(-20, 20)`.
    pub fn with_outliers() -> Self {
        RegressionSpec { n_outliers: 20, ..Default::default() }
    }

    fn generate(&self, n: usize, n_outliers: usize, rng: &mut Rng) -> InMemoryDataset {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x = rng.uniform_in(-self.x_range as f64, self.x_range as f64) as f32;
            let eps = rng.uniform_in(-self.noise as f64, self.noise as f64) as f32;
            xs.push(x);
            ys.push(self.slope * x + self.intercept + eps);
        }
        if n_outliers > 0 {
            let idx = rng.choose_k(n, n_outliers.min(n));
            for i in idx {
                ys[i] += rng.uniform_in(-self.outlier_mag as f64, self.outlier_mag as f64) as f32;
            }
        }
        InMemoryDataset::new(vec![1], xs, Targets::F32(ys))
            .expect("generator produces consistent shapes")
    }

    /// Generate the (train, test) splits. Outliers only contaminate the
    /// training split, matching the paper's setup.
    pub fn build(&self, seed: u64) -> (InMemoryDataset, InMemoryDataset) {
        let mut rng = Rng::seed_from(seed);
        let mut train_rng = rng.split();
        let mut test_rng = rng.split();
        let train = self.generate(self.n_train, self.n_outliers, &mut train_rng);
        let test = self.generate(self.n_test, 0, &mut test_rng);
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_paper() {
        let (tr, te) = RegressionSpec::default().build(0);
        assert_eq!(tr.len(), 1000);
        assert_eq!(te.len(), 10000);
        assert_eq!(tr.x_shape, vec![1]);
    }

    #[test]
    fn clean_data_fits_line_within_noise() {
        let (tr, _) = RegressionSpec::default().build(1);
        if let Targets::F32(ys) = &tr.ys {
            for (x, y) in tr.xs.iter().zip(ys) {
                let resid = y - (2.0 * x + 1.0);
                assert!(resid.abs() <= 5.0 + 1e-4, "residual {resid}");
            }
        } else {
            panic!("regression targets must be f32");
        }
    }

    #[test]
    fn outlier_variant_has_large_residuals() {
        let (tr, _) = RegressionSpec::with_outliers().build(2);
        if let Targets::F32(ys) = &tr.ys {
            let big = tr
                .xs
                .iter()
                .zip(ys)
                .filter(|(x, y)| (*y - (2.0 * *x + 1.0)).abs() > 5.0 + 1e-4)
                .count();
            assert!(big > 0 && big <= 20, "expected ≤20 contaminated points, got {big}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = RegressionSpec::default().build(3);
        let (b, _) = RegressionSpec::default().build(3);
        assert_eq!(a.xs, b.xs);
        let (c, _) = RegressionSpec::default().build(4);
        assert_ne!(a.xs, c.xs);
    }
}
