//! Batch sharding for the leader/worker data-parallel runtime.
//!
//! The paper trains sync data-parallel on 32 GPUs: the global batch is
//! split across workers, each worker runs forward (and later backward)
//! on its shard, and the leader owns selection + the parameter update.
//! [`shard_batch`] produces per-worker sub-batches whose row ranges are
//! recorded so per-example losses can be scattered back into global
//! batch order.

use anyhow::{bail, Result};

use super::dataset::Batch;
use super::tensor::{HostTensor, TensorData};

/// One worker's shard: the rows `range` of the global batch, padded back
/// up to the full batch size the executables were compiled for.
#[derive(Clone, Debug)]
pub struct Shard {
    pub batch: Batch,
    /// Global-batch row range covered by this shard.
    pub start: usize,
    pub len: usize,
}

fn slice_rows(t: &HostTensor, start: usize, len: usize, total_rows: usize) -> HostTensor {
    let stride = t.element_count() / total_rows;
    let mut shape = t.shape.clone();
    shape[0] = total_rows; // shards keep the compiled batch size
    match &t.data {
        TensorData::F32(v) => {
            let mut out = vec![0.0f32; total_rows * stride];
            out[..len * stride].copy_from_slice(&v[start * stride..(start + len) * stride]);
            HostTensor { shape, data: TensorData::F32(out) }
        }
        TensorData::I32(v) => {
            let mut out = vec![0i32; total_rows * stride];
            out[..len * stride].copy_from_slice(&v[start * stride..(start + len) * stride]);
            HostTensor { shape, data: TensorData::I32(out) }
        }
        TensorData::Bf16(_) => unreachable!("bf16 tensors are wire-only; batches are f32/i32"),
    }
}

/// Split a global batch into `workers` shards. Each shard is padded to
/// the full compiled batch size; `valid_mask` masks the padding. Rows are
/// dealt contiguously (worker w gets `[w·ceil, …)`), and empty shards are
/// allowed when `workers > rows` (their masks are all-zero).
pub fn shard_batch(b: &Batch, workers: usize) -> Result<Vec<Shard>> {
    if workers == 0 {
        bail!("workers must be > 0");
    }
    let n = b.batch_size();
    let per = n.div_ceil(workers);
    let mut out = Vec::with_capacity(workers);
    for w in 0..workers {
        let start = (w * per).min(n);
        let end = ((w + 1) * per).min(n);
        let len = end - start;
        let x = slice_rows(&b.x, start, len, n);
        let y = slice_rows(&b.y, start, len, n);
        let mut valid = vec![0.0f32; n];
        valid[..len].copy_from_slice(&b.valid_mask[start..end]);
        let mut ids = vec![usize::MAX; n];
        ids[..len].copy_from_slice(&b.ids[start..end]);
        let real = valid.iter().filter(|&&m| m > 0.0).count();
        out.push(Shard {
            batch: Batch { x, y, valid_mask: valid, real, ids },
            start,
            len,
        });
    }
    Ok(out)
}

/// Scatter per-shard loss vectors back into global batch order.
pub fn gather_losses(shards: &[Shard], per_shard: &[Vec<f32>], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for (s, losses) in shards.iter().zip(per_shard) {
        out[s.start..s.start + s.len].copy_from_slice(&losses[..s.len]);
    }
    out
}

/// Restrict a global 0/1 selection mask to one shard's local row space.
pub fn shard_mask(shard: &Shard, global_mask: &[f32]) -> Vec<f32> {
    let n = shard.batch.batch_size();
    let mut local = vec![0.0f32; n];
    local[..shard.len]
        .copy_from_slice(&global_mask[shard.start..shard.start + shard.len]);
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{InMemoryDataset, Targets};

    fn batch(n: usize) -> Batch {
        let ds = InMemoryDataset::new(
            vec![2],
            (0..n * 2).map(|i| i as f32).collect(),
            Targets::I32((0..n as i32).collect()),
        )
        .unwrap();
        ds.gather_batch(&(0..n).collect::<Vec<_>>(), n).unwrap()
    }

    #[test]
    fn shards_cover_batch_disjointly() {
        let b = batch(8);
        let shards = shard_batch(&b, 3).unwrap();
        assert_eq!(shards.len(), 3);
        let total: usize = shards.iter().map(|s| s.len).sum();
        assert_eq!(total, 8);
        assert_eq!(shards[0].start, 0);
        assert_eq!(shards[1].start, 3);
        assert_eq!(shards[2].start, 6);
        assert_eq!(shards[2].len, 2);
        // shard rows keep the compiled batch size with padding masked out
        for s in &shards {
            assert_eq!(s.batch.batch_size(), 8);
            assert_eq!(s.batch.real, s.len);
        }
    }

    #[test]
    fn shard_content_matches_rows() {
        let b = batch(6);
        let shards = shard_batch(&b, 2).unwrap();
        let x1 = shards[1].batch.x.as_f32().unwrap();
        // rows 3..6 of global batch: features 6..12
        assert_eq!(&x1[..6], &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert!(x1[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gather_losses_restores_global_order() {
        let b = batch(7);
        let shards = shard_batch(&b, 3).unwrap();
        let per: Vec<Vec<f32>> = shards
            .iter()
            .map(|s| {
                (0..s.batch.batch_size())
                    .map(|i| {
                        if i < s.len {
                            (s.start + i) as f32
                        } else {
                            999.0
                        }
                    })
                    .collect()
            })
            .collect();
        let got = gather_losses(&shards, &per, 7);
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shard_mask_localizes() {
        let b = batch(6);
        let shards = shard_batch(&b, 2).unwrap();
        let global = vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0];
        assert_eq!(shard_mask(&shards[0], &global)[..3], [1.0, 0.0, 1.0]);
        assert_eq!(shard_mask(&shards[1], &global)[..3], [0.0, 1.0, 1.0]);
        assert!(shard_mask(&shards[1], &global)[3..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn more_workers_than_rows() {
        let b = batch(2);
        let shards = shard_batch(&b, 4).unwrap();
        assert_eq!(shards.iter().map(|s| s.len).sum::<usize>(), 2);
        assert!(shards[2].len == 0 && shards[3].len == 0);
        assert!(shard_batch(&b, 0).is_err());
    }
}
