//! Host-side tensors: the interchange type between the L3 coordinator
//! and the execution backends.
//!
//! `HostTensor` is the plain-`Vec` representation that flows through
//! channels between the leader and worker threads and across the
//! [`crate::runtime::Backend`] boundary. The native backend computes on
//! it directly; the PJRT backend converts to/from `xla::Literal` (whose
//! handles are `Rc`-backed and cannot cross threads) in
//! `runtime::pjrt`.

use anyhow::{bail, Context, Result};

/// f32 → bf16 with round-to-nearest-even. NaN is quieted (top mantissa
/// bit forced) so it cannot round to infinity; ±Inf survives exactly.
/// Canonical scalar conversion — the SIMD scoring kernels re-export it,
/// so packed operands and wire snapshots round identically everywhere.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bf16 → f32 (exact: bf16 is the top half of the f32 bit pattern).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Element storage for a host tensor (models use f32 data, i32 labels;
/// bf16 exists only as a half-width wire form for param broadcasts —
/// backends never compute on it directly).
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Bf16(Vec<u16>),
}

/// A dense host tensor with row-major layout.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::I32(data) })
    }

    pub fn bf16(shape: Vec<usize>, data: Vec<u16>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::Bf16(data) })
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, TensorData::F32(_))
    }

    /// Borrow as f32 slice; errors on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
            TensorData::Bf16(_) => bail!("tensor is bf16, expected f32 (expand first)"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
            TensorData::Bf16(_) => bail!("tensor is bf16, expected i32"),
        }
    }

    /// Expand a bf16 wire tensor to exact f32 (the receiving worker's
    /// side of a half-width param broadcast); f32/i32 tensors pass
    /// through unchanged.
    pub fn expand_to_f32(&self) -> HostTensor {
        match &self.data {
            TensorData::Bf16(v) => HostTensor {
                shape: self.shape.clone(),
                data: TensorData::F32(v.iter().map(|&b| bf16_to_f32(b)).collect()),
            },
            _ => self.clone(),
        }
    }

    /// Scalar f32 value (shape [] or [1]).
    pub fn scalar_value(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// Per-element width in bytes (f32/i32: 4, bf16: 2).
    pub fn elem_bytes(&self) -> usize {
        match self.data {
            TensorData::F32(_) | TensorData::I32(_) => 4,
            TensorData::Bf16(_) => 2,
        }
    }

    /// Size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.elem_bytes()
    }

    // -- wire serialization (little-endian, see coordinator::proto) ------

    /// Append the wire encoding to `buf`: `dtype u8, ndim u8, dims u64…,
    /// raw element bytes`. Bit-exact for f32 (NaNs and signed zeros
    /// survive the roundtrip), so weight snapshots shipped across a
    /// process boundary stay bit-identical.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        debug_assert!(self.shape.len() <= u8::MAX as usize);
        buf.push(match self.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
            TensorData::Bf16(_) => 2u8,
        });
        buf.push(self.shape.len() as u8);
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match &self.data {
            TensorData::F32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::Bf16(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Append the wire encoding of an f32 tensor *rounded to bf16*
    /// (RNE via [`f32_to_bf16`]): dtype tag 2, same header, 2-byte
    /// elements. The half-width leader-side encode of a
    /// `param_precision = bf16` broadcast — non-f32 tensors encode
    /// unchanged. Decodes as a [`TensorData::Bf16`] tensor, so
    /// re-encoding is byte-identical.
    pub fn encode_as_bf16_into(&self, buf: &mut Vec<u8>) {
        let TensorData::F32(v) = &self.data else {
            return self.encode_into(buf);
        };
        debug_assert!(self.shape.len() <= u8::MAX as usize);
        buf.push(2u8);
        buf.push(self.shape.len() as u8);
        for &d in &self.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in v {
            buf.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
        }
    }

    /// Wire encoding as an owned buffer ([`HostTensor::encode_into`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(2 + self.shape.len() * 8 + self.size_bytes());
        self.encode_into(&mut buf);
        buf
    }

    /// Decode one tensor from the front of `b`; returns the tensor and
    /// the number of bytes consumed. Rejects truncated or inconsistent
    /// encodings (the element payload is bounded by the bytes actually
    /// present, so a corrupt length cannot trigger a huge allocation).
    pub fn decode_from(b: &[u8]) -> Result<(HostTensor, usize)> {
        if b.len() < 2 {
            bail!("tensor header truncated ({} bytes)", b.len());
        }
        let dtype = b[0];
        let esize = match dtype {
            0 | 1 => 4usize,
            2 => 2usize,
            other => bail!("unknown tensor dtype tag {other}"),
        };
        let ndim = b[1] as usize;
        let mut pos = 2usize;
        let mut shape = Vec::with_capacity(ndim);
        for d in 0..ndim {
            let Some(raw) = b.get(pos..pos + 8) else {
                bail!("tensor dims truncated (dim {d}/{ndim})");
            };
            let v = u64::from_le_bytes(raw.try_into().expect("8-byte slice"));
            if v > u32::MAX as u64 {
                bail!("tensor dim {v} implausibly large");
            }
            shape.push(v as usize);
            pos += 8;
        }
        let mut elems = 1usize;
        for &d in &shape {
            elems = elems
                .checked_mul(d)
                .filter(|n| n.checked_mul(esize).is_some())
                .ok_or_else(|| anyhow::anyhow!("tensor shape {shape:?} overflows"))?;
        }
        let Some(data) = b.get(pos..pos + elems * esize) else {
            bail!(
                "tensor data truncated: shape {shape:?} wants {} bytes, {} remain",
                elems * esize,
                b.len() - pos
            );
        };
        let t = match dtype {
            0 => HostTensor::f32(
                shape,
                data.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect(),
            )?,
            1 => HostTensor::i32(
                shape,
                data.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect(),
            )?,
            2 => HostTensor::bf16(
                shape,
                data.chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().expect("2-byte chunk")))
                    .collect(),
            )?,
            other => bail!("unknown tensor dtype tag {other}"),
        };
        Ok((t, pos + elems * esize))
    }

    /// Decode exactly one tensor spanning all of `b`.
    pub fn from_bytes(b: &[u8]) -> Result<HostTensor> {
        let (t, used) = Self::decode_from(b)?;
        if used != b.len() {
            bail!("{} trailing bytes after tensor", b.len() - used);
        }
        Ok(t)
    }
}

/// Encode a parameter list (e.g. a [`crate::runtime::Session`] weight
/// snapshot) as `count u64` + each tensor's wire form.
pub fn tensors_to_bytes(ts: &[HostTensor]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + ts.iter().map(|t| t.size_bytes() + 32).sum::<usize>());
    buf.extend_from_slice(&(ts.len() as u64).to_le_bytes());
    for t in ts {
        t.encode_into(&mut buf);
    }
    buf
}

/// Inverse of [`tensors_to_bytes`]; rejects truncation and trailing
/// garbage.
pub fn tensors_from_bytes(b: &[u8]) -> Result<Vec<HostTensor>> {
    let Some(raw) = b.get(..8) else {
        bail!("tensor list header truncated");
    };
    let count = u64::from_le_bytes(raw.try_into().expect("8-byte slice"));
    // each tensor needs at least its 2-byte header
    if count > (b.len() as u64) / 2 {
        bail!("tensor list claims {count} tensors in {} bytes", b.len());
    }
    let mut pos = 8usize;
    let mut out = Vec::with_capacity(count as usize);
    for i in 0..count {
        let (t, used) = HostTensor::decode_from(&b[pos..])
            .with_context(|| format!("tensor {i}/{count}"))?;
        pos += used;
        out.push(t);
    }
    if pos != b.len() {
        bail!("{} trailing bytes after tensor list", b.len() - pos);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_shape_check() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(4.5);
        assert_eq!(t.scalar_value().unwrap(), 4.5);
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn wire_roundtrip_preserves_bits() {
        let t = HostTensor::f32(
            vec![2, 3],
            vec![0.0, -0.0, f32::NAN, f32::INFINITY, -1.5e-30, 7.25],
        )
        .unwrap();
        let bytes = t.to_bytes();
        let back = HostTensor::from_bytes(&bytes).unwrap();
        assert_eq!(back.shape, t.shape);
        for (a, b) in t.as_f32().unwrap().iter().zip(back.as_f32().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let ti = HostTensor::i32(vec![3], vec![-1, 0, i32::MAX]).unwrap();
        assert_eq!(HostTensor::from_bytes(&ti.to_bytes()).unwrap(), ti);
        // scalar ([] shape) survives too
        let s = HostTensor::scalar_f32(4.5);
        assert_eq!(HostTensor::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn wire_rejects_truncation_and_garbage() {
        let t = HostTensor::f32(vec![4], vec![1.0; 4]).unwrap();
        let bytes = t.to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                HostTensor::from_bytes(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must not decode"
            );
        }
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(HostTensor::from_bytes(&trailing).is_err());
        let mut bad_dtype = bytes;
        bad_dtype[0] = 9;
        assert!(HostTensor::from_bytes(&bad_dtype).is_err());
    }

    #[test]
    fn tensor_list_roundtrip_and_rejection() {
        let ts = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
            HostTensor::i32(vec![1], vec![-7]).unwrap(),
            HostTensor::f32(vec![0], vec![]).unwrap(),
        ];
        let bytes = tensors_to_bytes(&ts);
        assert_eq!(tensors_from_bytes(&bytes).unwrap(), ts);
        assert_eq!(tensors_from_bytes(&tensors_to_bytes(&[])).unwrap(), vec![]);
        for cut in 0..bytes.len() {
            assert!(tensors_from_bytes(&bytes[..cut]).is_err());
        }
        // absurd count rejected before any allocation
        let mut huge = (u64::MAX).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0, 0]);
        assert!(tensors_from_bytes(&huge).is_err());
    }

    #[test]
    fn zeros_and_sizes() {
        let t = HostTensor::zeros_f32(vec![4, 4]);
        assert_eq!(t.element_count(), 16);
        assert_eq!(t.size_bytes(), 64);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bf16_conversion_rne_nan_and_inf() {
        // exactly representable values survive the round trip bit-for-bit
        for x in [0.0f32, -0.0, 1.0, -2.5, 0.125, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(
                bf16_to_f32(f32_to_bf16(x)).to_bits(),
                x.to_bits(),
                "{x} must convert exactly"
            );
        }
        // round-to-nearest-even on ties: 1 + 2^-8 (0x3F808000) is exactly
        // halfway between bf16 0x3F80 and 0x3F81 — the even mantissa wins —
        // while the next tie up (0x3F818000) rounds *up* to even 0x3F82.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
        // NaN stays NaN and is quieted (mantissa MSB set)
        let n = f32_to_bf16(f32::NAN);
        assert!(bf16_to_f32(n).is_nan());
        assert_ne!(n & 0x0040, 0);
        // a signalling-style NaN payload must not collapse to Inf
        let snan = f32::from_bits(0x7F80_0001);
        assert!(bf16_to_f32(f32_to_bf16(snan)).is_nan());
    }

    #[test]
    fn bf16_wire_roundtrip_and_sizes() {
        let raw: Vec<u16> = vec![0x3F80, 0x8000, 0x7FC0, 0xFF80, 0x0001];
        let t = HostTensor::bf16(vec![5], raw.clone()).unwrap();
        assert_eq!(t.size_bytes(), 10);
        assert!(HostTensor::bf16(vec![2], raw.clone()).is_err());
        let bytes = t.to_bytes();
        assert_eq!(bytes[0], 2, "bf16 wire dtype tag");
        let back = HostTensor::from_bytes(&bytes).unwrap();
        assert_eq!(back, t);
        // re-encode is byte-identical (decoded tensors keep dtype 2)
        assert_eq!(back.to_bytes(), bytes);
        for cut in 0..bytes.len() {
            assert!(
                HostTensor::from_bytes(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must not decode"
            );
        }
        // bf16 tensors refuse the f32 accessor until expanded
        assert!(t.as_f32().is_err());
        let exp = t.expand_to_f32();
        for (b, x) in raw.iter().zip(exp.as_f32().unwrap()) {
            assert_eq!(x.to_bits(), (*b as u32) << 16);
        }
    }

    #[test]
    fn encode_as_bf16_matches_elementwise_conversion() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, -3.7, f32::NAN, 1.0e-40]).unwrap();
        let mut buf = Vec::new();
        t.encode_as_bf16_into(&mut buf);
        let back = HostTensor::from_bytes(&buf).unwrap();
        assert_eq!(back.shape, t.shape);
        let TensorData::Bf16(got) = &back.data else {
            panic!("expected bf16 wire form");
        };
        let want: Vec<u16> = t.as_f32().unwrap().iter().map(|&x| f32_to_bf16(x)).collect();
        assert_eq!(got, &want);
        // non-f32 tensors pass through unchanged
        let ti = HostTensor::i32(vec![2], vec![5, -5]).unwrap();
        let mut bi = Vec::new();
        ti.encode_as_bf16_into(&mut bi);
        assert_eq!(bi, ti.to_bytes());
    }
}
