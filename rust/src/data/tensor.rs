//! Host-side tensors: the interchange type between the L3 coordinator
//! and the execution backends.
//!
//! `HostTensor` is the plain-`Vec` representation that flows through
//! channels between the leader and worker threads and across the
//! [`crate::runtime::Backend`] boundary. The native backend computes on
//! it directly; the PJRT backend converts to/from `xla::Literal` (whose
//! handles are `Rc`-backed and cannot cross threads) in
//! `runtime::pjrt`.

use anyhow::{bail, Result};

/// Element storage for a host tensor (models use f32 data, i32 labels).
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor with row-major layout.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::I32(data) })
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, TensorData::F32(_))
    }

    /// Borrow as f32 slice; errors on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar f32 value (shape [] or [1]).
    pub fn scalar_value(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// Size in bytes (all supported dtypes are 4 bytes).
    pub fn size_bytes(&self) -> usize {
        self.element_count() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_shape_check() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(4.5);
        assert_eq!(t.scalar_value().unwrap(), 4.5);
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn zeros_and_sizes() {
        let t = HostTensor::zeros_f32(vec![4, 4]);
        assert_eq!(t.element_count(), 16);
        assert_eq!(t.size_bytes(), 64);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
