//! In-memory datasets and fixed-size batch iteration.
//!
//! The AOT executables are compiled for a fixed batch size (the manifest's
//! `batch`); the final partial batch of an epoch is padded and its padding
//! rows masked out (`Batch::valid_mask`), so no data is dropped and eval
//! statistics stay exact.

use anyhow::{bail, Result};

use super::rng::Rng;
use super::tensor::HostTensor;

/// Targets: regression uses f32, classification uses i32 class ids.
#[derive(Clone, Debug, PartialEq)]
pub enum Targets {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Targets {
    pub fn len(&self) -> usize {
        match self {
            Targets::F32(v) => v.len(),
            Targets::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A dense, in-memory labelled dataset with fixed feature shape.
#[derive(Clone, Debug)]
pub struct InMemoryDataset {
    /// Per-example feature shape (without the leading batch dim).
    pub x_shape: Vec<usize>,
    /// Flattened features, `len = n * prod(x_shape)`.
    pub xs: Vec<f32>,
    pub ys: Targets,
}

impl InMemoryDataset {
    pub fn new(x_shape: Vec<usize>, xs: Vec<f32>, ys: Targets) -> Result<Self> {
        let stride: usize = x_shape.iter().product();
        if stride == 0 {
            bail!("x_shape must be non-empty and non-zero: {x_shape:?}");
        }
        if xs.len() % stride != 0 || xs.len() / stride != ys.len() {
            bail!(
                "inconsistent dataset: {} features / stride {} vs {} targets",
                xs.len(),
                stride,
                ys.len()
            );
        }
        Ok(InMemoryDataset { x_shape, xs, ys })
    }

    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn feature_stride(&self) -> usize {
        self.x_shape.iter().product()
    }

    /// Assemble a padded fixed-size batch from `indices` (may be fewer
    /// than `batch`; the remainder is zero-padded and masked out).
    pub fn gather_batch(&self, indices: &[usize], batch: usize) -> Result<Batch> {
        if indices.len() > batch {
            bail!("gather_batch: {} indices > batch {batch}", indices.len());
        }
        let stride = self.feature_stride();
        let mut xs = vec![0.0f32; batch * stride];
        for (row, &i) in indices.iter().enumerate() {
            if i >= self.len() {
                bail!("index {i} out of range (len {})", self.len());
            }
            xs[row * stride..(row + 1) * stride]
                .copy_from_slice(&self.xs[i * stride..(i + 1) * stride]);
        }
        let mut x_shape = vec![batch];
        x_shape.extend_from_slice(&self.x_shape);
        let x = HostTensor::f32(x_shape, xs)?;
        let y = match &self.ys {
            Targets::F32(v) => {
                let mut out = vec![0.0f32; batch];
                for (row, &i) in indices.iter().enumerate() {
                    out[row] = v[i];
                }
                HostTensor::f32(vec![batch], out)?
            }
            Targets::I32(v) => {
                let mut out = vec![0i32; batch];
                for (row, &i) in indices.iter().enumerate() {
                    out[row] = v[i];
                }
                HostTensor::i32(vec![batch], out)?
            }
        };
        let mut mask = vec![0.0f32; batch];
        for m in mask.iter_mut().take(indices.len()) {
            *m = 1.0;
        }
        let mut ids = vec![usize::MAX; batch];
        ids[..indices.len()].copy_from_slice(indices);
        Ok(Batch { x, y, valid_mask: mask, real: indices.len(), ids })
    }
}

/// A fixed-size batch ready for the PJRT executables.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: HostTensor,
    pub y: HostTensor,
    /// 1.0 for real rows, 0.0 for padding.
    pub valid_mask: Vec<f32>,
    /// Number of real (unpadded) rows.
    pub real: usize,
    /// Source-dataset index per row (`usize::MAX` for padding) — the
    /// stable example identity the loss cache keys on (the paper's
    /// "record a constant amount of information per instance").
    pub ids: Vec<usize>,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.valid_mask.len()
    }
}

/// Epoch iterator: shuffles indices (optionally) and yields padded
/// fixed-size batches covering the whole dataset.
pub struct BatchIter<'a> {
    ds: &'a InMemoryDataset,
    order: Vec<usize>,
    pos: usize,
    batch: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(ds: &'a InMemoryDataset, batch: usize, rng: Option<&mut Rng>) -> Self {
        assert!(batch > 0, "batch must be positive");
        let mut order: Vec<usize> = (0..ds.len()).collect();
        if let Some(r) = rng {
            r.shuffle(&mut order);
        }
        BatchIter { ds, order, pos: 0, batch }
    }

    pub fn num_batches(&self) -> usize {
        self.ds.len().div_ceil(self.batch)
    }
}

impl InMemoryDataset {
    /// Materialize the whole dataset as padded fixed-size batches in
    /// sequential order — the shared eval path (the serial trainer, the
    /// parallel trainer and the pipeline's async-eval stage all iterate
    /// this same deterministic cover).
    pub fn batches(&self, batch: usize) -> Vec<Batch> {
        BatchIter::new(self, batch, None).collect()
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let idx = &self.order[self.pos..end];
        self.pos = end;
        Some(
            self.ds
                .gather_batch(idx, self.batch)
                .expect("indices from internal order are valid"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> InMemoryDataset {
        let xs: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        let ys = Targets::I32((0..n as i32).collect());
        InMemoryDataset::new(vec![2], xs, ys).unwrap()
    }

    #[test]
    fn gather_pads_and_masks() {
        let ds = toy(5);
        let b = ds.gather_batch(&[0, 3], 4).unwrap();
        assert_eq!(b.real, 2);
        assert_eq!(b.valid_mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(b.x.as_f32().unwrap(), &[0.0, 1.0, 6.0, 7.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(b.y.as_i32().unwrap(), &[0, 3, 0, 0]);
    }

    #[test]
    fn gather_rejects_bad_index() {
        let ds = toy(3);
        assert!(ds.gather_batch(&[5], 4).is_err());
        assert!(ds.gather_batch(&[0, 1, 2], 2).is_err());
    }

    #[test]
    fn epoch_covers_every_example_once() {
        let ds = toy(10);
        let mut rng = Rng::seed_from(1);
        let it = BatchIter::new(&ds, 4, Some(&mut rng));
        assert_eq!(it.num_batches(), 3);
        let mut seen: Vec<i32> = vec![];
        for b in it {
            let ys = b.y.as_i32().unwrap();
            seen.extend_from_slice(&ys[..b.real]);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn batches_cover_sequentially_with_padding() {
        let ds = toy(6);
        let bs = ds.batches(4);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].real, 4);
        assert_eq!(bs[1].real, 2);
        assert_eq!(bs[1].y.as_i32().unwrap()[..2], [4, 5]);
    }

    #[test]
    fn unshuffled_is_sequential() {
        let ds = toy(6);
        let it = BatchIter::new(&ds, 4, None);
        let batches: Vec<Batch> = it.collect();
        assert_eq!(batches[0].y.as_i32().unwrap()[..4], [0, 1, 2, 3]);
        assert_eq!(batches[1].real, 2);
    }

    #[test]
    fn inconsistent_construction_rejected() {
        assert!(InMemoryDataset::new(vec![2], vec![0.0; 5], Targets::I32(vec![0, 1])).is_err());
        assert!(InMemoryDataset::new(vec![0], vec![], Targets::I32(vec![])).is_err());
    }
}
