//! MNIST-proxy generator (Fig 2 substitution — see DESIGN.md §3).
//!
//! We have no MNIST files in this environment; the selection methods
//! only consume the per-example **loss distribution**, so we synthesize
//! a 10-class, 784-feature dataset with the same phenomenology at the
//! same tensor shapes:
//!
//! * each class has a random dense template ("prototype digit");
//! * examples are `template + σ_class · noise`, with per-class σ spread
//!   so some classes stay hard longer (loss heterogeneity — what makes
//!   loss-aware selection matter);
//! * optional label noise injects outliers (mislabelled examples keep a
//!   persistently high loss, the failure mode of max-prob selection).

use super::dataset::{InMemoryDataset, Targets};
use super::rng::Rng;

pub const MNIST_DIM: usize = 784;
pub const MNIST_CLASSES: usize = 10;

/// Configuration for the MNIST-proxy generator.
#[derive(Clone, Debug)]
pub struct MnistProxySpec {
    pub n_train: usize,
    pub n_test: usize,
    /// Base observation noise; per-class σ is `noise · (0.6 + 0.15·class)`.
    pub noise: f32,
    /// Fraction of training labels flipped to a random other class.
    pub label_noise: f32,
    /// Template magnitude (separation between class means).
    pub template_scale: f32,
}

impl Default for MnistProxySpec {
    fn default() -> Self {
        MnistProxySpec {
            n_train: 8192,
            n_test: 2048,
            noise: 1.0,
            label_noise: 0.0,
            template_scale: 0.35,
        }
    }
}

impl MnistProxySpec {
    fn class_sigma(&self, class: usize) -> f32 {
        self.noise * (0.6 + 0.15 * class as f32)
    }

    fn templates(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..MNIST_CLASSES)
            .map(|_| {
                (0..MNIST_DIM)
                    .map(|_| self.template_scale * rng.normal() as f32)
                    .collect()
            })
            .collect()
    }

    fn generate(
        &self,
        n: usize,
        templates: &[Vec<f32>],
        label_noise: f32,
        rng: &mut Rng,
    ) -> InMemoryDataset {
        // Separate stream for flip decisions so the feature/class draws
        // stay identical between clean and noisy generations of the same
        // seed (label noise is then a pure label perturbation).
        let mut flip_rng = rng.split();
        let mut xs = Vec::with_capacity(n * MNIST_DIM);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(MNIST_CLASSES);
            let sigma = self.class_sigma(class);
            let t = &templates[class];
            for &tv in t.iter() {
                xs.push(tv + sigma * rng.normal() as f32);
            }
            let label = if label_noise > 0.0 && flip_rng.bernoulli(label_noise as f64) {
                // flip to a uniformly random *different* class
                let mut l = flip_rng.below(MNIST_CLASSES - 1);
                if l >= class {
                    l += 1;
                }
                l as i32
            } else {
                class as i32
            };
            ys.push(label);
        }
        InMemoryDataset::new(vec![MNIST_DIM], xs, Targets::I32(ys))
            .expect("generator produces consistent shapes")
    }

    /// Generate (train, test). Label noise only contaminates training.
    pub fn build(&self, seed: u64) -> (InMemoryDataset, InMemoryDataset) {
        let mut rng = Rng::seed_from(seed ^ 0x6d6e6973745f7078); // "mnist_px"
        let templates = self.templates(&mut rng);
        let mut train_rng = rng.split();
        let mut test_rng = rng.split();
        let train = self.generate(self.n_train, &templates, self.label_noise, &mut train_rng);
        let test = self.generate(self.n_test, &templates, 0.0, &mut test_rng);
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let spec = MnistProxySpec { n_train: 256, n_test: 64, ..Default::default() };
        let (tr, te) = spec.build(0);
        assert_eq!(tr.len(), 256);
        assert_eq!(te.len(), 64);
        assert_eq!(tr.x_shape, vec![MNIST_DIM]);
        if let Targets::I32(ys) = &tr.ys {
            assert!(ys.iter().all(|&y| (0..10).contains(&y)));
            // all 10 classes present with 256 draws (whp)
            let mut seen = [false; 10];
            for &y in ys {
                seen[y as usize] = true;
            }
            assert!(seen.iter().filter(|&&s| s).count() >= 8);
        }
    }

    #[test]
    fn label_noise_flips_some_labels() {
        let clean = MnistProxySpec { n_train: 512, n_test: 16, ..Default::default() };
        let noisy = MnistProxySpec { label_noise: 0.2, ..clean.clone() };
        let (a, _) = clean.build(7);
        let (b, _) = noisy.build(7);
        let (Targets::I32(ya), Targets::I32(yb)) = (&a.ys, &b.ys) else {
            panic!()
        };
        let flipped = ya.iter().zip(yb).filter(|(p, q)| p != q).count();
        // ~20% of 512 = ~102; allow wide tolerance
        assert!((50..200).contains(&flipped), "flipped={flipped}");
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = MnistProxySpec { n_train: 64, n_test: 16, ..Default::default() };
        let (a, _) = spec.build(5);
        let (b, _) = spec.build(5);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // nearest-template classification on clean data should beat chance
        let spec = MnistProxySpec {
            n_train: 200,
            n_test: 16,
            noise: 0.5,
            ..Default::default()
        };
        let (tr, _) = spec.build(1);
        // estimate per-class means from the data itself
        let Targets::I32(ys) = &tr.ys else { panic!() };
        let mut means = vec![vec![0.0f64; MNIST_DIM]; 10];
        let mut counts = [0usize; 10];
        for (i, &y) in ys.iter().enumerate() {
            counts[y as usize] += 1;
            for d in 0..MNIST_DIM {
                means[y as usize][d] += tr.xs[i * MNIST_DIM + d] as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            if c > 0 {
                for v in m.iter_mut() {
                    *v /= c as f64;
                }
            }
        }
        let mut correct = 0;
        for (i, &y) in ys.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d2: f64 = (0..MNIST_DIM)
                    .map(|d| {
                        let diff = tr.xs[i * MNIST_DIM + d] as f64 - m[d];
                        diff * diff
                    })
                    .sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ys.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc}");
    }
}
