//! Data substrate: deterministic RNG, host tensors, synthetic dataset
//! generators (the paper's three workloads), batching, sharding and the
//! streaming/prefetch pipeline.
//!
//! | paper workload | generator | DESIGN.md id |
//! |---|---|---|
//! | §4.1 linear regression (± outliers) | [`regression::RegressionSpec`] | fig1a/fig1b |
//! | §4.2 MNIST | [`mnist_proxy::MnistProxySpec`] | fig2 |
//! | §4.3 ImageNet | [`imagenet_proxy::ImagenetProxySpec`] | tab3 |

pub mod dataset;
pub mod imagenet_proxy;
pub mod mnist_proxy;
pub mod regression;
pub mod rng;
pub mod shard;
pub mod stream;
pub mod tensor;

pub use dataset::{Batch, BatchIter, InMemoryDataset, Targets};
pub use rng::Rng;
pub use tensor::{HostTensor, TensorData};

use anyhow::{bail, Result};

/// Build the (train, test) datasets named by a config string.
///
/// Recognized names: `regression`, `regression_outliers`, `mnist_proxy`,
/// `imagenet_proxy`. Sizes can be overridden by the caller afterwards by
/// regenerating with an explicit spec.
pub fn build_named(name: &str, seed: u64) -> Result<(InMemoryDataset, InMemoryDataset)> {
    match name {
        "regression" => Ok(regression::RegressionSpec::default().build(seed)),
        "regression_outliers" => Ok(regression::RegressionSpec::with_outliers().build(seed)),
        "mnist_proxy" => Ok(mnist_proxy::MnistProxySpec::default().build(seed)),
        "imagenet_proxy" => Ok(imagenet_proxy::ImagenetProxySpec::default().build(seed)),
        other => bail!(
            "unknown dataset {other:?}; expected regression | regression_outliers | \
             mnist_proxy | imagenet_proxy"
        ),
    }
}

/// The dataset conventionally paired with each model.
pub fn default_dataset_for(model: &str) -> &'static str {
    match model {
        "linreg" => "regression",
        "mlp" => "mnist_proxy",
        "cnn" | "cnn_lite" => "imagenet_proxy",
        _ => "mnist_proxy",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_named_all_variants() {
        for name in ["regression", "regression_outliers", "mnist_proxy", "imagenet_proxy"] {
            // Use tiny spec sizes by building through the specs directly
            // where large; here we just check dispatch works.
            if name.starts_with("regression") {
                let (tr, te) = build_named(name, 1).unwrap();
                assert!(tr.len() > 0 && te.len() > 0);
            }
        }
        assert!(build_named("cifar", 0).is_err());
    }

    #[test]
    fn default_pairings() {
        assert_eq!(default_dataset_for("linreg"), "regression");
        assert_eq!(default_dataset_for("mlp"), "mnist_proxy");
        assert_eq!(default_dataset_for("cnn"), "imagenet_proxy");
    }
}
