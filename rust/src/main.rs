//! `obftf` — launcher CLI for the One-Backward-from-Ten-Forward stack.
//!
//! Subcommands:
//!   train            run a training job from a TOML config + overrides
//!   eval             evaluate a checkpoint on a dataset's test split
//!   inspect          dump the artifact manifest / compiled-shape info
//!   config           print the effective (resolved) configuration
//!   bench-selection  micro-benchmark the selection policies off-line
//!   status           read the live status of a running streaming job
//!   worker           pipeline inference worker (spawned by the fleet
//!                    transport; speaks coordinator::proto frames over
//!                    stdin/stdout, or over a socket with --listen —
//!                    not for interactive use)
//!
//! Pipeline flags feed the typed `PipelineOverrides` layer, so the
//! resolution order is CLI > `OBFTF_*` env > config file > default
//! (see `config::options`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use obftf::config::{PipelineOptions, TrainConfig};
use obftf::coordinator::{ParallelTrainer, PipelineTrainer, StreamingTrainer, Trainer};
use obftf::data::rng::Rng;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::cli::{ArgParser, Parsed};

fn train_parser() -> ArgParser {
    with_train_flags(ArgParser::new("train", "run a training job"))
}

fn with_train_flags(p: ArgParser) -> ArgParser {
    p.flag("config", "TOML config file (flags override it)")
        .flag("model", "linreg | mlp | cnn | cnn_lite")
        .flag("flavour", "auto | native | pallas | jnp execution flavour")
        .flag("dataset", "regression[_outliers] | mnist_proxy | imagenet_proxy")
        .flag("method", "uniform | selective_backprop | mink | max_prob | obftf | obftf_prox | obftf_dp | frank_wolfe")
        .flag("ratio", "sampling ratio in [0,1]")
        .flag("epochs", "training epochs")
        .flag("lr", "learning rate")
        .flag("seed", "rng seed")
        .flag("workers", "data-parallel workers (1 = serial)")
        .flag("n-train", "training set size override")
        .flag("n-test", "test set size override")
        .flag("label-noise", "label noise fraction")
        .flag("checkpoint", "checkpoint path (written per epoch)")
        .flag("metrics-out", "per-step metrics CSV path")
        .flag("stream-steps", "streaming mode: number of stream steps")
        .flag("drift", "streaming concept-drift magnitude")
        .flag("status-addr", "bind a status endpoint (streaming mode)")
        .bool_flag("masked-backward", "use the masked full-batch backward (perf ablation)")
        .bool_flag("reuse-losses", "reuse cached per-instance losses (skip fwd when fresh)")
        .flag("loss-max-age", "loss cache max age in steps (0 = auto: two epochs' worth)")
        .bool_flag("pipeline", "streaming mode: run the staged pipeline (inference fleet + sharded cache + async eval)")
        .flag("pipeline-workers", "pipeline inference-fleet worker threads")
        .flag("pipeline-depth", "pipeline lookahead depth in batches")
        .flag("cache-shards", "sharded loss-cache stripes (0 = auto)")
        .bool_flag("pipeline-sync", "pipeline synchronous handoffs (bit-identical oracle mode)")
        .bool_flag(
            "pipeline-proc",
            "multi-process inference fleet (obftf worker children; implies --pipeline)",
        )
        .flag(
            "pipeline-socket",
            "fleet link: unix | tcp | none (implies --pipeline; none = stdio pipes)",
        )
        .flag(
            "pipeline-affinity",
            "true|false: route ScoreBatch to the majority shard owner (default true)",
        )
        .flag(
            "restart-limit",
            "supervised worker restarts allowed before a death is fatal (0 = fail-fast)",
        )
        .flag(
            "pipeline-min-workers",
            "fleet floor: retire (reshard) instead of abort while above it (default 1)",
        )
        .flag(
            "pipeline-join",
            "admit late fleet workers mid-run: \"step\" or \"step:count\"",
        )
        .flag(
            "cache-max-entries",
            "bound live loss-cache + journal entries, oldest-stamp eviction (0 = unbounded)",
        )
        .flag("proc-timeout-ms", "fleet spawn/connect/handshake/await bound (0 = 30 s)")
        .flag(
            "score-precision",
            "fleet scoring-forward precision: f32 | bf16 (bf16 = async pipeline only)",
        )
        .flag(
            "param-precision",
            "param-broadcast wire precision: f32 | bf16 (bf16 = async pipeline only)",
        )
        .bool_flag(
            "pipeline-overlap",
            "overlapped-step leader: lookup prefetch + parallel publish fan-out + async epilogue (async pipeline only)",
        )
}

fn build_config(p: &Parsed) -> Result<TrainConfig> {
    let mut cfg = match p.get("config") {
        Some(path) => TrainConfig::from_toml_file(Path::new(path))?,
        None => TrainConfig::default(),
    };
    if let Some(v) = p.get("model") {
        cfg.model = v.to_string();
    }
    if let Some(v) = p.get("flavour") {
        cfg.flavour = v.to_string();
    }
    if let Some(v) = p.get("dataset") {
        cfg.dataset = Some(v.to_string());
    }
    if let Some(v) = p.get("method") {
        cfg.method = v.parse()?;
    }
    if let Some(v) = p.get_parse::<f64>("ratio")? {
        cfg.sampling_ratio = v;
    }
    if let Some(v) = p.get_parse::<usize>("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = p.get_parse::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = p.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = p.get_parse::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = p.get_parse::<usize>("n-train")? {
        cfg.n_train = Some(v);
    }
    if let Some(v) = p.get_parse::<usize>("n-test")? {
        cfg.n_test = Some(v);
    }
    if let Some(v) = p.get_parse::<f32>("label-noise")? {
        cfg.label_noise = v;
    }
    if let Some(v) = p.get("checkpoint") {
        cfg.checkpoint = Some(v.to_string());
    }
    if let Some(v) = p.get("metrics-out") {
        cfg.metrics_out = Some(v.to_string());
    }
    if let Some(v) = p.get_parse::<usize>("stream-steps")? {
        cfg.stream_steps = v;
        if v > 0 {
            cfg.epochs = 0;
        }
    }
    if let Some(v) = p.get_parse::<f32>("drift")? {
        cfg.drift = v;
    }
    if let Some(v) = p.get("status-addr") {
        cfg.status_addr = Some(v.to_string());
    }
    if p.get_bool("masked-backward") {
        cfg.masked_backward = true;
    }
    if p.get_bool("reuse-losses") {
        cfg.reuse_losses = true;
    }
    if let Some(v) = p.get_parse::<u64>("loss-max-age")? {
        cfg.loss_max_age = v;
    }
    if p.get_bool("pipeline") {
        cfg.pipeline = true;
    }
    // pipeline shape flags feed the CLI-overrides layer (beats env and
    // config in PipelineOptions::resolve); the mirrored config fields
    // keep `validate` and `--print-effective` seeing the same values
    if let Some(v) = p.get_parse::<usize>("pipeline-workers")? {
        cfg.pipeline_workers = v;
        cfg.overrides.workers = Some(v);
    }
    if let Some(v) = p.get_parse::<usize>("pipeline-depth")? {
        cfg.pipeline_depth = v;
        cfg.overrides.depth = Some(v);
    }
    if let Some(v) = p.get_parse::<usize>("cache-shards")? {
        cfg.cache_shards = v;
        cfg.overrides.shards = Some(v);
    }
    if p.get_bool("pipeline-sync") {
        cfg.pipeline_sync = true;
        cfg.overrides.sync = Some(true);
    }
    if p.get_bool("pipeline-proc") {
        cfg.pipeline = true;
        cfg.pipeline_proc = true;
        cfg.overrides.proc = Some(true);
    }
    if let Some(v) = p.get("pipeline-socket") {
        cfg.pipeline = true;
        cfg.pipeline_socket = v.to_string();
        cfg.overrides.socket = Some(v.to_string());
    }
    if let Some(v) = p.get_bool_value("pipeline-affinity")? {
        cfg.pipeline_affinity = v;
        cfg.overrides.affinity = Some(v);
    }
    if let Some(v) = p.get_parse::<u32>("restart-limit")? {
        cfg.pipeline_restart_limit = v;
        cfg.overrides.restart_limit = Some(v);
    }
    if let Some(v) = p.get_parse::<usize>("pipeline-min-workers")? {
        cfg.pipeline_min_workers = v;
        cfg.overrides.min_workers = Some(v);
    }
    if let Some(v) = p.get("pipeline-join") {
        cfg.pipeline_join = v.to_string();
        cfg.overrides.join = Some(v.to_string());
    }
    if let Some(v) = p.get_parse::<u64>("cache-max-entries")? {
        cfg.cache_max_entries = v;
        cfg.overrides.cache_max_entries = Some(v);
    }
    if let Some(v) = p.get_parse::<u64>("proc-timeout-ms")? {
        cfg.proc_timeout_ms = v;
        cfg.overrides.timeout_ms = Some(v);
    }
    if let Some(v) = p.get("score-precision") {
        cfg.score_precision = v.to_string();
        cfg.overrides.score_precision = Some(v.to_string());
    }
    if let Some(v) = p.get("param-precision") {
        cfg.param_precision = v.to_string();
        cfg.overrides.param_precision = Some(v.to_string());
    }
    if p.get_bool("pipeline-overlap") {
        cfg.pipeline_overlap = true;
        cfg.overrides.overlap = Some(true);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `obftf config --print-effective` — dump the pipeline knobs exactly
/// as a run launched with the same config/env/flags would resolve them
/// (CLI > env > config > default).
fn cmd_config(args: &[String]) -> Result<()> {
    let parser = with_train_flags(
        ArgParser::new("config", "inspect the effective configuration").bool_flag(
            "print-effective",
            "print every pipeline knob after CLI > env > config > default resolution",
        ),
    );
    let p = parser.parse(args)?;
    if !p.get_bool("print-effective") {
        bail!("nothing to do — pass --print-effective\n\n{}", parser.usage());
    }
    let cfg = build_config(&p)?;
    println!("# effective configuration (CLI > env > config > default)");
    println!("model = {:?}", cfg.model);
    println!("flavour = {:?}", cfg.flavour);
    println!("dataset = {:?}", cfg.dataset_name());
    println!("method = {:?}", cfg.method.as_str());
    println!("pipeline = {}", cfg.pipeline);
    // kernel flavour resolves from the environment, not the TOML layer
    let kcfg = obftf::runtime::KernelConfig::from_env();
    println!("native_kernels = {}", kcfg.flavour.as_str());
    println!("cpu_features = {}", obftf::runtime::kernels::simd::cpu_features());
    // no dataset is materialised here, so the auto max-age window
    // (two epochs' worth of steps) cannot be sized yet
    let options = PipelineOptions::resolve(&cfg, 0, 0)?;
    for line in options.effective_lines(cfg.loss_max_age == 0) {
        println!("{line}");
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = train_parser().parse(args)?;
    let cfg = build_config(&p)?;
    eprintln!(
        "obftf train: model={} method={} ratio={} flavour={} workers={} dataset={}",
        cfg.model,
        cfg.method,
        cfg.sampling_ratio,
        cfg.flavour,
        cfg.workers,
        cfg.dataset_name()
    );
    let report = if cfg.stream_steps > 0 {
        match &cfg.status_addr {
            Some(addr) => {
                use obftf::coordinator::service::{serve, StatusBoard};
                let board = StatusBoard::new();
                let server = serve(board.clone(), addr)?;
                eprintln!("status endpoint: {}", server.addr);
                board.update(|s| {
                    s.model = cfg.model.clone();
                    s.method = cfg.method.as_str().to_string();
                });
                let report = if cfg.pipeline {
                    PipelineTrainer::from_config(&cfg)?.run_with_board(&board)?
                } else {
                    StreamingTrainer::from_config(&cfg)?.run_with_board(&board)?
                };
                board.update(|s| {
                    s.done = true;
                    s.step = report.steps;
                });
                report
            }
            None if cfg.pipeline => PipelineTrainer::from_config(&cfg)?.run()?,
            None => StreamingTrainer::from_config(&cfg)?.run()?,
        }
    } else if cfg.workers > 1 {
        ParallelTrainer::from_config(&cfg)?.run()?
    } else {
        Trainer::from_config(&cfg)?.run()?
    };
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let parser = ArgParser::new("eval", "evaluate a checkpoint")
        .flag("checkpoint", "checkpoint file to load (required)")
        .flag("model", "model name (default mlp)")
        .flag("flavour", "auto | native | pallas | jnp (default auto)")
        .flag("dataset", "dataset override")
        .flag("seed", "dataset generation seed");
    let p = parser.parse(args)?;
    let Some(ck) = p.get("checkpoint") else {
        bail!("--checkpoint is required\n\n{}", parser.usage());
    };
    let mut cfg = TrainConfig {
        model: p.get("model").unwrap_or("mlp").to_string(),
        flavour: p.get("flavour").unwrap_or("auto").to_string(),
        dataset: p.get("dataset").map(|s| s.to_string()),
        epochs: 1,
        ..Default::default()
    };
    if let Some(seed) = p.get_parse::<u64>("seed")? {
        cfg.seed = seed;
    }
    cfg.validate()?;
    let mut trainer = Trainer::from_config(&cfg)?;
    trainer.load_checkpoint(&PathBuf::from(ck))?;
    let ev = trainer.evaluate()?;
    println!("{{\"loss\": {}, \"metric\": {}}}", ev.loss, ev.metric);
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;
    println!("artifacts dir: {:?}", manifest.dir);
    println!("compiled batch size: {}", manifest.batch);
    println!("default flavour: {}", manifest.default_flavour());
    for (name, entry) in &manifest.models {
        let nparam: usize = entry
            .params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum();
        println!(
            "model {name}: task={} x_shape={:?} classes={} params={} ({} tensors) artifacts={}",
            entry.task,
            entry.x_shape,
            entry.num_classes,
            nparam,
            entry.params.len(),
            entry.executables.len()
        );
    }
    Ok(())
}

fn cmd_bench_selection(args: &[String]) -> Result<()> {
    use std::time::Instant;
    let parser = ArgParser::new("bench-selection", "micro-benchmark selection policies")
        .flag("n", "batch size (default 128)")
        .flag("ratio", "sampling ratio (default 0.25)")
        .flag("iters", "iterations per method (default 200)");
    let p = parser.parse(args)?;
    let n = p.get_parse::<usize>("n")?.unwrap_or(128);
    let ratio = p.get_parse::<f64>("ratio")?.unwrap_or(0.25);
    let iters = p.get_parse::<usize>("iters")?.unwrap_or(200);

    let mut rng = Rng::seed_from(7);
    let losses: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.8).exp() as f32).collect();
    let valid = vec![1.0f32; n];
    let b = obftf::sampling::budget_for(ratio, n);
    println!("n={n} b={b} iters={iters}");
    for m in Method::ALL {
        let mut sampler = m.build(1.0);
        let t0 = Instant::now();
        let mut selected_total = 0usize;
        for _ in 0..iters {
            selected_total += sampler.select(&losses, &valid, b, &mut rng).len();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{:<20} {:>10.1} µs/select  avg selected {:.1}",
            m.as_str(),
            per * 1e6,
            selected_total as f64 / iters as f64
        );
    }
    Ok(())
}

/// `obftf worker` — the multi-process pipeline's inference worker.
/// Speaks length-prefixed `coordinator::proto` frames over stdin/stdout
/// by default, or binds `--listen <unix:PATH | tcp:HOST:PORT>` and
/// serves one leader connection; all human-readable output goes to
/// stderr (socket mode also prints the `OBFTF_LISTEN` bootstrap line on
/// stdout).
fn cmd_worker(args: &[String]) -> Result<()> {
    let parser = ArgParser::new("worker", "pipeline inference worker (proto frames)")
        .flag("worker-id", "this worker's index in the fleet (required)")
        .flag("workers", "fleet size (required)")
        .flag("model", "model name (default mlp)")
        .flag("flavour", "auto | native | pallas | jnp (default auto)")
        .flag("capacity", "loss-cache capacity = training-set size (required)")
        .flag("max-age", "loss max age in steps (diagnostic; freshness is leader-side)")
        .flag("listen", "serve one leader over a socket: unix:PATH | tcp:HOST:PORT")
        .flag("score-precision", "scoring-forward precision: f32 | bf16 (default f32)")
        .bool_flag("join", "late joiner: announce Join and own nothing until resharded")
        .flag("fail-after", "TEST ONLY: crash after N frames (kill-a-worker regression)");
    let p = parser.parse(args)?;
    let need = |name: &str| -> Result<usize> {
        p.get_parse::<usize>(name)?
            .ok_or_else(|| anyhow::anyhow!("--{name} is required\n\n{}", parser.usage()))
    };
    let cfg = obftf::coordinator::WorkerConfig {
        worker_id: need("worker-id")?,
        n_workers: need("workers")?,
        model: p.get("model").unwrap_or("mlp").to_string(),
        flavour: p.get("flavour").unwrap_or("auto").to_string(),
        capacity: need("capacity")?,
        max_age: p.get_parse::<u64>("max-age")?.unwrap_or(0),
        score_precision: p.get("score-precision").unwrap_or("f32").to_string(),
        join: p.get_bool("join"),
        fail_after: p.get_parse::<u64>("fail-after")?,
    };
    if let Some(listen) = p.get("listen") {
        return obftf::coordinator::endpoint::serve_worker(&cfg, listen);
    }
    let stdin = std::io::stdin().lock();
    let stdout = std::io::BufWriter::new(std::io::stdout().lock());
    obftf::coordinator::ipc::run_worker(&cfg, stdin, stdout)
}

fn usage() -> String {
    "obftf — One Backward from Ten Forward (Dong et al. 2021)\n\n\
     USAGE:\n  obftf <SUBCOMMAND> [FLAGS]\n\n\
     SUBCOMMANDS:\n\
     \x20 train            run a training job (--help for flags)\n\
     \x20 eval             evaluate a checkpoint\n\
     \x20 inspect          dump the artifact manifest\n\
     \x20 config           print the effective configuration (--print-effective)\n\
     \x20 bench-selection  micro-benchmark the selection policies\n\
     \x20 status <addr>    read a running job's status endpoint\n\
     \x20 worker           pipeline inference worker (internal; stdio or --listen socket)\n"
        .to_string()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{}", usage());
        std::process::exit(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "inspect" => cmd_inspect(),
        "config" => cmd_config(rest),
        "bench-selection" => cmd_bench_selection(rest),
        "worker" => cmd_worker(rest),
        "status" => {
            let parser =
                ArgParser::new("status", "read a status endpoint").positional("addr", "host:port");
            let p = parser.parse(rest)?;
            let s = obftf::coordinator::service::read_status(p.positional(0).unwrap())?;
            println!("{}", s.to_json().to_string_pretty());
            Ok(())
        }
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{}", usage());
            std::process::exit(2);
        }
    }
}
