//! Frank–Wolfe solver over the continuous relaxation — the "fast and
//! accurate algorithms" the paper defers to future work (§3.3).
//!
//! Relaxation: minimize `f(z) = ((c·z)/b − t)²` over the capped simplex
//! `{0 ≤ z ≤ 1, Σz = b}`. The linear minimization oracle over that
//! polytope is simply "pick the b smallest (or largest) gradient
//! coordinates", and the exact line search for a quadratic is closed
//! form, so each iteration is `O(n log n)`. The fractional solution is
//! rounded to the top-b coordinates and repaired with local swaps.

use super::{local_swap, trivial, Selection, SubsetProblem, SubsetSolver};

/// Frank–Wolfe + rounding + swap repair.
#[derive(Clone, Copy, Debug)]
pub struct FrankWolfe {
    pub iters: usize,
    /// Local swap passes after rounding (0 = raw rounding).
    pub repair_passes: usize,
}

impl Default for FrankWolfe {
    fn default() -> Self {
        // repair_passes is the number of *single-swap* improvement steps
        // (see `local_swap`); rounding an FW vertex mixture typically
        // needs tens of swaps to close the last gap to the target mean.
        FrankWolfe { iters: 32, repair_passes: 64 }
    }
}

impl SubsetSolver for FrankWolfe {
    fn solve(&self, p: &SubsetProblem) -> Selection {
        if let Some(t) = trivial(p) {
            return t;
        }
        let n = p.losses.len();
        let b = p.budget;
        let c: Vec<f64> = p.losses.iter().map(|&v| v as f64).collect();

        // order by loss ascending; LMO vertices are prefixes/suffixes
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &q| c[a].partial_cmp(&c[q]).unwrap());

        // start: uniform fractional point z = b/n
        let mut z = vec![b as f64 / n as f64; n];
        let mut cz: f64 = c.iter().map(|ci| ci * b as f64 / n as f64).sum();

        for _ in 0..self.iters {
            let a = cz / b as f64 - p.target_mean;
            if a.abs() < 1e-15 {
                break;
            }
            // gradient ∝ a·c; LMO: minimize Σ grad_i s_i over capped simplex
            // → if a > 0 pick the b smallest c, else the b largest.
            let verts: Vec<usize> = if a > 0.0 {
                order[..b].to_vec()
            } else {
                order[n - b..].to_vec()
            };
            let cs: f64 = verts.iter().map(|&i| c[i]).sum();
            let d = (cs - cz) / b as f64;
            if d.abs() < 1e-18 {
                break;
            }
            // f(γ) = (a + γ d)² → γ* = −a/d clamped to [0, 1]
            let gamma = (-a / d).clamp(0.0, 1.0);
            if gamma <= 0.0 {
                break;
            }
            // z ← (1−γ)z + γ·vertex
            for zi in z.iter_mut() {
                *zi *= 1.0 - gamma;
            }
            for &i in &verts {
                z[i] += gamma;
            }
            cz = (1.0 - gamma) * cz + gamma * cs;
        }

        // round: take top-b fractional coordinates (stable on ties)
        let mut by_z: Vec<usize> = (0..n).collect();
        by_z.sort_by(|&a, &q| z[q].partial_cmp(&z[a]).unwrap().then(a.cmp(&q)));
        let rounded: Vec<usize> = by_z[..b].to_vec();

        if self.repair_passes > 0 {
            local_swap(p, rounded, self.repair_passes)
        } else {
            Selection::from_indices(p, rounded)
        }
    }

    fn name(&self) -> &'static str {
        "frank_wolfe"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::bnb::BranchBound;
    use crate::testkit::propcheck;

    #[test]
    fn near_exact_on_simple_instance() {
        let losses = [0.5, 1.5, 2.5, 3.5, 10.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        let s = FrankWolfe::default().solve(&p);
        assert!(s.objective < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn close_to_exact_on_batch_sized_instances() {
        let mut rng = Rng::seed_from(41);
        let mut worse = 0;
        for _ in 0..20 {
            let losses: Vec<f32> =
                (0..128).map(|_| rng.normal().abs() as f32).collect();
            let mean = losses.iter().sum::<f32>() as f64 / 128.0;
            let p = SubsetProblem::new(&losses, 32, mean).unwrap();
            let fw = FrankWolfe::default().solve(&p);
            let ex = BranchBound::default().solve(&p);
            if fw.objective > ex.objective + 1e-3 {
                worse += 1;
            }
        }
        // FW+repair should be within 1e-3 of exact on ≥ 80% of instances
        assert!(worse <= 4, "FW was far from exact on {worse}/20 instances");
    }

    #[test]
    fn extreme_targets_saturate_sensibly() {
        let losses = [1.0f32, 2.0, 3.0, 4.0];
        // target far above any achievable mean → picks the largest b values
        let p = SubsetProblem::new(&losses, 2, 100.0).unwrap();
        let s = FrankWolfe::default().solve(&p);
        assert_eq!(s.indices, vec![2, 3]);
        // far below → smallest
        let p = SubsetProblem::new(&losses, 2, -100.0).unwrap();
        let s = FrankWolfe::default().solve(&p);
        assert_eq!(s.indices, vec![0, 1]);
    }

    #[test]
    fn prop_valid_selection() {
        propcheck(
            "fw-valid-selection",
            48,
            |rng| {
                let n = 2 + rng.below(78);
                let losses: Vec<f32> =
                    (0..n).map(|_| (rng.uniform() * 10.0) as f32).collect();
                let b = rng.below(n + 1);
                let tfrac = rng.uniform_in(0.0, 2.0);
                (losses, b, tfrac)
            },
            |(losses, b, tfrac)| {
                let mean = losses.iter().sum::<f32>() as f64 / losses.len() as f64;
                let p = SubsetProblem::new(losses, *b, mean * tfrac).unwrap();
                let s = FrankWolfe::default().solve(&p);
                if s.indices.len() != *b {
                    return Err(format!("budget {} != {b}", s.indices.len()));
                }
                let mut u = s.indices.clone();
                u.dedup();
                if u.len() != *b {
                    return Err("duplicate indices".into());
                }
                Ok(())
            },
        );
    }
}
