//! Exact enumeration oracle: every C(n, b) subset.
//!
//! Exponential — only for tests and tiny instances (`n ≤ 24` guarded by
//! an assert). The proptest suite in `bnb.rs`/`dp.rs` validates the real
//! solvers against this oracle.

use super::{trivial, Selection, SubsetProblem, SubsetSolver};

/// Exhaustive subset enumeration (test oracle).
#[derive(Clone, Copy, Debug, Default)]
pub struct BruteForce;

impl SubsetSolver for BruteForce {
    fn solve(&self, p: &SubsetProblem) -> Selection {
        if let Some(t) = trivial(p) {
            return t;
        }
        let n = p.losses.len();
        assert!(n <= 24, "BruteForce is an oracle for n ≤ 24, got n = {n}");
        let b = p.budget;
        let target_sum = p.target_mean * b as f64;

        let mut best_err = f64::INFINITY;
        let mut best: u32 = 0;
        // iterate combinations via Gosper's hack over b-bit masks
        let mut mask: u32 = (1u32 << b) - 1;
        let limit: u32 = 1u32 << n;
        while mask < limit {
            let mut sum = 0.0f64;
            let mut m = mask;
            while m != 0 {
                let i = m.trailing_zeros() as usize;
                sum += p.losses[i] as f64;
                m &= m - 1;
            }
            let err = (sum - target_sum).abs();
            if err < best_err {
                best_err = err;
                best = mask;
            }
            // Gosper's hack: next mask with the same popcount
            let c = mask & mask.wrapping_neg();
            let r = mask + c;
            if r >= limit || c == 0 {
                break;
            }
            mask = (((r ^ mask) >> 2) / c) | r;
        }
        let indices: Vec<usize> = (0..n).filter(|&i| best >> i & 1 == 1).collect();
        Selection::from_indices(p, indices)
    }

    fn name(&self) -> &'static str {
        "brute"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_exact_subset() {
        let losses = [0.5, 1.5, 2.5, 3.5, 10.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        let s = BruteForce.solve(&p);
        assert!(s.objective < 1e-9);
        assert_eq!(s.indices, vec![1, 2]); // mean(1.5, 2.5) = 2.0
    }

    #[test]
    fn budget_one_picks_closest() {
        let losses = [0.1, 0.9, 2.0];
        let p = SubsetProblem::new(&losses, 1, 1.0).unwrap();
        let s = BruteForce.solve(&p);
        assert_eq!(s.indices, vec![1]);
    }

    #[test]
    fn full_and_empty_budget() {
        let losses = [1.0, 3.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        let s = BruteForce.solve(&p);
        assert_eq!(s.indices, vec![0, 1]);
        assert!(s.objective < 1e-9);
        let p0 = SubsetProblem::new(&losses, 0, 2.0).unwrap();
        assert!(BruteForce.solve(&p0).indices.is_empty());
    }

    #[test]
    fn b_equals_n_minus_one() {
        let losses = [1.0, 2.0, 3.0, 4.0];
        let p = SubsetProblem::new(&losses, 3, 2.0).unwrap();
        let s = BruteForce.solve(&p);
        assert_eq!(s.indices, vec![0, 1, 2]); // mean 2.0 exactly
        assert!(s.objective < 1e-9);
    }
}
