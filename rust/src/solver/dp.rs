//! ε-approximate dynamic program over a discretized loss grid.
//!
//! Losses are scaled onto an integer grid of `grid` buckets spanning
//! `[min_loss, max_loss]`; a cardinality-constrained subset-sum DP over
//! bitset rows (`feasible[count]` ⊆ {0..S}) then finds, for subset size
//! `b`, the achievable scaled sum closest to the scaled target. The
//! discretization error is at most `b · bucket_width / b = bucket_width`
//! on the subset *mean*, i.e. `(max−min)/grid` — deterministic, unlike
//! the node-budgeted branch-and-bound.
//!
//! Memory: `(b+1)` bitset rows of `b·grid` bits plus a `u32` choice
//! table for reconstruction; with the default `grid = 4096` and
//! `b ≤ 128` this stays under ~300 MiB worst case and ~17 MiB for the
//! paper's n = 128 batches. Runtime is `O(n · b · S / 64)` word ops.

use super::{local_swap, trivial, Selection, SubsetProblem, SubsetSolver};

/// DP solver with a configurable discretization grid.
#[derive(Clone, Copy, Debug)]
pub struct DpApprox {
    /// Number of grid buckets for the loss range (ε = range/grid).
    pub grid: usize,
    /// Post-process with a few local swap passes in continuous space to
    /// shave off the discretization error.
    pub polish: bool,
}

impl Default for DpApprox {
    fn default() -> Self {
        DpApprox { grid: 4096, polish: true }
    }
}

struct Bitset {
    words: Vec<u64>,
    bits: usize,
}

impl Bitset {
    fn new(bits: usize) -> Self {
        Bitset { words: vec![0; bits.div_ceil(64)], bits }
    }

    #[inline]
    fn get(&self, i: usize) -> bool {
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    #[inline]
    fn set(&mut self, i: usize) {
        self.words[i / 64] |= 1 << (i % 64);
    }

    /// `out = self << k`, clipped to `bits`.
    fn shifted_into(&self, k: usize, out: &mut Vec<u64>) {
        let nw = self.words.len();
        out.clear();
        out.resize(nw, 0);
        let wshift = k / 64;
        let bshift = k % 64;
        if bshift == 0 {
            for i in (wshift..nw).rev() {
                out[i] = self.words[i - wshift];
            }
        } else {
            for i in (wshift..nw).rev() {
                let lo = self.words[i - wshift] << bshift;
                let hi = if i > wshift {
                    self.words[i - wshift - 1] >> (64 - bshift)
                } else {
                    0
                };
                out[i] = lo | hi;
            }
        }
        // clip stray bits above `bits`
        let extra = nw * 64 - self.bits;
        if extra > 0 {
            let m = u64::MAX >> extra;
            if let Some(last) = out.last_mut() {
                *last &= m;
            }
        }
    }
}

impl SubsetSolver for DpApprox {
    fn solve(&self, p: &SubsetProblem) -> Selection {
        if let Some(t) = trivial(p) {
            return t;
        }
        let b = p.budget;

        let lo = p.losses.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        let hi = p.losses.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let range = (hi - lo).max(1e-12);
        // Clamp the grid so the scaled sum space `b·(grid-1)` stays near
        // 2^16: the DP is O(n·b·S/64) words with an O(b·S) u32 choice
        // table, and an unclamped 4096-grid at b=128 would mean a 270 MB
        // table and seconds of work. The coarser ε at large b is repaid
        // by the post-polish swap pass (perf log: EXPERIMENTS.md §Perf).
        let grid = self.grid.max(2).min(((1usize << 16) / b.max(1)).max(64));
        let scale = (grid - 1) as f64 / range;

        // scaled integer weights; max scaled sum
        let w: Vec<usize> = p
            .losses
            .iter()
            .map(|&c| ((c as f64 - lo) * scale).round() as usize)
            .collect();
        let smax = b * (grid - 1);

        // feasible[count] = bitset of reachable scaled sums with `count` items
        let mut feasible: Vec<Bitset> = (0..=b).map(|_| Bitset::new(smax + 1)).collect();
        feasible[0].set(0);
        // choice[count][sum] = item that reached (count, sum) first
        let mut choice: Vec<Vec<u32>> = (0..=b).map(|_| vec![u32::MAX; smax + 1]).collect();

        let mut shifted: Vec<u64> = Vec::new();
        for (item, &wi) in w.iter().enumerate() {
            let top = b.min(item + 1);
            for count in (1..=top).rev() {
                // new = feasible[count-1] << wi, minus already-feasible
                feasible[count - 1].shifted_into(wi, &mut shifted);
                let row = &mut feasible[count];
                for wd in 0..row.words.len() {
                    let added = shifted[wd] & !row.words[wd];
                    if added != 0 {
                        row.words[wd] |= added;
                        let mut bits = added;
                        while bits != 0 {
                            let bit = bits.trailing_zeros() as usize;
                            choice[count][wd * 64 + bit] = item as u32;
                            bits &= bits - 1;
                        }
                    }
                }
            }
        }

        // pick the feasible sum at count b closest to the scaled target
        let target_scaled = ((p.target_mean - lo) * b as f64 * scale).round() as i64;
        let mut best_sum = None;
        let mut best_d = i64::MAX;
        for s in 0..=smax {
            if feasible[b].get(s) {
                let d = (s as i64 - target_scaled).abs();
                if d < best_d {
                    best_d = d;
                    best_sum = Some(s);
                }
            }
        }
        let Some(mut s) = best_sum else {
            // can only happen if b > 0 and no subset exists — impossible
            // for b ≤ n; keep a defensive fallback.
            return local_swap(p, (0..b).collect(), 8);
        };

        // walk the choice chain back
        let mut indices = Vec::with_capacity(b);
        for count in (1..=b).rev() {
            let item = choice[count][s];
            debug_assert_ne!(item, u32::MAX, "broken DP chain");
            indices.push(item as usize);
            s -= w[item as usize];
        }
        debug_assert_eq!(s, 0);

        let sel = Selection::from_indices(p, indices);
        if self.polish {
            let polished = local_swap(p, sel.indices.clone(), 4);
            if polished.objective < sel.objective {
                return polished;
            }
        }
        sel
    }

    fn name(&self) -> &'static str {
        "dp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::brute::BruteForce;
    use crate::testkit::propcheck;

    #[test]
    fn bitset_shift() {
        let mut bs = Bitset::new(130);
        bs.set(0);
        bs.set(5);
        bs.set(64);
        let mut out = Vec::new();
        let check = |v: &Vec<u64>, i: usize| v[i / 64] >> (i % 64) & 1 == 1;
        bs.shifted_into(3, &mut out);
        assert!(check(&out, 3) && check(&out, 8) && check(&out, 67));
        assert!(!check(&out, 0) && !check(&out, 5) && !check(&out, 64));
        // shift by multiple of 64
        bs.shifted_into(64, &mut out);
        assert!(check(&out, 64) && check(&out, 69) && check(&out, 128));
    }

    #[test]
    fn exact_when_grid_resolves_values() {
        let losses = [0.0, 1.0, 2.0, 3.0, 4.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        let s = DpApprox::default().solve(&p);
        assert!(s.objective < 1e-9, "obj {}", s.objective);
        assert_eq!(s.indices.len(), 2);
    }

    #[test]
    fn identical_losses_degenerate_range() {
        let losses = [1.5f32; 16];
        let p = SubsetProblem::new(&losses, 5, 1.5).unwrap();
        let s = DpApprox::default().solve(&p);
        assert_eq!(s.indices.len(), 5);
        assert!(s.objective < 1e-6);
    }

    #[test]
    fn within_epsilon_of_oracle_on_random_instances() {
        let mut rng = Rng::seed_from(23);
        for _ in 0..40 {
            let n = 6 + rng.below(12);
            let b = 1 + rng.below(n - 1);
            let losses: Vec<f32> = (0..n).map(|_| (rng.uniform() * 4.0) as f32).collect();
            let mean = losses.iter().sum::<f32>() as f64 / n as f64;
            let p = SubsetProblem::new(&losses, b, mean).unwrap();
            let exact = BruteForce.solve(&p);
            let got = DpApprox { grid: 4096, polish: false }.solve(&p);
            let eps = 2.0 * 4.0 / 4095.0; // 2·range/grid on the mean (item+target rounding)
            assert!(
                got.objective <= exact.objective + eps + 1e-9,
                "dp {} vs oracle {} (eps {eps})",
                got.objective,
                exact.objective
            );
        }
    }

    #[test]
    fn large_instance_runs_fast_and_valid() {
        let mut rng = Rng::seed_from(31);
        let losses: Vec<f32> = (0..512).map(|_| rng.normal().abs() as f32).collect();
        let p = SubsetProblem::new(&losses, 128, 0.7).unwrap();
        let s = DpApprox::default().solve(&p);
        assert_eq!(s.indices.len(), 128);
        assert!(s.objective < 1e-2, "obj {}", s.objective);
    }

    #[test]
    fn prop_dp_epsilon_guarantee() {
        propcheck(
            "dp-epsilon",
            48,
            |rng| {
                let n = 4 + rng.below(10);
                let losses: Vec<f32> =
                    (0..n).map(|_| (rng.uniform() * 8.0) as f32).collect();
                let b = ((n as f64 * rng.uniform_in(0.1, 0.9)) as usize).clamp(1, n - 1);
                (losses, b)
            },
            |(losses, b)| {
                let n = losses.len();
                let mean = losses.iter().sum::<f32>() as f64 / n as f64;
                let p = SubsetProblem::new(losses, *b, mean).unwrap();
                let exact = BruteForce.solve(&p);
                let dp = DpApprox { grid: 2048, polish: false };
                let got = dp.solve(&p);
                let lo = losses.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
                let hi = losses.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
                let eps = 2.0 * (hi - lo).max(1e-12) / 2047.0 + 1e-7;
                if got.objective > exact.objective + eps {
                    return Err(format!(
                        "dp {} oracle {} eps {eps}",
                        got.objective, exact.objective
                    ));
                }
                if got.indices.len() != *b {
                    return Err(format!("budget {} != {b}", got.indices.len()));
                }
                Ok(())
            },
        );
    }
}
