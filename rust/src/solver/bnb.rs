//! Exact branch-and-bound solver for the sparse subset approximation
//! problem — the production replacement for the paper's CBC MIP call.
//!
//! Values are sorted ascending; at each node (`pos`, `taken`, partial
//! sum) the reachable sum interval is bounded by prefix sums (take the
//! `r` smallest vs `r` largest remaining values), giving an admissible
//! lower bound on the objective for pruning. The incumbent is seeded
//! with a [`local_swap`]-improved strided start so pruning bites
//! immediately; a node budget bounds worst-case latency (on budget
//! exhaustion the incumbent — already a high-quality heuristic answer —
//! is returned, flagged via [`BnbStats::exhausted`]).

use std::cell::Cell;

use super::{local_swap, trivial, Selection, SubsetProblem, SubsetSolver};

/// Exact branch-and-bound solver with a node budget.
#[derive(Clone, Copy, Debug)]
pub struct BranchBound {
    /// Maximum number of search nodes before falling back to the
    /// incumbent (default 200k ≈ well under a fwd_loss execution).
    pub node_budget: usize,
    /// Stop early once the objective is below this (absolute) tolerance.
    pub tolerance: f64,
}

impl Default for BranchBound {
    fn default() -> Self {
        BranchBound { node_budget: 200_000, tolerance: 1e-12 }
    }
}

/// Statistics from the last `solve` call (thread-local to keep the
/// `SubsetSolver` interface object-safe and `&self`).
#[derive(Clone, Copy, Debug, Default)]
pub struct BnbStats {
    pub nodes: usize,
    pub exhausted: bool,
}

thread_local! {
    static LAST_STATS: Cell<BnbStats> = Cell::new(BnbStats::default());
}

impl BranchBound {
    /// Stats for the most recent solve on this thread.
    pub fn last_stats() -> BnbStats {
        LAST_STATS.with(|s| s.get())
    }
}

struct Search<'a> {
    vals: &'a [f64],   // sorted ascending
    pre: &'a [f64],    // prefix sums, pre[i] = Σ vals[..i]
    b: usize,
    target_sum: f64,
    tolerance: f64,
    node_budget: usize,
    nodes: usize,
    best_err: f64,
    best: Vec<usize>,   // positions into `vals`
    current: Vec<usize>,
}

impl<'a> Search<'a> {
    /// Admissible bound on |sum − T| from (pos, taken, cur).
    fn bound(&self, pos: usize, taken: usize, cur: f64) -> f64 {
        let r = self.b - taken;
        let n = self.vals.len();
        debug_assert!(pos + r <= n);
        let lo = cur + (self.pre[pos + r] - self.pre[pos]);
        let hi = cur + (self.pre[n] - self.pre[n - r]);
        if self.target_sum < lo {
            lo - self.target_sum
        } else if self.target_sum > hi {
            self.target_sum - hi
        } else {
            0.0
        }
    }

    fn rec(&mut self, pos: usize, taken: usize, cur: f64) {
        if self.best_err <= self.tolerance || self.nodes >= self.node_budget {
            return;
        }
        self.nodes += 1;
        if taken == self.b {
            let err = (cur - self.target_sum).abs();
            if err < self.best_err {
                self.best_err = err;
                self.best = self.current.clone();
            }
            return;
        }
        let n = self.vals.len();
        let r = self.b - taken;
        if n - pos == r {
            // forced: take all remaining
            let mut sum = cur;
            for q in pos..n {
                self.current.push(q);
                sum += self.vals[q];
            }
            let err = (sum - self.target_sum).abs();
            if err < self.best_err {
                self.best_err = err;
                self.best = self.current.clone();
            }
            self.current.truncate(self.current.len() - r);
            return;
        }

        // child bounds decide exploration order (best-first locally)
        let take_bound = self.bound(pos + 1, taken + 1, cur + self.vals[pos]);
        let skip_bound = self.bound(pos + 1, taken, cur);
        let explore = |s: &mut Self, take_first: bool| {
            let order = if take_first { [true, false] } else { [false, true] };
            for take in order {
                if take {
                    if take_bound < s.best_err {
                        s.current.push(pos);
                        s.rec(pos + 1, taken + 1, cur + s.vals[pos]);
                        s.current.pop();
                    }
                } else if skip_bound < s.best_err {
                    s.rec(pos + 1, taken, cur);
                }
            }
        };
        explore(self, take_bound <= skip_bound);
    }
}

impl SubsetSolver for BranchBound {
    fn solve(&self, p: &SubsetProblem) -> Selection {
        if let Some(t) = trivial(p) {
            LAST_STATS.with(|s| s.set(BnbStats::default()));
            return t;
        }
        let n = p.losses.len();
        let b = p.budget;

        // sort positions by loss ascending
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &c| p.losses[a].partial_cmp(&p.losses[c]).unwrap());
        let vals: Vec<f64> = order.iter().map(|&i| p.losses[i] as f64).collect();
        let mut pre = vec![0.0f64; n + 1];
        for i in 0..n {
            pre[i + 1] = pre[i] + vals[i];
        }

        // incumbent: strided pick over sorted order, improved by swaps
        let stride = n as f64 / b as f64;
        let seed: Vec<usize> = (0..b)
            .map(|i| ((i as f64 + 0.5) * stride) as usize)
            .map(|q| order[q.min(n - 1)])
            .collect();
        let incumbent = local_swap(p, seed, 32);

        let mut search = Search {
            vals: &vals,
            pre: &pre,
            b,
            target_sum: p.target_mean * b as f64,
            tolerance: self.tolerance * b as f64, // bound works in sum space
            node_budget: self.node_budget,
            nodes: 0,
            best_err: incumbent.objective * b as f64,
            best: vec![],
            current: Vec::with_capacity(b),
        };
        search.rec(0, 0, 0.0);

        let exhausted = search.nodes >= self.node_budget;
        LAST_STATS.with(|s| s.set(BnbStats { nodes: search.nodes, exhausted }));

        if search.best.is_empty() {
            // incumbent was never beaten
            return incumbent;
        }
        let indices: Vec<usize> = search.best.iter().map(|&q| order[q]).collect();
        let sel = Selection::from_indices(p, indices);
        if sel.objective <= incumbent.objective {
            sel
        } else {
            incumbent
        }
    }

    fn name(&self) -> &'static str {
        "bnb"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::brute::BruteForce;
    use crate::testkit::propcheck;

    #[test]
    fn exact_on_simple_instance() {
        let losses = [0.5, 1.5, 2.5, 3.5, 10.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        let s = BranchBound::default().solve(&p);
        assert!(s.objective < 1e-9, "obj {}", s.objective);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Rng::seed_from(17);
        for trial in 0..60 {
            let n = 6 + rng.below(12);
            let b = 1 + rng.below(n - 1);
            let losses: Vec<f32> =
                (0..n).map(|_| (rng.uniform() * 5.0) as f32).collect();
            let mean = losses.iter().sum::<f32>() as f64 / n as f64;
            let target = mean * (0.6 + 0.8 * rng.uniform());
            let p = SubsetProblem::new(&losses, b, target).unwrap();
            let exact = BruteForce.solve(&p);
            let got = BranchBound::default().solve(&p);
            assert!(
                got.objective <= exact.objective + 1e-9,
                "trial {trial}: bnb {} > brute {}",
                got.objective,
                exact.objective
            );
        }
    }

    #[test]
    fn node_budget_falls_back_to_incumbent() {
        let mut rng = Rng::seed_from(3);
        let losses: Vec<f32> = (0..256).map(|_| rng.uniform() as f32).collect();
        let p = SubsetProblem::new(&losses, 64, 0.5).unwrap();
        let tight = BranchBound { node_budget: 50, tolerance: 0.0 };
        let s = tight.solve(&p);
        assert_eq!(s.indices.len(), 64);
        // incumbent quality: strided + swaps should already be good
        assert!(s.objective < 0.05, "objective {}", s.objective);
    }

    #[test]
    fn selection_has_exact_budget_and_unique_indices() {
        let mut rng = Rng::seed_from(5);
        let losses: Vec<f32> = (0..128).map(|_| (rng.normal().abs()) as f32).collect();
        let p = SubsetProblem::new(&losses, 32, 0.8).unwrap();
        let s = BranchBound::default().solve(&p);
        assert_eq!(s.indices.len(), 32);
        let mut u = s.indices.clone();
        u.dedup();
        assert_eq!(u.len(), 32);
        assert!(s.indices.iter().all(|&i| i < 128));
    }

    #[test]
    fn prop_matches_oracle() {
        propcheck(
            "bnb-matches-oracle",
            64,
            |rng| {
                let n = 4 + rng.below(10);
                let losses: Vec<f32> =
                    (0..n).map(|_| (rng.uniform() * 10.0) as f32).collect();
                let b = ((n as f64 * rng.uniform_in(0.1, 0.9)) as usize).clamp(1, n - 1);
                let mean = losses.iter().sum::<f32>() as f64 / n as f64;
                let target = mean * rng.uniform_in(0.2, 1.8);
                (losses, b, target)
            },
            |(losses, b, target)| {
                let p = SubsetProblem::new(losses, *b, *target).unwrap();
                let exact = BruteForce.solve(&p);
                let got = BranchBound::default().solve(&p);
                if got.objective > exact.objective + 1e-9 {
                    return Err(format!("bnb {} > oracle {}", got.objective, exact.objective));
                }
                if got.indices.len() != *b {
                    return Err(format!("budget {} != {b}", got.indices.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_budget_and_bounds_hold() {
        propcheck(
            "bnb-budget-bounds",
            64,
            |rng| {
                let n = 2 + rng.below(62);
                let losses: Vec<f32> =
                    (0..n).map(|_| rng.uniform_in(-5.0, 5.0) as f32).collect();
                let b = rng.below(n + 1);
                let target = rng.uniform_in(-6.0, 6.0);
                (losses, b, target)
            },
            |(losses, b, target)| {
                let p = SubsetProblem::new(losses, *b, *target).unwrap();
                let s = BranchBound::default().solve(&p);
                if s.indices.len() != *b {
                    return Err(format!("budget {} != {b}", s.indices.len()));
                }
                let mut u = s.indices.clone();
                u.dedup();
                if u.len() != *b {
                    return Err("duplicate indices".into());
                }
                if !s.indices.iter().all(|&i| i < losses.len()) {
                    return Err("index out of range".into());
                }
                Ok(())
            },
        );
    }
}
