//! Sparse subset approximation solvers (paper Eq. 6).
//!
//! Problem: given per-example losses `c[0..n]`, a budget `b` and a
//! target mean `t`, choose `z ⊆ {0..n}` with `|z| = b` minimizing
//!
//! ```text
//!     | (1/b) · Σ_{i∈z} c_i  −  t |
//! ```
//!
//! The paper solves this with OR-tools CBC per batch; CBC is not
//! available on the rust hot path, so this module implements the solver
//! stack from scratch:
//!
//! * [`brute::BruteForce`] — exact enumeration, test oracle (n ≤ ~24);
//! * [`bnb::BranchBound`] — exact branch-and-bound with prefix-sum
//!   bounds and a node budget (the production solver);
//! * [`dp::DpApprox`] — ε-approximate DP over a discretized loss grid
//!   (pseudo-polynomial, deterministic worst case);
//! * [`frank_wolfe::FrankWolfe`] — continuous relaxation + rounding +
//!   local swap repair (the paper's "future work" fast path);
//! * [`local_swap`] — greedy swap improver shared by the heuristics.

pub mod bnb;
pub mod brute;
pub mod dp;
pub mod frank_wolfe;

use anyhow::{bail, Result};

/// One subset-approximation instance.
#[derive(Clone, Copy, Debug)]
pub struct SubsetProblem<'a> {
    /// Per-example losses (must be finite).
    pub losses: &'a [f32],
    /// Subset size `b` (`0 ≤ b ≤ n`).
    pub budget: usize,
    /// Target mean (the paper uses a noised batch mean; see
    /// `sampling::obftf`).
    pub target_mean: f64,
}

impl<'a> SubsetProblem<'a> {
    pub fn new(losses: &'a [f32], budget: usize, target_mean: f64) -> Result<Self> {
        if budget > losses.len() {
            bail!("budget {budget} > n {}", losses.len());
        }
        if losses.iter().any(|l| !l.is_finite()) {
            bail!("losses must be finite");
        }
        Ok(SubsetProblem { losses, budget, target_mean })
    }

    /// `|mean(indices) − target|`; the quantity being minimized.
    pub fn objective(&self, indices: &[usize]) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        let sum: f64 = indices.iter().map(|&i| self.losses[i] as f64).sum();
        (sum / self.budget as f64 - self.target_mean).abs()
    }
}

/// A solver's answer: the chosen indices (sorted) and its objective.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub indices: Vec<usize>,
    pub objective: f64,
}

impl Selection {
    pub fn from_indices(p: &SubsetProblem, mut indices: Vec<usize>) -> Self {
        indices.sort_unstable();
        let objective = p.objective(&indices);
        Selection { indices, objective }
    }
}

/// Common interface across the solver stack.
pub trait SubsetSolver {
    fn solve(&self, p: &SubsetProblem) -> Selection;
    fn name(&self) -> &'static str;
}

/// Handle the `b == 0` / `b == n` trivial cases shared by all solvers.
pub(crate) fn trivial(p: &SubsetProblem) -> Option<Selection> {
    if p.budget == 0 {
        return Some(Selection { indices: vec![], objective: 0.0 });
    }
    if p.budget == p.losses.len() {
        return Some(Selection::from_indices(p, (0..p.losses.len()).collect()));
    }
    None
}

/// Greedy local search: repeatedly apply the best single swap
/// (selected ↔ unselected) that reduces the objective. With the
/// complement sorted by loss, the best partner for a needed delta is
/// found by binary search, so each pass is `O(n log n)`.
pub fn local_swap(p: &SubsetProblem, start: Vec<usize>, max_passes: usize) -> Selection {
    if let Some(t) = trivial(p) {
        return t;
    }
    let n = p.losses.len();
    let b = p.budget;
    let mut selected = vec![false; n];
    let mut indices = start;
    for &i in &indices {
        selected[i] = true;
    }
    let mut sum: f64 = indices.iter().map(|&i| p.losses[i] as f64).sum();
    let target_sum = p.target_mean * b as f64;

    // complement sorted by loss value for binary-search partner lookup
    for _pass in 0..max_passes {
        let mut comp: Vec<usize> = (0..n).filter(|&i| !selected[i]).collect();
        comp.sort_by(|&a, &c| p.losses[a].partial_cmp(&p.losses[c]).unwrap());
        let comp_vals: Vec<f64> = comp.iter().map(|&i| p.losses[i] as f64).collect();

        let mut best: Option<(usize, usize, f64)> = None; // (sel_pos, comp_pos, new_err)
        let cur_err = (sum - target_sum).abs();
        for (si, &i) in indices.iter().enumerate() {
            // ideal replacement value v* = losses[i] + (target_sum - sum)
            let ideal = p.losses[i] as f64 + (target_sum - sum);
            let pos = comp_vals.partition_point(|&v| v < ideal);
            for cand in [pos.wrapping_sub(1), pos] {
                if cand < comp.len() {
                    let new_sum = sum - p.losses[i] as f64 + comp_vals[cand];
                    let err = (new_sum - target_sum).abs();
                    if err + 1e-15 < best.map_or(cur_err, |(_, _, e)| e) {
                        best = Some((si, cand, err));
                    }
                }
            }
        }
        match best {
            Some((si, ci, _)) => {
                let old = indices[si];
                let new = comp[ci];
                selected[old] = false;
                selected[new] = true;
                sum += comp_vals[ci] - p.losses[old] as f64;
                indices[si] = new;
            }
            None => break,
        }
    }
    Selection::from_indices(p, indices)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_validation() {
        assert!(SubsetProblem::new(&[1.0, 2.0], 3, 0.0).is_err());
        assert!(SubsetProblem::new(&[1.0, f32::NAN], 1, 0.0).is_err());
        assert!(SubsetProblem::new(&[1.0, 2.0], 1, 1.5).is_ok());
    }

    #[test]
    fn objective_is_mean_distance() {
        let losses = [1.0, 2.0, 3.0, 4.0];
        let p = SubsetProblem::new(&losses, 2, 2.0).unwrap();
        assert_eq!(p.objective(&[0, 1]), 0.5); // mean 1.5
        assert_eq!(p.objective(&[1, 2]), 0.5); // mean 2.5
        assert_eq!(p.objective(&[0, 2]), 0.0); // mean 2.0
    }

    #[test]
    fn local_swap_improves_to_exact() {
        let losses = [1.0, 2.0, 3.0, 4.0, 5.0];
        let p = SubsetProblem::new(&losses, 2, 3.0).unwrap();
        // start from the worst pair {1, 2} (mean 1.5)
        let s = local_swap(&p, vec![0, 1], 10);
        assert!(s.objective < 1e-9, "objective {}", s.objective);
        assert_eq!(s.indices.len(), 2);
    }

    #[test]
    fn local_swap_trivial_budgets() {
        let losses = [1.0, 2.0];
        let p0 = SubsetProblem::new(&losses, 0, 1.0).unwrap();
        assert!(local_swap(&p0, vec![], 4).indices.is_empty());
        let p2 = SubsetProblem::new(&losses, 2, 1.0).unwrap();
        assert_eq!(local_swap(&p2, vec![0, 1], 4).indices, vec![0, 1]);
    }
}
