//! Minimal JSON substrate (no serde in the offline dependency set).
//!
//! Full parser for the JSON grammar (objects, arrays, strings with
//! escapes incl. `\uXXXX`, numbers, booleans, null) plus a pretty
//! serializer. Object key order is preserved (insertion order) so the
//! manifest round-trips stably.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic output; manifest consumers look up
    /// by key, never by position.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
        self
    }

    // -- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest-style validation.
    pub fn need(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u32::MAX as f64 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// `[1, 2, 3]` → `Vec<usize>` (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (the whole input must be one value).
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.pos)
            .copied()
            .with_context(|| format!("unexpected end of input at byte {}", self.pos))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)
                                .context("bad \\u escape")?;
                            self.pos += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.pos + 2..self.pos + 6],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c).context("bad surrogate pair")?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).context("bad codepoint")?
                            };
                            s.push(ch);
                        }
                        e => bail!("bad escape \\{:?}", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // re-assemble multi-byte utf8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk =
                            std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        let n: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "batch": 128,
            "models": {
                "mlp": {
                    "task": "classification",
                    "x_shape": [784],
                    "params": [{"name": "w1", "shape": [784, 256]}]
                }
            }
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.need("version").unwrap().as_usize().unwrap(), 1);
        let mlp = j.need("models").unwrap().need("mlp").unwrap();
        assert_eq!(mlp.need("task").unwrap().as_str().unwrap(), "classification");
        assert_eq!(
            mlp.need("x_shape").unwrap().as_usize_vec().unwrap(),
            vec![784]
        );
        let p0 = &mlp.need("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.need("shape").unwrap().as_usize_vec().unwrap(), vec![784, 256]);
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let doc = r#"{"a": [1, 2.5, -3], "b": "hi\nthere", "c": true, "d": null}"#;
        let j = parse(doc).unwrap();
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""tab\t quote\" backslash\\ unicodeé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "tab\t quote\" backslash\\ unicodeé");
        // surrogate pair (emoji)
        let j = parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn errors_are_rejected() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "01a", "\"unterminated",
            "{\"a\":1} extra", "[1 2]",
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-0.5e2").unwrap().as_f64().unwrap(), -50.0);
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(parse("1.5").unwrap().as_usize().is_err());
        assert!(parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("x", Json::Num(1.0))
            .set("y", Json::Arr(vec![Json::Str("a".into())]));
        let s = j.to_string_compact();
        assert_eq!(parse(&s).unwrap(), j);
    }
}
