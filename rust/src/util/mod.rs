//! From-scratch substrates the offline dependency set forces us to own
//! (DESIGN.md §3): JSON, a TOML subset, CLI parsing, and a bench
//! harness. Small, tested, and sufficient for this system's needs —
//! not general-purpose replacements.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod toml_min;
