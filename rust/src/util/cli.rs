//! Minimal CLI argument substrate (offline: no `clap`).
//!
//! `ArgSpec` describes the flags of one subcommand; [`ArgParser::parse`]
//! handles `--flag value`, `--flag=value`, boolean flags, required
//! positionals, `--help`, and unknown-flag errors with suggestions.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One flag's spec.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flags take no value.
    pub is_bool: bool,
}

/// A subcommand's argument parser.
#[derive(Clone, Debug, Default)]
pub struct ArgParser {
    pub command: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl ArgParser {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        ArgParser { command, about, flags: vec![], positionals: vec![] }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, is_bool: false });
        self
    }

    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, is_bool: true });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("obftf {} — {}\n\nUSAGE:\n  obftf {}", self.command, self.about, self.command);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [FLAGS]\n\nFLAGS:\n");
        for f in &self.flags {
            let arg = if f.is_bool {
                format!("--{}", f.name)
            } else {
                format!("--{} <v>", f.name)
            };
            s.push_str(&format!("  {arg:<24} {}\n", f.help));
        }
        for (p, h) in &self.positionals {
            s.push_str(&format!("  <{p}>{:<20} {h}\n", ""));
        }
        s
    }

    fn find(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Parse `args` (without the program/subcommand prefix).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut out = Parsed::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let Some(spec) = self.find(name) else {
                    let suggestion = self
                        .flags
                        .iter()
                        .map(|f| f.name)
                        .min_by_key(|cand| levenshtein(cand, name))
                        .map(|c| format!(" (did you mean --{c}?)"))
                        .unwrap_or_default();
                    bail!("unknown flag --{name}{suggestion}\n\n{}", self.usage());
                };
                if spec.is_bool {
                    if inline_val.is_some() {
                        bail!("--{name} takes no value");
                    }
                    out.bools.insert(name.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    out.values.insert(name.to_string(), val);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        if out.positionals.len() < self.positionals.len() {
            bail!(
                "missing positional <{}>\n\n{}",
                self.positionals[out.positionals.len()].0,
                self.usage()
            );
        }
        Ok(out)
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(String::as_str)
    }

    /// Tri-state boolean *value* flag (`--x true` / `--x=false`):
    /// `None` when absent, `Err` on anything that isn't a recognisable
    /// boolean. Used for knobs whose default is `true`, where a plain
    /// presence flag could only turn them on.
    pub fn get_bool_value(&self, name: &str) -> Result<Option<bool>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.trim().to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" | "on" => Ok(Some(true)),
                "0" | "false" | "no" | "off" => Ok(Some(false)),
                other => bail!("--{name}: expected a boolean, got {other:?}"),
            },
        }
    }
}

fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur[j + 1] = (prev[j + 1] + 1).min(cur[j] + 1).min(prev[j] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn parser() -> ArgParser {
        ArgParser::new("train", "run a job")
            .flag("model", "model name")
            .flag("ratio", "sampling ratio")
            .bool_flag("verbose", "log more")
    }

    #[test]
    fn parses_flags_and_values() {
        let p = parser().parse(&argv(&["--model", "mlp", "--ratio=0.25", "--verbose"])).unwrap();
        assert_eq!(p.get("model"), Some("mlp"));
        assert_eq!(p.get_parse::<f64>("ratio").unwrap(), Some(0.25));
        assert!(p.get_bool("verbose"));
        assert_eq!(p.get("missing"), None);
    }

    #[test]
    fn unknown_flag_suggests() {
        let err = parser().parse(&argv(&["--moodel", "x"])).unwrap_err().to_string();
        assert!(err.contains("did you mean --model"), "{err}");
    }

    #[test]
    fn missing_value_and_positionals() {
        assert!(parser().parse(&argv(&["--model"])).is_err());
        let p = ArgParser::new("status", "read status").positional("addr", "host:port");
        assert!(p.parse(&argv(&[])).is_err());
        let got = p.parse(&argv(&["127.0.0.1:9"])).unwrap();
        assert_eq!(got.positional(0), Some("127.0.0.1:9"));
    }

    #[test]
    fn help_contains_flags() {
        let err = parser().parse(&argv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("--model") && err.contains("--ratio"));
    }

    #[test]
    fn bad_parse_type_errors() {
        let p = parser().parse(&argv(&["--ratio", "abc"])).unwrap();
        assert!(p.get_parse::<f64>("ratio").is_err());
    }

    #[test]
    fn bool_value_flags_are_tri_state() {
        let p = parser().parse(&argv(&["--model", "off"])).unwrap();
        assert_eq!(p.get_bool_value("model").unwrap(), Some(false));
        assert_eq!(p.get_bool_value("ratio").unwrap(), None);
        let p = parser().parse(&argv(&["--model=TRUE"])).unwrap();
        assert_eq!(p.get_bool_value("model").unwrap(), Some(true));
        let p = parser().parse(&argv(&["--model", "maybe"])).unwrap();
        assert!(p.get_bool_value("model").is_err());
    }
}
