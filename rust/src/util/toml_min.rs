//! Minimal TOML substrate for config files (offline: no `toml` crate).
//!
//! Supports the subset our configs use: flat `key = value` lines,
//! `#` comments, basic strings, integers, floats, booleans. Unknown
//! syntax (tables, arrays, datetimes, multi-line strings) is rejected
//! loudly rather than mis-parsed.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed TOML scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// Parse a flat TOML document into key → value.
pub fn parse(input: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            bail!("line {}: tables are not supported (flat config only)", lineno + 1);
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty()
            || !key
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            bail!("line {}: invalid key {key:?}", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: value for {key:?}", lineno + 1))?;
        if out.insert(key.to_string(), val).is_some() {
            bail!("line {}: duplicate key {key:?}", lineno + 1);
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a string must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = text.strip_prefix('"') {
        let Some(end) = stripped.rfind('"') else {
            bail!("unterminated string");
        };
        if end != stripped.len() - 1 {
            bail!("trailing characters after string");
        }
        let body = &stripped[..end];
        let mut s = String::new();
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    other => bail!("bad escape \\{other:?}"),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(TomlValue::Str(s));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {text:?} (strings need quotes)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_config() {
        let doc = r#"
# a config
model = "mlp"          # inline comment
sampling_ratio = 0.25
epochs = 5
seed = 1_000
stream = false
path = "out#1.csv"
"#;
        let m = parse(doc).unwrap();
        assert_eq!(m["model"].as_str().unwrap(), "mlp");
        assert_eq!(m["sampling_ratio"].as_f64().unwrap(), 0.25);
        assert_eq!(m["epochs"].as_usize().unwrap(), 5);
        assert_eq!(m["seed"].as_u64().unwrap(), 1000);
        assert!(!m["stream"].as_bool().unwrap());
        assert_eq!(m["path"].as_str().unwrap(), "out#1.csv");
    }

    #[test]
    fn rejects_tables_and_junk() {
        assert!(parse("[section]\nx = 1").is_err());
        assert!(parse("just words").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = unquoted").is_err());
        assert!(parse("x = 1\nx = 2").is_err());
        assert!(parse("bad key = 1").is_err());
    }

    #[test]
    fn escapes_in_strings() {
        let m = parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(m["s"].as_str().unwrap(), "a\nb\t\"c\"");
    }

    #[test]
    fn numeric_coercions() {
        let m = parse("a = 2\nb = 2.5\nc = -3").unwrap();
        assert_eq!(m["a"].as_f64().unwrap(), 2.0);
        assert_eq!(m["b"].as_f32().unwrap(), 2.5);
        assert!(m["c"].as_usize().is_err());
        assert!(m["b"].as_usize().is_err());
    }
}
