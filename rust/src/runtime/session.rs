//! A single-threaded model session: input validation + dispatch onto a
//! [`Backend`] trait object.
//!
//! `Session` owns everything backend-independent — shape/dtype checks
//! against the manifest entry, parameter-arity checks, flavour
//! dispatch — so the coordinator, engine and trainers are written once
//! against this type and run unchanged on the native CPU backend or
//! the PJRT artifact backend (`pjrt` cargo feature).
//!
//! Backends may hold non-`Send` handles (PJRT's are `Rc`-backed); a
//! `Session` therefore lives on exactly one thread. Multi-worker
//! execution wraps one `Session` per worker thread (see
//! [`crate::runtime::engine`]).

use anyhow::{bail, Context, Result};

use super::backend::{Backend, ScorePrecision, SessionStats};
use super::manifest::{Flavour, Manifest, ModelEntry};
use super::native::NativeBackend;
use crate::data::tensor::{HostTensor, TensorData};

/// One model's validated executor handle.
pub struct Session {
    backend: Box<dyn Backend>,
    entry: ModelEntry,
    model_name: String,
    flavour: Flavour,
    batch: usize,
    /// Retained so the session can be re-materialized on another thread
    /// ([`Session::fork`] / the pipeline's inference + eval stages).
    manifest: Manifest,
}

impl Session {
    /// Build the backend for `model` at `flavour`.
    ///
    /// `Flavour::Native` needs no artifacts (the executables are built
    /// in); `Pallas`/`Jnp` compile the AOT HLO artifacts the manifest
    /// names, and require the `pjrt` cargo feature.
    pub fn new(manifest: &Manifest, model: &str, flavour: Flavour) -> Result<Session> {
        let entry = manifest.model(model)?.clone();
        let backend: Box<dyn Backend> = match flavour {
            Flavour::Native => Box::new(
                NativeBackend::new(model, &entry, manifest.batch)
                    .with_context(|| format!("building native backend for {model}"))?,
            ),
            Flavour::Pallas | Flavour::Jnp => pjrt_backend(manifest, model, flavour)?,
        };
        Ok(Session {
            backend,
            entry,
            model_name: model.to_string(),
            flavour,
            batch: manifest.batch,
            manifest: manifest.clone(),
        })
    }

    /// The manifest this session was built from (pipeline stages clone
    /// it to build sibling sessions on their own threads).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Copy the resident parameters to host — the weight snapshot the
    /// async-eval stage ships across threads (alias of
    /// [`Session::params_to_host`], named for intent).
    pub fn snapshot(&self) -> Result<Vec<HostTensor>> {
        self.params_to_host()
    }

    /// The weight snapshot in wire form (little-endian, bit-exact f32)
    /// — what a `ParamUpdate` frame carries to a multi-process
    /// inference worker.
    pub fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        Ok(crate::data::tensor::tensors_to_bytes(&self.snapshot()?))
    }

    /// Load parameters from [`Session::snapshot_bytes`] output
    /// (shape-checked against the manifest like
    /// [`Session::load_params`]).
    pub fn load_snapshot_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        self.load_params(&crate::data::tensor::tensors_from_bytes(bytes)?)
    }

    /// Build an independent session of the same model × flavour and, if
    /// this session holds parameters, load a snapshot of them into the
    /// clone. Sessions are single-threaded (backends may hold
    /// non-`Send` handles), so cross-thread cloning goes through
    /// `manifest()` + [`Session::new`] on the target thread instead.
    pub fn fork(&self) -> Result<Session> {
        let mut s = Session::new(&self.manifest, &self.model_name, self.flavour)?;
        if self.backend.n_resident_params() == self.entry.n_params() {
            s.load_params(&self.params_to_host()?)?;
        }
        Ok(s)
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    pub fn flavour(&self) -> Flavour {
        self.flavour
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn stats(&self) -> SessionStats {
        self.backend.stats()
    }

    /// Human-readable execution platform of the underlying backend.
    pub fn client_platform(&self) -> String {
        self.backend.platform_name()
    }

    /// Select the precision of subsequent [`Session::fwd_loss`] calls —
    /// the inference fleet's fast-scoring knob. Training and eval math
    /// is unaffected (always exact f32); backends without a
    /// reduced-precision path ignore this.
    pub fn set_score_precision(&mut self, precision: ScorePrecision) {
        self.backend.set_score_precision(precision);
    }

    /// Initialize parameters from `seed` (runs the `init` executable).
    pub fn init(&mut self, seed: i32) -> Result<()> {
        self.backend.init(seed)?;
        if self.backend.n_resident_params() != self.entry.n_params() {
            bail!(
                "init produced {} tensors, manifest declares {} params",
                self.backend.n_resident_params(),
                self.entry.n_params()
            );
        }
        Ok(())
    }

    fn check_ready(&self) -> Result<()> {
        if self.backend.n_resident_params() != self.entry.n_params() {
            bail!("session has no parameters; call init() or load_params() first");
        }
        Ok(())
    }

    fn check_mask(&self, mask: &[f32]) -> Result<()> {
        if mask.len() != self.batch {
            bail!("mask len {} != batch {}", mask.len(), self.batch);
        }
        Ok(())
    }

    fn check_batch_inputs(&self, x: &HostTensor, y: &HostTensor) -> Result<()> {
        let mut want = vec![self.batch];
        want.extend_from_slice(&self.entry.x_shape);
        if x.shape != want {
            bail!("x shape {:?} != expected {:?}", x.shape, want);
        }
        if y.shape != vec![self.batch] {
            bail!("y shape {:?} != expected [{}]", y.shape, self.batch);
        }
        let want_i32 = self.entry.y_dtype == "i32";
        if want_i32 != matches!(y.data, TensorData::I32(_)) {
            bail!("y dtype mismatch: model wants {}", self.entry.y_dtype);
        }
        Ok(())
    }

    /// "Ten forward": per-example losses for the whole batch.
    pub fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        self.backend.fwd_loss(x, y)
    }

    /// "One backward": masked train step; parameters update in place.
    /// Returns the selected-subset mean loss.
    pub fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        self.check_mask(mask)?;
        self.backend.train_step(x, y, mask, lr)
    }

    /// "One backward", gathered: run the backward only on the selected
    /// rows — O(|selection|) instead of O(batch), numerically
    /// equivalent to [`Session::train_step`] with the matching mask.
    pub fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        if selected.is_empty() {
            bail!("train_step_selected: empty selection");
        }
        for &i in selected {
            if i >= self.batch {
                bail!("selected index {i} out of range");
            }
        }
        self.backend.train_step_selected(x, y, selected, lr)
    }

    /// Gradients for a masked shard (the data-parallel worker path).
    /// Returns (grads, selected mean loss over this shard).
    pub fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        self.check_mask(mask)?;
        self.backend.grads(x, y, mask)
    }

    /// Apply externally averaged gradients (the leader path).
    pub fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        self.check_ready()?;
        if grads.len() != self.entry.n_params() {
            bail!("apply got {} grads, expected {}", grads.len(), self.entry.n_params());
        }
        self.backend.apply(grads, lr)
    }

    /// Masked eval sums: `(sum_loss, sum_metric, count)`.
    pub fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        self.check_mask(mask)?;
        self.backend.eval_batch(x, y, mask)
    }

    /// Copy the resident parameters to host (checkpointing / broadcast).
    pub fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.backend.params_to_host()
    }

    /// Replace the resident parameters from host tensors (shape-checked
    /// against the manifest).
    pub fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        if params.len() != self.entry.n_params() {
            bail!("load_params got {} tensors, expected {}", params.len(), self.entry.n_params());
        }
        for (t, spec) in params.iter().zip(&self.entry.params) {
            if t.shape != spec.shape {
                bail!("param {}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
        }
        self.backend.load_params(params)
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(manifest: &Manifest, model: &str, flavour: Flavour) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::new(manifest, model, flavour)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_manifest: &Manifest, _model: &str, flavour: Flavour) -> Result<Box<dyn Backend>> {
    bail!(
        "flavour {flavour} executes AOT artifacts and needs the `pjrt` cargo feature \
         (build with --features pjrt); the artifact-free default is flavour `native`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn native_session(model: &str) -> Session {
        let dir = TempDir::new("session").unwrap();
        let m = Manifest::native(dir.path());
        Session::new(&m, model, Flavour::Native).unwrap()
    }

    #[test]
    fn native_linreg_round_trip() {
        let mut s = native_session("linreg");
        assert_eq!(s.model_name(), "linreg");
        assert_eq!(s.flavour(), Flavour::Native);
        assert_eq!(s.client_platform(), "native-cpu");
        let n = s.batch();
        s.init(3).unwrap();
        let x = HostTensor::f32(vec![n, 1], vec![0.5; n]).unwrap();
        let y = HostTensor::f32(vec![n], vec![2.0; n]).unwrap();
        let losses = s.fwd_loss(&x, &y).unwrap();
        assert_eq!(losses.len(), n);
        let mask = vec![1.0f32; n];
        let before = s.params_to_host().unwrap();
        let loss = s.train_step(&x, &y, &mask, 0.01).unwrap();
        assert!(loss.is_finite());
        let after = s.params_to_host().unwrap();
        assert_ne!(before, after, "train_step must move parameters");
        let n0 = s.stats().executions;
        s.fwd_loss(&x, &y).unwrap();
        assert_eq!(s.stats().executions, n0 + 1);
    }

    #[test]
    fn fork_clones_weights_and_diverges_after() {
        let mut s = native_session("linreg");
        s.init(5).unwrap();
        let n = s.batch();
        let x = HostTensor::f32(vec![n, 1], vec![0.25; n]).unwrap();
        let y = HostTensor::f32(vec![n], vec![1.0; n]).unwrap();
        let mut f = s.fork().unwrap();
        assert_eq!(
            s.params_to_host().unwrap(),
            f.params_to_host().unwrap(),
            "fork must start bit-identical"
        );
        assert_eq!(s.fwd_loss(&x, &y).unwrap(), f.fwd_loss(&x, &y).unwrap());
        // training the fork must not move the original
        let before = s.params_to_host().unwrap();
        let mask = vec![1.0f32; n];
        f.train_step(&x, &y, &mask, 0.05).unwrap();
        assert_eq!(s.params_to_host().unwrap(), before);
        assert_ne!(f.params_to_host().unwrap(), before);
        // snapshot() is the params_to_host alias
        assert_eq!(s.snapshot().unwrap(), before);
    }

    #[test]
    fn snapshot_bytes_roundtrip_is_bit_identical() {
        let mut s = native_session("mlp");
        s.init(7).unwrap();
        let bytes = s.snapshot_bytes().unwrap();
        let before = s.params_to_host().unwrap();
        // perturb, then restore from the wire form
        let n = s.batch();
        let x = HostTensor::f32(vec![n, 784], vec![0.1; n * 784]).unwrap();
        let y = HostTensor::i32(vec![n], vec![0; n]).unwrap();
        let mask = vec![1.0f32; n];
        s.train_step(&x, &y, &mask, 0.1).unwrap();
        assert_ne!(s.params_to_host().unwrap(), before);
        s.load_snapshot_bytes(&bytes).unwrap();
        assert_eq!(s.params_to_host().unwrap(), before);
        // truncated snapshots are rejected
        assert!(s.load_snapshot_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn fork_of_uninitialized_session_is_uninitialized() {
        let s = native_session("linreg");
        let f = s.fork().unwrap();
        assert_eq!(f.model_name(), "linreg");
        assert_eq!(s.manifest().batch, f.manifest().batch);
    }

    #[test]
    fn uninitialized_session_refuses_to_run() {
        let mut s = native_session("linreg");
        let n = s.batch();
        let x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
        let y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();
        let err = s.fwd_loss(&x, &y).unwrap_err().to_string();
        assert!(err.contains("init"), "err: {err}");
    }

    #[test]
    fn shape_violations_rejected_before_execution() {
        let mut s = native_session("linreg");
        s.init(0).unwrap();
        let n = s.batch();
        let good_x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
        let good_y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();
        let bad_x = HostTensor::f32(vec![n + 1, 1], vec![0.0; n + 1]).unwrap();
        assert!(s.fwd_loss(&bad_x, &good_y).is_err());
        let bad_y = HostTensor::i32(vec![n], vec![0; n]).unwrap();
        assert!(s.fwd_loss(&good_x, &bad_y).is_err());
        let short_mask = vec![1.0f32; n - 1];
        assert!(s.train_step(&good_x, &good_y, &short_mask, 0.1).is_err());
        assert!(s.apply(&[], 0.1).is_err());
        assert!(s.train_step_selected(&good_x, &good_y, &[], 0.1).is_err());
        assert!(s.train_step_selected(&good_x, &good_y, &[n + 5], 0.1).is_err());
        // still usable after rejected calls
        assert!(s.fwd_loss(&good_x, &good_y).is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn artifact_flavours_need_the_pjrt_feature() {
        let dir = TempDir::new("session").unwrap();
        let m = Manifest::native(dir.path());
        let err = match Session::new(&m, "mlp", Flavour::Jnp) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("jnp must not build without the pjrt feature"),
        };
        assert!(err.contains("pjrt"), "err: {err}");
    }
}
