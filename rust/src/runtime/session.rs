//! A single-threaded PJRT session: one model × flavour, all six
//! executables compiled, parameters held resident as XLA `Literal`s.
//!
//! The `xla` crate's handles are `Rc`-backed (not `Send`); a `Session`
//! therefore lives on exactly one thread. Multi-worker execution wraps
//! one `Session` per worker thread (see [`crate::runtime::engine`]).
//!
//! Hot-path design: parameters never round-trip through `HostTensor`
//! between steps — `train_step` returns a tuple literal whose leading
//! elements simply *become* the new parameter literals. Only the scalar
//! selected-loss and the per-example loss vector are copied to host.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{Exe, Flavour, Manifest, ModelEntry};
use crate::data::tensor::{HostTensor, TensorData};

/// Cumulative execution counters for the perf pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    pub executions: u64,
    pub exec_ns: u64,
    pub compile_ns: u64,
}

/// One model's compiled executables + resident parameters.
pub struct Session {
    client: xla::PjRtClient,
    exes: HashMap<Exe, xla::PjRtLoadedExecutable>,
    /// Sub-batch `train_step_b{bb}` variants, keyed by compiled batch
    /// size `bb` (ascending); the gathered backward picks the smallest
    /// `bb ≥ |selection|` (see [`Session::train_step_selected`]).
    gather_exes: std::collections::BTreeMap<usize, xla::PjRtLoadedExecutable>,
    entry: ModelEntry,
    model_name: String,
    flavour: Flavour,
    batch: usize,
    params: Vec<xla::Literal>,
    stats: std::cell::Cell<SessionStats>,
}

/// Convert a host tensor into an XLA literal.
///
/// Uses `create_from_shape_and_untyped_data` — a single memcpy — rather
/// than `vec1().reshape()`, which copies twice (§Perf: 242 µs → ~60 µs
/// for a 128×784 batch).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    fn as_bytes<T>(v: &[T]) -> &[u8] {
        // SAFETY: f32/i32 are plain-old-data; the literal copies out of
        // this view before it returns.
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    let lit = match &t.data {
        TensorData::F32(v) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &t.shape,
                as_bytes(v),
            )
            .map_err(|e| anyhow::anyhow!("literal from f32 {:?}: {e:?}", t.shape))?
        }
        TensorData::I32(v) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &t.shape,
                as_bytes(v),
            )
            .map_err(|e| anyhow::anyhow!("literal from i32 {:?}: {e:?}", t.shape))?
        }
    };
    Ok(lit)
}

/// Convert an XLA literal back to a host tensor.
pub fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match l.ty()? {
        xla::ElementType::F32 => Ok(HostTensor { shape: dims, data: TensorData::F32(l.to_vec()?) }),
        xla::ElementType::S32 => Ok(HostTensor { shape: dims, data: TensorData::I32(l.to_vec()?) }),
        other => bail!("unsupported artifact dtype {other:?}"),
    }
}

impl Session {
    /// Compile all six executables of `model` from `manifest`.
    pub fn new(manifest: &Manifest, model: &str, flavour: Flavour) -> Result<Session> {
        let entry = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        let mut compile_ns = 0u64;
        for exe in Exe::ALL {
            let path = manifest.artifact_path(model, exe, flavour)?;
            let t0 = Instant::now();
            let compiled = compile_hlo(&client, &path)
                .with_context(|| format!("compiling {model}/{}", exe.as_str()))?;
            compile_ns += t0.elapsed().as_nanos() as u64;
            exes.insert(exe, compiled);
        }
        // optional sub-batch backward variants (train_step_b{bb}:{flavour})
        let mut gather_exes = std::collections::BTreeMap::new();
        let suffix = format!(":{}", flavour.as_str());
        for (key, fname) in &entry.executables {
            let Some(stem) = key.strip_suffix(&suffix) else { continue };
            let Some(bb) = stem.strip_prefix("train_step_b") else { continue };
            let Ok(bb) = bb.parse::<usize>() else { continue };
            let t0 = Instant::now();
            let compiled = compile_hlo(&client, &manifest.dir.join(fname))
                .with_context(|| format!("compiling {model}/{key}"))?;
            compile_ns += t0.elapsed().as_nanos() as u64;
            gather_exes.insert(bb, compiled);
        }
        Ok(Session {
            client,
            exes,
            gather_exes,
            entry,
            model_name: model.to_string(),
            flavour,
            batch: manifest.batch,
            params: vec![],
            stats: std::cell::Cell::new(SessionStats { compile_ns, ..Default::default() }),
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    pub fn flavour(&self) -> Flavour {
        self.flavour
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn stats(&self) -> SessionStats {
        self.stats.get()
    }

    pub fn client_platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one AOT executable and untuple its outputs.
    /// `&self` + `Cell` stats so callers can pass inputs borrowing
    /// `self.params` and re-assign them from the outputs afterwards.
    fn run(&self, exe: Exe, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exec = self.exes.get(&exe).expect("all exes compiled in new()");
        self.run_exec(exec, exe.as_str(), inputs)
    }

    fn run_exec(
        &self,
        exec: &xla::PjRtLoadedExecutable,
        label: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = exec
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {label}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch output literal")?;
        let outs = tuple.to_tuple().context("untuple output")?;
        let mut stats = self.stats.get();
        stats.executions += 1;
        stats.exec_ns += t0.elapsed().as_nanos() as u64;
        self.stats.set(stats);
        Ok(outs)
    }

    /// Initialize parameters from `seed` (runs the `init` executable).
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let seed_lit = xla::Literal::scalar(seed);
        let outs = self.run(Exe::Init, &[&seed_lit])?;
        if outs.len() != self.entry.n_params() {
            bail!(
                "init returned {} tensors, manifest declares {} params",
                outs.len(),
                self.entry.n_params()
            );
        }
        self.params = outs;
        Ok(())
    }

    fn check_ready(&self) -> Result<()> {
        if self.params.len() != self.entry.n_params() {
            bail!("session has no parameters; call init() or load_params() first");
        }
        Ok(())
    }

    fn check_batch_inputs(&self, x: &HostTensor, y: &HostTensor) -> Result<()> {
        let mut want = vec![self.batch];
        want.extend_from_slice(&self.entry.x_shape);
        if x.shape != want {
            bail!("x shape {:?} != expected {:?}", x.shape, want);
        }
        if y.shape != vec![self.batch] {
            bail!("y shape {:?} != expected [{}]", y.shape, self.batch);
        }
        let want_i32 = self.entry.y_dtype == "i32";
        if want_i32 != matches!(y.data, TensorData::I32(_)) {
            bail!("y dtype mismatch: model wants {}", self.entry.y_dtype);
        }
        Ok(())
    }

    /// "Ten forward": per-example losses for the whole batch.
    pub fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&xl);
        inputs.push(&yl);
        let outs = self.run(Exe::FwdLoss, &inputs)?;
        let loss = from_literal(&outs[0])?;
        Ok(loss.as_f32()?.to_vec())
    }

    /// "One backward": masked train step; parameters update in place.
    /// Returns the selected-subset mean loss.
    pub fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        if mask.len() != self.batch {
            bail!("mask len {} != batch {}", mask.len(), self.batch);
        }
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml, &lrl]);
        let mut outs = self.run(Exe::TrainStep, &inputs)?;
        let loss_lit = outs.pop().expect("train_step returns params + loss");
        if outs.len() != self.entry.n_params() {
            bail!("train_step returned {} params, expected {}", outs.len(), self.entry.n_params());
        }
        self.params = outs;
        Ok(from_literal(&loss_lit)?.scalar_value()?)
    }

    /// "One backward", gathered: run the backward only on the selected
    /// rows, using the smallest compiled sub-batch `bb ≥ |selected|`
    /// (falling back to the masked full-batch step when none fits).
    /// Numerically identical to [`Session::train_step`] with the
    /// equivalent mask — the masked mean over gathered rows equals the
    /// masked mean over the full batch — but costs O(bb) instead of
    /// O(n) in the backward (EXPERIMENTS.md §Perf).
    pub fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        let k = selected.len();
        if k == 0 {
            bail!("train_step_selected: empty selection");
        }
        // smallest compiled sub-batch that fits
        let bb = self
            .gather_exes
            .range(k..)
            .next()
            .map(|(&bb, _)| bb)
            .filter(|&bb| bb < self.batch);
        let Some(bb) = bb else {
            // no useful sub-batch: masked full-batch step
            let mut mask = vec![0.0f32; self.batch];
            for &i in selected {
                if i >= self.batch {
                    bail!("selected index {i} out of range");
                }
                mask[i] = 1.0;
            }
            return self.train_step(x, y, &mask, lr);
        };

        // gather the selected rows, zero-pad to bb
        let stride = x.element_count() / self.batch;
        let xv = x.as_f32()?;
        let mut gx = vec![0.0f32; bb * stride];
        for (row, &i) in selected.iter().enumerate() {
            if i >= self.batch {
                bail!("selected index {i} out of range");
            }
            gx[row * stride..(row + 1) * stride]
                .copy_from_slice(&xv[i * stride..(i + 1) * stride]);
        }
        let mut gshape = x.shape.clone();
        gshape[0] = bb;
        let gx = HostTensor { shape: gshape, data: TensorData::F32(gx) };
        let gy = match &y.data {
            TensorData::F32(v) => {
                let mut out = vec![0.0f32; bb];
                for (row, &i) in selected.iter().enumerate() {
                    out[row] = v[i];
                }
                HostTensor { shape: vec![bb], data: TensorData::F32(out) }
            }
            TensorData::I32(v) => {
                let mut out = vec![0i32; bb];
                for (row, &i) in selected.iter().enumerate() {
                    out[row] = v[i];
                }
                HostTensor { shape: vec![bb], data: TensorData::I32(out) }
            }
        };
        let mut mask = vec![0.0f32; bb];
        for m in mask.iter_mut().take(k) {
            *m = 1.0;
        }

        let xl = to_literal(&gx)?;
        let yl = to_literal(&gy)?;
        let ml = xla::Literal::vec1(&mask);
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml, &lrl]);
        let exec = &self.gather_exes[&bb];
        let mut outs = self.run_exec(exec, &format!("train_step_b{bb}"), &inputs)?;
        let loss_lit = outs.pop().expect("train_step returns params + loss");
        if outs.len() != self.entry.n_params() {
            bail!("train_step_b{bb} returned {} params", outs.len());
        }
        self.params = outs;
        Ok(from_literal(&loss_lit)?.scalar_value()?)
    }

    /// Gradients for a masked shard (the data-parallel worker path).
    /// Returns (grads, selected mean loss over this shard).
    pub fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml]);
        let mut outs = self.run(Exe::Grads, &inputs)?;
        let loss_lit = outs.pop().expect("grads returns grads + loss");
        let grads = outs.iter().map(from_literal).collect::<Result<Vec<_>>>()?;
        Ok((grads, from_literal(&loss_lit)?.scalar_value()?))
    }

    /// Apply externally averaged gradients (the leader path).
    pub fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        self.check_ready()?;
        if grads.len() != self.entry.n_params() {
            bail!("apply got {} grads, expected {}", grads.len(), self.entry.n_params());
        }
        let glits = grads.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend(glits.iter());
        inputs.push(&lrl);
        let outs = self.run(Exe::Apply, &inputs)?;
        if outs.len() != self.entry.n_params() {
            bail!("apply returned {} params, expected {}", outs.len(), self.entry.n_params());
        }
        self.params = outs;
        Ok(())
    }

    /// Masked eval sums: `(sum_loss, sum_metric, count)`.
    pub fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)> {
        self.check_ready()?;
        self.check_batch_inputs(x, y)?;
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml]);
        let outs = self.run(Exe::Eval, &inputs)?;
        let s = from_literal(&outs[0])?.scalar_value()? as f64;
        let m = from_literal(&outs[1])?.scalar_value()? as f64;
        let c = from_literal(&outs[2])?.scalar_value()? as f64;
        Ok((s, m, c))
    }

    /// Copy the resident parameters to host (checkpointing / broadcast).
    pub fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.params.iter().map(from_literal).collect()
    }

    /// Replace the resident parameters from host tensors (shape-checked
    /// against the manifest).
    pub fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        if params.len() != self.entry.n_params() {
            bail!("load_params got {} tensors, expected {}", params.len(), self.entry.n_params());
        }
        for (t, spec) in params.iter().zip(&self.entry.params) {
            if t.shape != spec.shape {
                bail!("param {}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
        }
        self.params = params.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// Load HLO text and compile it on `client` (see /opt/xla-example: text,
/// not serialized proto, is the interchange format).
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse HLO text {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("XLA compile {path:?}: {e:?}"))
}
