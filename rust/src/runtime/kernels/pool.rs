//! Scoped row-sharding for the blocked kernels.
//!
//! [`par_rows`] splits a row-major output buffer into contiguous,
//! disjoint row ranges and runs one closure per range on
//! `std::thread::scope` workers. Shard boundaries never change what is
//! computed — every kernel built on this either computes rows
//! independently (forward, `grad_input`) or gives each thread a
//! disjoint slice of `dW` rows whose batch reduction order is fixed
//! (`grad_weights`) — so results are bit-identical at any thread count.
//!
//! Workers are spawned per call (threads−1 spawns per parallel region;
//! the last shard runs on the caller), a deliberate trade: tens of µs
//! per threaded kernel call against the ms-scale calls that clear
//! [`super::PAR_THRESHOLD_FLOPS`]. A persistent pool is the upgrade
//! path if profile data ever shows the spawn tax matters.

/// Detected hardware parallelism (the `OBFTF_NATIVE_THREADS` default).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(row_start, row_end, chunk)` over `threads` contiguous shards
/// of `out` (`rows` rows of `row_elems` f32s each). The chunk passed to
/// `f` is `out[row_start * row_elems .. row_end * row_elems]`; row
/// indices are global so closures can index shared inputs. With one
/// shard (or one row) `f` runs on the calling thread.
pub fn par_rows<F>(out: &mut [f32], rows: usize, row_elems: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_elems);
    let t = threads.clamp(1, rows.max(1));
    if t <= 1 {
        f(0, rows, out);
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut start = 0usize;
        for ti in 0..t {
            // even split: remaining rows over remaining shards
            let take = (rows - start).div_ceil(t - ti);
            let slice = std::mem::take(&mut rest);
            let (head, tail) = slice.split_at_mut(take * row_elems);
            rest = tail;
            let s0 = start;
            start += take;
            if ti == t - 1 {
                // run the last shard on the calling thread
                f(s0, s0 + take, head);
            } else {
                scope.spawn(move || f(s0, s0 + take, head));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_row_exactly_once() {
        for threads in [1, 2, 3, 7, 64] {
            for rows in [0usize, 1, 2, 5, 13] {
                let mut out = vec![0.0f32; rows * 3];
                par_rows(&mut out, rows, 3, threads, |s, e, chunk| {
                    assert_eq!(chunk.len(), (e - s) * 3);
                    for (r, row) in chunk.chunks_exact_mut(3).enumerate() {
                        for v in row.iter_mut() {
                            *v += (s + r) as f32 + 1.0;
                        }
                    }
                });
                for r in 0..rows {
                    for c in 0..3 {
                        assert_eq!(out[r * 3 + c], r as f32 + 1.0, "row {r} col {c} threads {threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn shards_actually_run_concurrently_scoped() {
        let hits = AtomicUsize::new(0);
        let mut out = vec![0.0f32; 8];
        par_rows(&mut out, 8, 1, 4, |_, _, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn available_threads_is_positive() {
        assert!(available_threads() >= 1);
    }
}
