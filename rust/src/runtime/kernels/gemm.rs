//! Cache-blocked, register-tiled f32 kernels.
//!
//! The naive loops stream the full weight matrix from memory once per
//! batch row (~100 MB per MLP layer step at batch 128). The blocked
//! path removes that traffic three ways:
//!
//! * **packing** — weights are repacked once per call into [`NR`]-wide
//!   column panels (`panel[k][c] = w[k][o0 + c]`, zero-padded), so the
//!   micro-kernel streams contiguous 64-byte lines instead of striding
//!   across rows; `grad_weights` additionally packs `hᵀ` and `dz`
//!   panels, `grad_input` packs `Wᵀ`;
//! * **register tiling** — each micro-kernel invocation holds an
//!   [`MR`]×[`NR`] f32 accumulator tile in registers, so every packed
//!   line loaded is reused `MR` times and outputs are stored exactly
//!   once;
//! * **row sharding** — independent batch rows (forward, `grad_input`)
//!   or disjoint `dW` rows (`grad_weights`) split across scoped worker
//!   threads ([`super::pool`]).
//!
//! All inner loops run over fixed-length slices (`chunks_exact`,
//! `zip` on `[f32; NR]`), which LLVM auto-vectorizes without any
//! `unsafe` or explicit intrinsics; reductions keep a fixed index
//! order, so results are deterministic and thread-count-invariant (see
//! the module docs in [`super`]).

use super::pool::par_rows;
use super::{Arena, MR, NR};

/// Pack a `rows×cols` row-major matrix into `ceil(cols/NR)` column
/// panels: `dst[p*rows*NR + r*NR + c] = src[r*cols + p*NR + c]`,
/// zero-padded in the last panel. Used for the forward weight panels
/// and the backward `dz` panels — both stream contiguous `NR`-wide
/// lines in the micro-kernels ([`super::simd`] packs identically, so
/// its tiles see bit-for-bit the same operands).
pub(super) fn pack_panels(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    let npanels = cols.div_ceil(NR);
    for p in 0..npanels {
        let o0 = p * NR;
        let valid = NR.min(cols - o0);
        let panel = &mut dst[p * rows * NR..(p + 1) * rows * NR];
        for (r, line) in panel.chunks_exact_mut(NR).enumerate() {
            line[..valid].copy_from_slice(&src[r * cols + o0..r * cols + o0 + valid]);
            line[valid..].fill(0.0);
        }
    }
}

/// Forward micro-kernel: `M` batch rows × one `NR`-wide panel, bias in
/// registers, optional fused ReLU. Row indices are local to `h`/`out`.
#[inline]
fn mk_forward<const M: usize>(
    h: &[f32],
    i0: usize,
    din: usize,
    panel: &[f32],
    bias: &[f32],
    out: &mut [f32],
    dout: usize,
    o0: usize,
    valid: usize,
    relu: bool,
) {
    let mut acc = [[0.0f32; NR]; M];
    for row in acc.iter_mut() {
        row.copy_from_slice(bias);
    }
    for (k, line) in panel.chunks_exact(NR).enumerate() {
        for (r, row) in acc.iter_mut().enumerate() {
            let hv = h[(i0 + r) * din + k];
            for (a, &wv) in row.iter_mut().zip(line) {
                *a += hv * wv;
            }
        }
    }
    for (r, row) in acc.iter_mut().enumerate() {
        if relu {
            for v in row.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        let at = (i0 + r) * dout + o0;
        out[at..at + valid].copy_from_slice(&row[..valid]);
    }
}

/// Blocked `out = act(h · W + b)`; see [`super::matmul_bias_act`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_act(
    arena: &mut Arena,
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
    threads: usize,
) {
    let npanels = dout.div_ceil(NR);
    let mut wpack = arena.take(npanels * din * NR);
    pack_panels(w, din, dout, &mut wpack);
    let mut bpad = arena.take(npanels * NR);
    bpad[..dout].copy_from_slice(b);
    par_rows(out, n, dout, threads, |s, e, chunk| {
        let rows = e - s;
        let hloc = &h[s * din..e * din];
        for p in 0..npanels {
            let panel = &wpack[p * din * NR..(p + 1) * din * NR];
            let bias = &bpad[p * NR..(p + 1) * NR];
            let o0 = p * NR;
            let valid = NR.min(dout - o0);
            let mut i = 0;
            while i + MR <= rows {
                mk_forward::<MR>(hloc, i, din, panel, bias, chunk, dout, o0, valid, relu);
                i += MR;
            }
            match rows - i {
                1 => mk_forward::<1>(hloc, i, din, panel, bias, chunk, dout, o0, valid, relu),
                2 => mk_forward::<2>(hloc, i, din, panel, bias, chunk, dout, o0, valid, relu),
                3 => mk_forward::<3>(hloc, i, din, panel, bias, chunk, dout, o0, valid, relu),
                _ => {}
            }
        }
    });
    arena.put(bpad);
    arena.put(wpack);
}

/// Weight-gradient micro-kernel: `M` rows of `dW` (the `din`
/// dimension) × one `NR`-wide `dz` panel, reducing batch rows `0..n`
/// in ascending order. `k0` indexes the packed `hᵀ`; `k0loc` the
/// thread-local `dw` chunk.
#[inline]
#[allow(clippy::too_many_arguments)]
fn mk_grad_w<const M: usize>(
    ht: &[f32],
    n: usize,
    k0: usize,
    dzpan: &[f32],
    chunk: &mut [f32],
    k0loc: usize,
    dout: usize,
    o0: usize,
    valid: usize,
) {
    let mut acc = [[0.0f32; NR]; M];
    for (i, line) in dzpan.chunks_exact(NR).enumerate() {
        for (r, row) in acc.iter_mut().enumerate() {
            let hv = ht[(k0 + r) * n + i];
            for (a, &dv) in row.iter_mut().zip(line) {
                *a += hv * dv;
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        let at = (k0loc + r) * dout + o0;
        chunk[at..at + valid].copy_from_slice(&row[..valid]);
    }
}

/// Blocked `dw = hᵀ·dz`, `db = Σᵢ dz[i]`; see [`super::grad_weights`].
#[allow(clippy::too_many_arguments)]
pub fn grad_weights(
    arena: &mut Arena,
    h: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    // db: one sequential pass in batch order (cheap; its reduction
    // order must not depend on the thread count)
    db.fill(0.0);
    for drow in dz.chunks_exact(dout) {
        for (d, &v) in db.iter_mut().zip(drow) {
            *d += v;
        }
    }
    // pack hᵀ so micro-kernel rows read `n` contiguous values
    let mut ht = arena.take(din * n);
    for (i, hrow) in h.chunks_exact(din).enumerate() {
        for (k, &hv) in hrow.iter().enumerate() {
            ht[k * n + i] = hv;
        }
    }
    // pack dz into NR-wide panels (L1-resident across the k loop)
    let npanels = dout.div_ceil(NR);
    let mut dzp = arena.take(npanels * n * NR);
    pack_panels(dz, n, dout, &mut dzp);
    // shard the din dimension: each thread owns disjoint dW rows, and
    // every element still reduces batch rows 0..n sequentially
    par_rows(dw, din, dout, threads, |k0, k1, chunk| {
        let rows = k1 - k0;
        for p in 0..npanels {
            let dzpan = &dzp[p * n * NR..(p + 1) * n * NR];
            let o0 = p * NR;
            let valid = NR.min(dout - o0);
            let mut k = 0;
            while k + MR <= rows {
                mk_grad_w::<MR>(&ht, n, k0 + k, dzpan, chunk, k, dout, o0, valid);
                k += MR;
            }
            match rows - k {
                1 => mk_grad_w::<1>(&ht, n, k0 + k, dzpan, chunk, k, dout, o0, valid),
                2 => mk_grad_w::<2>(&ht, n, k0 + k, dzpan, chunk, k, dout, o0, valid),
                3 => mk_grad_w::<3>(&ht, n, k0 + k, dzpan, chunk, k, dout, o0, valid),
                _ => {}
            }
        }
    });
    arena.put(dzp);
    arena.put(ht);
}

/// Shared `dh = dz · Wᵀ` core with an optional fused ReLU gate: the
/// gate (when given the layer's input activation) is applied per row
/// block inside the parallel region while the block is cache-hot.
/// Gating is an elementwise zeroing after each block's accumulation,
/// so the ungated values are bit-identical either way.
#[allow(clippy::too_many_arguments)]
fn dz_wt_impl(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    gate: Option<&[f32]>,
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    // pack Wᵀ so each output row accumulates over contiguous din-wide
    // lines (outer product over the dout reduction)
    let mut wt = arena.take(dout * din);
    for (k, wrow) in w.chunks_exact(dout).enumerate() {
        for (o, &wv) in wrow.iter().enumerate() {
            wt[o * din + k] = wv;
        }
    }
    par_rows(dh, n, din, threads, |s, e, chunk| {
        let rows = e - s;
        let mut i = 0;
        while i < rows {
            let m = MR.min(rows - i);
            chunk[i * din..(i + m) * din].fill(0.0);
            // dh[r] += dz[r][o] · wt[o], o ascending per element; a
            // Wᵀ line stays L1-hot across the m rows of the block
            for (o, wtline) in wt.chunks_exact(din).enumerate() {
                for r in 0..m {
                    let dv = dz[(s + i + r) * dout + o];
                    if dv == 0.0 {
                        continue; // masked-out rows add exact zeros
                    }
                    let dst = &mut chunk[(i + r) * din..(i + r + 1) * din];
                    for (a, &wv) in dst.iter_mut().zip(wtline) {
                        *a += dv * wv;
                    }
                }
            }
            if let Some(h) = gate {
                // ReLU gate by the layer's activation
                for r in 0..m {
                    let hrow = &h[(s + i + r) * din..(s + i + r + 1) * din];
                    let dst = &mut chunk[(i + r) * din..(i + r + 1) * din];
                    for (d, &hv) in dst.iter_mut().zip(hrow) {
                        if hv <= 0.0 {
                            *d = 0.0;
                        }
                    }
                }
            }
            i += m;
        }
    });
    arena.put(wt);
}

/// Blocked plain `dh = dz · Wᵀ` (no activation gate) — the conv
/// chain's ungated head-to-pool / patch gradients
/// ([`super::matmul_dz_wt`], [`super::conv::conv2d_grad_x_blocked`]).
#[allow(clippy::too_many_arguments)]
pub fn dz_wt(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    dz_wt_impl(arena, dz, w, None, dh, n, din, dout, threads);
}

/// Blocked ReLU-gated `dh = dz · Wᵀ`; see [`super::grad_input`].
#[allow(clippy::too_many_arguments)]
pub fn grad_input(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    h: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    dz_wt_impl(arena, dz, w, Some(h), dh, n, din, dout, threads);
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::data::rng::Rng;

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what} length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-4 * x.abs().max(1.0);
            assert!((x - y).abs() <= tol, "{what}[{i}]: blocked {x} vs reference {y}");
        }
    }

    /// Shapes chosen to hit every remainder path: rows % MR ∈ {0,1,2,3},
    /// dout % NR ∈ {0, small, NR-1}, din below/above a panel line.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 2, 5),
        (4, 16, 16),
        (5, 17, 31),
        (8, 17, 10),
        (13, 7, 33),
        (16, 32, 48),
    ];

    #[test]
    fn forward_matches_reference_across_shapes_and_threads() {
        for &(n, din, dout) in SHAPES {
            for threads in [1, 3] {
                for relu in [false, true] {
                    let mut rng = Rng::seed_from(42);
                    let h = fill(&mut rng, n * din);
                    let w = fill(&mut rng, din * dout);
                    let b = fill(&mut rng, dout);
                    let mut want = vec![0.0f32; n * dout];
                    reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, relu);
                    let mut arena = Arena::new();
                    let mut got = vec![0.0f32; n * dout];
                    matmul_bias_act(&mut arena, &h, &w, &b, &mut got, n, din, dout, relu, threads);
                    assert_close(&got, &want, &format!("fwd {n}x{din}x{dout} t{threads}"));
                }
            }
        }
    }

    #[test]
    fn grad_weights_matches_reference_across_shapes_and_threads() {
        for &(n, din, dout) in SHAPES {
            for threads in [1, 3] {
                let mut rng = Rng::seed_from(7);
                let h = fill(&mut rng, n * din);
                let dz = fill(&mut rng, n * dout);
                let (mut want_w, mut want_b) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
                reference::grad_weights(&h, &dz, &mut want_w, &mut want_b, n, din, dout);
                let mut arena = Arena::new();
                let (mut got_w, mut got_b) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
                grad_weights(&mut arena, &h, &dz, &mut got_w, &mut got_b, n, din, dout, threads);
                assert_close(&got_w, &want_w, &format!("dw {n}x{din}x{dout} t{threads}"));
                assert_close(&got_b, &want_b, &format!("db {n}x{din}x{dout} t{threads}"));
            }
        }
    }

    #[test]
    fn grad_input_matches_reference_across_shapes_and_threads() {
        for &(n, din, dout) in SHAPES {
            for threads in [1, 3] {
                let mut rng = Rng::seed_from(23);
                let dz = fill(&mut rng, n * dout);
                let w = fill(&mut rng, din * dout);
                // activations: ReLU-like (about half exactly zero)
                let h: Vec<f32> =
                    fill(&mut rng, n * din).into_iter().map(|v| v.max(0.0)).collect();
                let mut want = vec![0.0f32; n * din];
                reference::grad_input(&dz, &w, &h, &mut want, n, din, dout);
                let mut arena = Arena::new();
                let mut got = vec![1.0f32; n * din]; // dirty: kernel must overwrite
                grad_input(&mut arena, &dz, &w, &h, &mut got, n, din, dout, threads);
                assert_close(&got, &want, &format!("dh {n}x{din}x{dout} t{threads}"));
            }
        }
    }

    #[test]
    fn threaded_equals_single_thread_bitwise() {
        let (n, din, dout) = (29, 37, 19);
        let mut rng = Rng::seed_from(99);
        let h = fill(&mut rng, n * din);
        let w = fill(&mut rng, din * dout);
        let b = fill(&mut rng, dout);
        let mut arena = Arena::new();
        let (mut o1, mut o4) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
        matmul_bias_act(&mut arena, &h, &w, &b, &mut o1, n, din, dout, true, 1);
        matmul_bias_act(&mut arena, &h, &w, &b, &mut o4, n, din, dout, true, 4);
        assert_eq!(o1, o4, "forward must be thread-count invariant");
        let dz = fill(&mut rng, n * dout);
        let (mut w1, mut b1) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut w4, mut b4) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        grad_weights(&mut arena, &h, &dz, &mut w1, &mut b1, n, din, dout, 1);
        grad_weights(&mut arena, &h, &dz, &mut w4, &mut b4, n, din, dout, 4);
        assert_eq!(w1, w4, "grad_weights must be thread-count invariant");
        assert_eq!(b1, b4);
        let (mut h1, mut h4) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
        grad_input(&mut arena, &dz, &w, &o1, &mut h1, n, din, dout, 1);
        grad_input(&mut arena, &dz, &w, &o1, &mut h4, n, din, dout, 4);
        assert_eq!(h1, h4, "grad_input must be thread-count invariant");
    }
}
