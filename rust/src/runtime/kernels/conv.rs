//! Conv2d kernel family: SAME-padded NHWC convolution lowered onto the
//! blocked GEMM tiles, plus global average pooling.
//!
//! The blocked path is **im2col + GEMM**: each conv layer's input is
//! unfolded into a `(n·oh·ow) × (kh·kw·cin)` patch matrix whose rows
//! stream through the exact packed-panel [`MR`]×[`NR`] micro-kernels of
//! [`super::gemm`] — the HWIO weight layout `[kh, kw, cin, cout]` is,
//! flattened, already the row-major `(kh·kw·cin) × cout` GEMM operand,
//! so the forward fuses bias + ReLU for free, `dW = patchesᵀ · dz`
//! reuses the transposed weight-gradient kernel, and `dx` is
//! `dz · Wᵀ` scattered back through [`col2im`].
//!
//! **Determinism.** Patch rows are ordered `(image, oy, ox)` with the
//! image index outermost, so batch-row sharding in the GEMM and
//! image sharding in `col2im` give every output element a fixed
//! reduction order at any thread count, and a masked-out image's
//! exact-zero `dz` rows contribute exact zeros interleaved in the same
//! ascending order the gathered sub-batch visits — the conv chain
//! inherits the gathered-vs-masked bit-equality of the dense kernels
//! (see the module docs in [`super`]).
//!
//! A deliberate trade: a train step unfolds each layer input twice
//! (once in the forward, once in `dW`), keeping the kernel API
//! stateless and the arena's working set one buffer deep. Retaining
//! the forward's patch matrices across the backward (a few MB per
//! layer) is the named upgrade path if profile data shows the second
//! unfold matters — the values are identical either way, so no
//! numerics would change.
//!
//! [`MR`]: super::MR
//! [`NR`]: super::NR

use super::pool::par_rows;
use super::{gemm, simd, Arena};

/// Geometry of one SAME-padded conv layer (NHWC activations, HWIO
/// weights), resolved once at backend construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad_top: usize,
    pub pad_left: usize,
    pub oh: usize,
    pub ow: usize,
}

impl ConvShape {
    /// XLA `SAME` geometry: `oh = ceil(h/s)`, total padding
    /// `max((oh−1)·s + kh − h, 0)` split low-side-first (top gets
    /// `total/2`) — matches `jax.lax.conv_general_dilated(.., "SAME")`.
    pub fn same(
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
    ) -> ConvShape {
        assert!(stride > 0, "stride must be positive");
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let pad_h = ((oh - 1) * stride + kh).saturating_sub(h);
        let pad_w = ((ow - 1) * stride + kw).saturating_sub(w);
        ConvShape {
            h,
            w,
            cin,
            cout,
            kh,
            kw,
            stride,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
            oh,
            ow,
        }
    }

    /// Input elements per image (`h·w·cin`).
    pub fn in_elems(&self) -> usize {
        self.h * self.w * self.cin
    }

    /// Output elements per image (`oh·ow·cout`).
    pub fn out_elems(&self) -> usize {
        self.oh * self.ow * self.cout
    }

    /// Spatial output positions per image (`oh·ow`).
    pub fn positions(&self) -> usize {
        self.oh * self.ow
    }

    /// im2col patch width (`kh·kw·cin`) — the GEMM reduction length.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Multiply-add FLOPs of one forward pass over `n` images.
    pub fn fwd_flops(&self, n: usize) -> f64 {
        2.0 * n as f64 * self.positions() as f64 * self.patch_len() as f64 * self.cout as f64
    }
}

/// Unfold `n` NHWC images into the patch matrix: row `(i·oh + oy)·ow + ox`,
/// column `(ky·kw + kx)·cin + c`. Out-of-image taps are zero (SAME
/// padding); every row is fully rewritten, so `cols` may be dirty.
/// Sharded over images — each image's rows are a disjoint, purely
/// written block, so the unfold is bit-identical at any thread count.
pub fn im2col(x: &[f32], n: usize, s: &ConvShape, cols: &mut [f32], threads: usize) {
    debug_assert_eq!(x.len(), n * s.in_elems());
    debug_assert_eq!(cols.len(), n * s.positions() * s.patch_len());
    let pl = s.patch_len();
    let per_image = s.positions() * pl;
    par_rows(cols, n, per_image, threads, |i0, i1, chunk| {
        for i in i0..i1 {
            let img = &x[i * s.in_elems()..(i + 1) * s.in_elems()];
            let rows = &mut chunk[(i - i0) * per_image..(i - i0 + 1) * per_image];
            for oy in 0..s.oh {
                for ox in 0..s.ow {
                    let pos = oy * s.ow + ox;
                    let dst = &mut rows[pos * pl..(pos + 1) * pl];
                    dst.fill(0.0);
                    for ky in 0..s.kh {
                        let y = (oy * s.stride + ky) as isize - s.pad_top as isize;
                        if y < 0 || y as usize >= s.h {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let xx = (ox * s.stride + kx) as isize - s.pad_left as isize;
                            if xx < 0 || xx as usize >= s.w {
                                continue;
                            }
                            let src = (y as usize * s.w + xx as usize) * s.cin;
                            let at = (ky * s.kw + kx) * s.cin;
                            dst[at..at + s.cin].copy_from_slice(&img[src..src + s.cin]);
                        }
                    }
                }
            }
        }
    });
}

/// Fold a patch-gradient matrix (the `dz · Wᵀ` of [`conv2d_grad_x`])
/// back onto image gradients, accumulating overlapping taps, with an
/// optional fused ReLU gate by the layer's input activation (applied
/// per image while its chunk is cache-hot; gating after the scatter
/// is elementwise, so the ungated values are bit-identical). Sharded
/// over images: each image's `dx` is written by exactly one thread in
/// a fixed `(oy, ox, ky, kx)` order, so the scatter is bit-identical
/// at any thread count. `dx` is fully overwritten.
pub fn col2im(
    dpatch: &[f32],
    n: usize,
    s: &ConvShape,
    dx: &mut [f32],
    gate: Option<&[f32]>,
    threads: usize,
) {
    debug_assert_eq!(dpatch.len(), n * s.positions() * s.patch_len());
    debug_assert_eq!(dx.len(), n * s.in_elems());
    let pl = s.patch_len();
    par_rows(dx, n, s.in_elems(), threads, |i0, i1, chunk| {
        chunk.fill(0.0);
        for i in i0..i1 {
            let img = &mut chunk[(i - i0) * s.in_elems()..(i - i0 + 1) * s.in_elems()];
            for oy in 0..s.oh {
                for ox in 0..s.ow {
                    let row = (i * s.oh + oy) * s.ow + ox;
                    let patch = &dpatch[row * pl..(row + 1) * pl];
                    for ky in 0..s.kh {
                        let y = (oy * s.stride + ky) as isize - s.pad_top as isize;
                        if y < 0 || y as usize >= s.h {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let xx = (ox * s.stride + kx) as isize - s.pad_left as isize;
                            if xx < 0 || xx as usize >= s.w {
                                continue;
                            }
                            let dst = (y as usize * s.w + xx as usize) * s.cin;
                            let at = (ky * s.kw + kx) * s.cin;
                            for (d, &v) in
                                img[dst..dst + s.cin].iter_mut().zip(&patch[at..at + s.cin])
                            {
                                *d += v;
                            }
                        }
                    }
                }
            }
            if let Some(g) = gate {
                relu_gate(img, &g[i * s.in_elems()..(i + 1) * s.in_elems()]);
            }
        }
    });
}

/// Blocked `out = act(conv2d(x, k) + b)`: im2col, then the packed-panel
/// GEMM over `(n·oh·ow)` patch rows with fused bias + ReLU.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bias_act_blocked(
    arena: &mut Arena,
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut cols = arena.take(rows * s.patch_len());
    im2col(x, n, s, &mut cols, threads);
    gemm::matmul_bias_act(arena, &cols, k, b, out, rows, s.patch_len(), s.cout, relu, threads);
    arena.put(cols);
}

/// Blocked `dk = patchesᵀ · dz`, `db = Σ dz` (sum over batch *and*
/// spatial positions, ascending patch-row order per element).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_grad_w_blocked(
    arena: &mut Arena,
    x: &[f32],
    dz: &[f32],
    dk: &mut [f32],
    db: &mut [f32],
    n: usize,
    s: &ConvShape,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut cols = arena.take(rows * s.patch_len());
    im2col(x, n, s, &mut cols, threads);
    gemm::grad_weights(arena, &cols, dz, dk, db, rows, s.patch_len(), s.cout, threads);
    arena.put(cols);
}

/// Blocked input gradient: `dpatch = dz · Wᵀ` (packed, ungated), folded
/// back with [`col2im`], then ReLU-gated by the layer's input
/// activation `h_in` (the previous layer's post-ReLU output).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_grad_x_blocked(
    arena: &mut Arena,
    dz: &[f32],
    k: &[f32],
    h_in: &[f32],
    dx: &mut [f32],
    n: usize,
    s: &ConvShape,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut dpatch = arena.take(rows * s.patch_len());
    gemm::dz_wt(arena, dz, k, &mut dpatch, rows, s.patch_len(), s.cout, threads);
    col2im(&dpatch, n, s, dx, Some(h_in), threads);
    arena.put(dpatch);
}

/// SIMD `out = act(conv2d(x, k) + b)`: the same im2col unfold routed
/// through the AVX2 GEMM microkernels ([`super::simd`]) — bit-identical
/// to [`conv2d_bias_act_blocked`] by the GEMM-level equality.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bias_act_simd(
    arena: &mut Arena,
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut cols = arena.take(rows * s.patch_len());
    im2col(x, n, s, &mut cols, threads);
    simd::matmul_bias_act(arena, &cols, k, b, out, rows, s.patch_len(), s.cout, relu, threads);
    arena.put(cols);
}

/// SIMD `dk = patchesᵀ · dz`, `db = Σ dz`; bit-identical to
/// [`conv2d_grad_w_blocked`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_grad_w_simd(
    arena: &mut Arena,
    x: &[f32],
    dz: &[f32],
    dk: &mut [f32],
    db: &mut [f32],
    n: usize,
    s: &ConvShape,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut cols = arena.take(rows * s.patch_len());
    im2col(x, n, s, &mut cols, threads);
    simd::grad_weights(arena, &cols, dz, dk, db, rows, s.patch_len(), s.cout, threads);
    arena.put(cols);
}

/// SIMD conv input gradient; bit-identical to
/// [`conv2d_grad_x_blocked`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_grad_x_simd(
    arena: &mut Arena,
    dz: &[f32],
    k: &[f32],
    h_in: &[f32],
    dx: &mut [f32],
    n: usize,
    s: &ConvShape,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut dpatch = arena.take(rows * s.patch_len());
    simd::dz_wt(arena, dz, k, &mut dpatch, rows, s.patch_len(), s.cout, threads);
    col2im(&dpatch, n, s, dx, Some(h_in), threads);
    arena.put(dpatch);
}

/// bf16 fast-scoring conv forward: the f32 im2col unfold feeding the
/// bf16 packed-panel GEMM ([`super::simd::matmul_bias_act_bf16`]).
/// Scoring only — relaxed tolerance, never used by training math.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bias_act_bf16(
    arena: &mut Arena,
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
    threads: usize,
) {
    let rows = n * s.positions();
    let mut cols = arena.take(rows * s.patch_len());
    im2col(x, n, s, &mut cols, threads);
    simd::matmul_bias_act_bf16(arena, &cols, k, b, out, rows, s.patch_len(), s.cout, relu, threads);
    arena.put(cols);
}

/// Zero `dst` wherever the matching activation is not strictly
/// positive — the ReLU gate (activation > 0 ⟺ pre-activation > 0).
pub fn relu_gate(dst: &mut [f32], act: &[f32]) {
    debug_assert_eq!(dst.len(), act.len());
    for (d, &hv) in dst.iter_mut().zip(act) {
        if hv <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Global average pool: `out[i][c] = mean over positions of
/// x[i][pos][c]` (positions reduced in ascending order). Shared by both
/// kernel flavours — the op is memory-bound and already deterministic.
pub fn global_avg_pool(x: &[f32], out: &mut [f32], n: usize, positions: usize, c: usize) {
    debug_assert_eq!(x.len(), n * positions * c);
    debug_assert_eq!(out.len(), n * c);
    let inv = 1.0 / positions as f32;
    for i in 0..n {
        let dst = &mut out[i * c..(i + 1) * c];
        dst.fill(0.0);
        let img = &x[i * positions * c..(i + 1) * positions * c];
        for pos in 0..positions {
            for (d, &v) in dst.iter_mut().zip(&img[pos * c..(pos + 1) * c]) {
                *d += v;
            }
        }
        for d in dst.iter_mut() {
            *d *= inv;
        }
    }
}

/// Global-average-pool gradient: every position inherits
/// `dpool[i][c] / positions`, optionally ReLU-gated in place by the
/// pooled layer's activation (one pass instead of spread-then-gate;
/// identical values). `dx` is fully overwritten.
pub fn global_avg_pool_grad(
    dpool: &[f32],
    dx: &mut [f32],
    gate: Option<&[f32]>,
    n: usize,
    positions: usize,
    c: usize,
) {
    debug_assert_eq!(dpool.len(), n * c);
    debug_assert_eq!(dx.len(), n * positions * c);
    if let Some(g) = gate {
        debug_assert_eq!(g.len(), dx.len());
    }
    let inv = 1.0 / positions as f32;
    for i in 0..n {
        let src = &dpool[i * c..(i + 1) * c];
        for pos in 0..positions {
            let at = (i * positions + pos) * c;
            let dst = &mut dx[at..at + c];
            match gate {
                Some(g) => {
                    for ((d, &v), &hv) in dst.iter_mut().zip(src).zip(&g[at..at + c]) {
                        *d = if hv > 0.0 { v * inv } else { 0.0 };
                    }
                }
                None => {
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = v * inv;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_geometry_matches_xla() {
        // 16×16, k=3: stride 1 → 16×16 pad (1,1); stride 2 → 8×8 with
        // one total pad row split low-side-first (top 0, bottom 1)
        let s1 = ConvShape::same(16, 16, 3, 8, 3, 3, 1);
        assert_eq!((s1.oh, s1.ow, s1.pad_top, s1.pad_left), (16, 16, 1, 1));
        let s2 = ConvShape::same(16, 16, 3, 8, 3, 3, 2);
        assert_eq!((s2.oh, s2.ow, s2.pad_top, s2.pad_left), (8, 8, 0, 0));
        assert_eq!((s2.oh - 1) * 2 + 3 - 16, 1, "one pad row, on the bottom");
        // degenerate 1×1 image with a 3×3 kernel: all taps but the
        // center are padding
        let s3 = ConvShape::same(1, 1, 2, 4, 3, 3, 1);
        assert_eq!((s3.oh, s3.ow, s3.pad_top, s3.pad_left), (1, 1, 1, 1));
        // kernel == image, no padding needed at stride = image size
        let s4 = ConvShape::same(3, 3, 1, 1, 3, 3, 3);
        assert_eq!((s4.oh, s4.ow, s4.pad_top, s4.pad_left), (1, 1, 0, 0));
        assert_eq!(s4.patch_len(), 9);
        assert_eq!(s1.fwd_flops(2), 2.0 * 2.0 * 256.0 * 27.0 * 8.0);
    }

    #[test]
    fn im2col_col2im_roundtrip_counts_taps() {
        // col2im(im2col(1s)) counts, per input element, how many patches
        // it participates in — every in-image tap exactly once per use.
        let s = ConvShape::same(3, 3, 1, 1, 3, 3, 1);
        let n = 1;
        let x = vec![1.0f32; n * s.in_elems()];
        let mut cols = vec![7.0f32; n * s.positions() * s.patch_len()];
        im2col(&x, n, &s, &mut cols, 1);
        // padding taps must be exact zeros even in a dirty buffer
        let total: f32 = cols.iter().sum();
        // 9 positions × 9 taps = 81 taps; corner positions see 4 in-image
        // taps, edges 6, center 9 → 4·4 + 4·6 + 9 = 49
        assert_eq!(total, 49.0);
        let mut dx = vec![3.0f32; n * s.in_elems()];
        col2im(&cols, n, &s, &mut dx, None, 1);
        // center pixel participates in all 9 patches, corners in 4
        assert_eq!(dx[4], 9.0);
        assert_eq!(dx[0], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 49.0);
    }

    #[test]
    fn gap_forward_and_grad() {
        // 2 images × 2 positions × 2 channels
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let mut out = vec![0.0f32; 4];
        global_avg_pool(&x, &mut out, 2, 2, 2);
        assert_eq!(out, vec![2.0, 3.0, 20.0, 30.0]);
        let mut dx = vec![9.0f32; 8];
        global_avg_pool_grad(&out, &mut dx, None, 2, 2, 2);
        assert_eq!(dx, vec![1.0, 1.5, 1.0, 1.5, 10.0, 15.0, 10.0, 15.0]);
    }

    #[test]
    fn relu_gate_zeroes_inactive_lanes() {
        let mut d = vec![1.0f32, 2.0, 3.0, -4.0];
        relu_gate(&mut d, &[0.5, 0.0, -1.0, 2.0]);
        assert_eq!(d, vec![1.0, 0.0, 0.0, -4.0]);
    }
}
