//! The native backend's kernel subsystem: cache-blocked, register-tiled
//! f32 dense **and conv** kernels with a naive reference oracle.
//!
//! Three kernels cover the whole dense-chain training step:
//!
//! * [`matmul_bias_act`] — `out = act(h · W + b)` (forward);
//! * [`grad_weights`]    — `dW = hᵀ · dz`, `db = Σᵢ dz` (backward,
//!   weight gradients);
//! * [`grad_input`]      — `dh = relu_gate(h) ⊙ (dz · Wᵀ)` (backward,
//!   input gradients).
//!
//! The conv family extends the same contract to SAME-padded NHWC
//! convolution (the cnn / cnn_lite stacks of Table 3):
//!
//! * [`conv2d_bias_act`] — `out = act(conv2d(x, K) + b)` (forward);
//! * [`conv2d_grad_w`]   — `dK = patchesᵀ · dz`, `db = Σ dz`;
//! * [`conv2d_grad_x`]   — input gradient, ReLU-gated by the layer's
//!   input activation;
//! * [`matmul_dz_wt`]    — plain `dz · Wᵀ` (the linear pooled node);
//! * [`conv::global_avg_pool`] / [`conv::global_avg_pool_grad`].
//!
//! Three implementations sit behind [`KernelConfig`]:
//!
//! * [`gemm`] — the blocked path: weights packed into [`NR`]-wide
//!   column panels (contiguous streaming), [`MR`]×[`NR`] register
//!   tiles, fused bias + ReLU epilogues, and batch-row sharding across
//!   a scoped thread pool ([`pool`]); conv lowers onto the same tiles
//!   via im2col ([`conv`]);
//! * [`simd`] — the same packing/tiling/sharding with explicit
//!   AVX2+FMA microkernels, runtime-detected
//!   (`is_x86_feature_detected!`) and falling back to the blocked path
//!   on other machines; bit-identical to `blocked` for all f32
//!   training math, plus the bf16 fast-scoring forward the inference
//!   fleet uses under a relaxed-tolerance contract;
//! * [`reference`] — the naive row-major loops (triple loops for
//!   dense, direct seven-deep loops for conv) the blocked path is
//!   property-tested against (`tests/kernel_parity.rs`,
//!   `tests/conv_parity.rs`).
//!
//! **Determinism contract.** Every per-element reduction runs in a
//! fixed index order that does not depend on the thread count or on how
//! rows are grouped into register tiles: the forward and `grad_input`
//! kernels are sharded over batch rows (each row's result is computed
//! independently), and `grad_weights` is sharded over `din` so each
//! `dW[k][o]` accumulates batch rows `0..n` sequentially on exactly one
//! thread. Masked-out rows contribute exact zeros to every reduction,
//! so the gathered sub-batch step stays bit-identical to the masked
//! full-batch step — the invariant `NativeBackend::train_step_selected`
//! documents — at any thread count.
//!
//! Environment knobs (read once per backend construction):
//!
//! * `OBFTF_NATIVE_THREADS` — worker threads for the blocked path
//!   (default: available parallelism; `1` disables threading);
//! * `OBFTF_NATIVE_KERNELS` — `simd`, `blocked` (default) or
//!   `reference`; an unrecognized value warns once to stderr and falls
//!   back to `blocked`.

#![allow(clippy::too_many_arguments)] // kernels take flat slices + dims

pub mod conv;
pub mod gemm;
pub mod pool;
pub mod reference;
pub mod simd;

pub use conv::ConvShape;

/// Register-tile rows (batch dimension): each micro-kernel invocation
/// computes `MR` output rows so a packed panel line is reused `MR`
/// times per load.
pub const MR: usize = 4;

/// Register-tile columns (output dimension): the SIMD-friendly lane
/// width. One panel line is `NR` contiguous f32s (a 64-byte cache
/// line), so the inner loops vectorize without gather loads.
pub const NR: usize = 16;

/// Below this many scalar multiply-adds a kernel call runs
/// single-threaded: spawning scoped threads costs more than the work.
pub const PAR_THRESHOLD_FLOPS: usize = 1 << 18;

/// Which kernel implementation a backend dispatches onto.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelFlavour {
    /// Explicit AVX2+FMA microkernels with runtime feature detection;
    /// bit-identical to [`KernelFlavour::Blocked`] for f32 training
    /// math, and falls back to it when the CPU lacks AVX2+FMA.
    Simd,
    /// Blocked/packed register-tiled kernels (the default).
    Blocked,
    /// Naive row-major loops — the property-test oracle, kept
    /// selectable so benches can measure the speedup.
    Reference,
}

impl KernelFlavour {
    /// The `OBFTF_NATIVE_KERNELS` spelling of this flavour.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelFlavour::Simd => "simd",
            KernelFlavour::Blocked => "blocked",
            KernelFlavour::Reference => "reference",
        }
    }
}

/// Resolved kernel configuration for one backend instance.
#[derive(Clone, Copy, Debug)]
pub struct KernelConfig {
    pub flavour: KernelFlavour,
    /// Worker threads for the blocked path (`>= 1`).
    pub threads: usize,
}

impl KernelConfig {
    /// Resolve from the environment: `OBFTF_NATIVE_KERNELS` /
    /// `OBFTF_NATIVE_THREADS`, defaulting to blocked kernels on all
    /// available cores. An unrecognized kernel flavour warns once to
    /// stderr (instead of silently falling back) and uses `blocked`.
    pub fn from_env() -> KernelConfig {
        let flavour = match std::env::var("OBFTF_NATIVE_KERNELS").as_deref() {
            Ok("simd") => KernelFlavour::Simd,
            Ok("blocked") => KernelFlavour::Blocked,
            Ok("reference") | Ok("naive") => KernelFlavour::Reference,
            Ok(other) => {
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "warning: unrecognized OBFTF_NATIVE_KERNELS value {other:?} \
                         (expected simd | blocked | reference); using blocked"
                    );
                });
                KernelFlavour::Blocked
            }
            Err(_) => KernelFlavour::Blocked,
        };
        let threads = std::env::var("OBFTF_NATIVE_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(pool::available_threads);
        KernelConfig { flavour, threads }
    }

    /// SIMD kernels (AVX2+FMA when the CPU has them, blocked scalar
    /// otherwise — bit-identical either way).
    pub fn simd(threads: usize) -> KernelConfig {
        KernelConfig { flavour: KernelFlavour::Simd, threads: threads.max(1) }
    }

    /// Single-threaded blocked kernels (deterministic default for
    /// tests).
    pub fn blocked(threads: usize) -> KernelConfig {
        KernelConfig { flavour: KernelFlavour::Blocked, threads: threads.max(1) }
    }

    /// The naive oracle (always single-threaded).
    pub fn reference() -> KernelConfig {
        KernelConfig { flavour: KernelFlavour::Reference, threads: 1 }
    }

    /// Threads to use for a kernel call of `flops` multiply-adds.
    fn threads_for(&self, flops: usize) -> usize {
        if flops < PAR_THRESHOLD_FLOPS {
            1
        } else {
            self.threads
        }
    }
}

/// Whether this machine can run the AVX2+FMA microkernels — what the
/// `simd` flavour actually executes (false means it transparently runs
/// the scalar blocked path). Surfaced by `obftf config
/// --print-effective`.
pub fn simd_available() -> bool {
    simd::available()
}

/// A free-list of f32 scratch buffers so the per-step working set
/// (activations, head gradients, packed panels) is allocated once and
/// recycled across training steps instead of `Vec`-allocated fresh on
/// every `forward`/`compute_grads` call.
#[derive(Default)]
pub struct Arena {
    free: Vec<Vec<f32>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Check out a zeroed buffer of exactly `len` elements, reusing the
    /// best-fitting recycled buffer when one exists.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let fits = buf.capacity() >= len;
            best = match best {
                None => Some(i),
                Some(j) => {
                    let jfits = self.free[j].capacity() >= len;
                    // prefer the smallest buffer that fits, else the
                    // largest available (it will grow the least)
                    let better = if fits && jfits {
                        buf.capacity() < self.free[j].capacity()
                    } else if fits != jfits {
                        fits
                    } else {
                        buf.capacity() > self.free[j].capacity()
                    };
                    Some(if better { i } else { j })
                }
            };
        }
        let mut buf = match best {
            Some(i) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the free list for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Buffers currently waiting for reuse.
    pub fn idle_buffers(&self) -> usize {
        self.free.len()
    }
}

/// `out = act(h · W + b)`: `h` is `n×din` row-major, `w` is `din×dout`,
/// `b` is `dout`, `out` is `n×dout`. `relu` selects the hidden-layer
/// epilogue (identity on the head).
pub fn matmul_bias_act(
    cfg: &KernelConfig,
    arena: &mut Arena,
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
) {
    debug_assert_eq!(h.len(), n * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    debug_assert_eq!(out.len(), n * dout);
    match cfg.flavour {
        KernelFlavour::Reference => reference::matmul_bias_act(h, w, b, out, n, din, dout, relu),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * din * dout);
            gemm::matmul_bias_act(arena, h, w, b, out, n, din, dout, relu, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * din * dout);
            simd::matmul_bias_act(arena, h, w, b, out, n, din, dout, relu, threads);
        }
    }
}

/// Forward matmul for the *scoring* pass: with `bf16` set the weights
/// and activations round to bf16 packed panels (f32 accumulation,
/// relaxed tolerance — see [`simd::matmul_bias_act_bf16`]) regardless
/// of the configured flavour; otherwise identical to
/// [`matmul_bias_act`]. Only `NativeBackend::fwd_loss` routes here —
/// training and eval math never does.
pub fn matmul_bias_act_scored(
    cfg: &KernelConfig,
    arena: &mut Arena,
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
    bf16: bool,
) {
    if bf16 {
        debug_assert_eq!(h.len(), n * din);
        debug_assert_eq!(w.len(), din * dout);
        let threads = cfg.threads_for(n * din * dout);
        simd::matmul_bias_act_bf16(arena, h, w, b, out, n, din, dout, relu, threads);
    } else {
        matmul_bias_act(cfg, arena, h, w, b, out, n, din, dout, relu);
    }
}

/// `dw = hᵀ · dz` and `db = Σᵢ dz[i]`: `h` is `n×din`, `dz` is
/// `n×dout`, `dw` is `din×dout`, `db` is `dout`. Rows accumulate in
/// ascending batch order for every output element.
pub fn grad_weights(
    cfg: &KernelConfig,
    arena: &mut Arena,
    h: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(h.len(), n * din);
    debug_assert_eq!(dz.len(), n * dout);
    debug_assert_eq!(dw.len(), din * dout);
    debug_assert_eq!(db.len(), dout);
    match cfg.flavour {
        KernelFlavour::Reference => reference::grad_weights(h, dz, dw, db, n, din, dout),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * din * dout);
            gemm::grad_weights(arena, h, dz, dw, db, n, din, dout, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * din * dout);
            simd::grad_weights(arena, h, dz, dw, db, n, din, dout, threads);
        }
    }
}

/// `dh[i][k] = (h[i][k] > 0) · Σₒ dz[i][o] · w[k][o]` — the ReLU-gated
/// input gradient `dz · Wᵀ`. `h` here is the *activation* of the layer
/// whose input gradient is being computed (acts > 0 ⟺ pre-act > 0).
pub fn grad_input(
    cfg: &KernelConfig,
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    h: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(dz.len(), n * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(h.len(), n * din);
    debug_assert_eq!(dh.len(), n * din);
    match cfg.flavour {
        KernelFlavour::Reference => reference::grad_input(dz, w, h, dh, n, din, dout),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * din * dout);
            gemm::grad_input(arena, dz, w, h, dh, n, din, dout, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * din * dout);
            simd::grad_input(arena, dz, w, h, dh, n, din, dout, threads);
        }
    }
}

/// Plain `dh = dz · Wᵀ` with **no** activation gate — the gradient
/// through a linear node (the conv chain's global-average-pool output
/// feeding the dense head). Same shapes as [`grad_input`].
pub fn matmul_dz_wt(
    cfg: &KernelConfig,
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    debug_assert_eq!(dz.len(), n * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(dh.len(), n * din);
    match cfg.flavour {
        KernelFlavour::Reference => reference::dz_wt(dz, w, dh, n, din, dout),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * din * dout);
            gemm::dz_wt(arena, dz, w, dh, n, din, dout, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * din * dout);
            simd::dz_wt(arena, dz, w, dh, n, din, dout, threads);
        }
    }
}

/// `out = act(conv2d(x, k) + b)` over `n` SAME-padded NHWC images:
/// `x` is `n×h×w×cin`, `k` is HWIO `kh×kw×cin×cout`, `b` is `cout`,
/// `out` is `n×oh×ow×cout` (all flat, row-major).
pub fn conv2d_bias_act(
    cfg: &KernelConfig,
    arena: &mut Arena,
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
) {
    debug_assert_eq!(x.len(), n * s.in_elems());
    debug_assert_eq!(k.len(), s.patch_len() * s.cout);
    debug_assert_eq!(b.len(), s.cout);
    debug_assert_eq!(out.len(), n * s.out_elems());
    match cfg.flavour {
        KernelFlavour::Reference => reference::conv2d_bias_act(x, k, b, out, n, s, relu),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_bias_act_blocked(arena, x, k, b, out, n, s, relu, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_bias_act_simd(arena, x, k, b, out, n, s, relu, threads);
        }
    }
}

/// Conv forward for the *scoring* pass — the conv analogue of
/// [`matmul_bias_act_scored`]: with `bf16` set the im2col patches and
/// weights round to bf16 panels regardless of flavour.
pub fn conv2d_bias_act_scored(
    cfg: &KernelConfig,
    arena: &mut Arena,
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
    bf16: bool,
) {
    if bf16 {
        debug_assert_eq!(x.len(), n * s.in_elems());
        debug_assert_eq!(out.len(), n * s.out_elems());
        let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
        conv::conv2d_bias_act_bf16(arena, x, k, b, out, n, s, relu, threads);
    } else {
        conv2d_bias_act(cfg, arena, x, k, b, out, n, s, relu);
    }
}

/// `dk = patchesᵀ · dz`, `db = Σ dz` for one conv layer: `x` is the
/// layer input (`n×h×w×cin`), `dz` the output gradient
/// (`n×oh×ow×cout`), `dk` HWIO-shaped, `db` `cout`. Patch rows reduce
/// in ascending `(image, oy, ox)` order for every output element.
pub fn conv2d_grad_w(
    cfg: &KernelConfig,
    arena: &mut Arena,
    x: &[f32],
    dz: &[f32],
    dk: &mut [f32],
    db: &mut [f32],
    n: usize,
    s: &ConvShape,
) {
    debug_assert_eq!(x.len(), n * s.in_elems());
    debug_assert_eq!(dz.len(), n * s.out_elems());
    debug_assert_eq!(dk.len(), s.patch_len() * s.cout);
    debug_assert_eq!(db.len(), s.cout);
    match cfg.flavour {
        KernelFlavour::Reference => reference::conv2d_grad_w(x, dz, dk, db, n, s),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_grad_w_blocked(arena, x, dz, dk, db, n, s, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_grad_w_simd(arena, x, dz, dk, db, n, s, threads);
        }
    }
}

/// Conv input gradient `dx = relu_gate(h_in) ⊙ scatter(dz · Kᵀ)`:
/// `h_in` is the layer's input activation (the previous layer's
/// post-ReLU output), `dx` is `n×h×w×cin` and fully overwritten.
pub fn conv2d_grad_x(
    cfg: &KernelConfig,
    arena: &mut Arena,
    dz: &[f32],
    k: &[f32],
    h_in: &[f32],
    dx: &mut [f32],
    n: usize,
    s: &ConvShape,
) {
    debug_assert_eq!(dz.len(), n * s.out_elems());
    debug_assert_eq!(k.len(), s.patch_len() * s.cout);
    debug_assert_eq!(h_in.len(), n * s.in_elems());
    debug_assert_eq!(dx.len(), n * s.in_elems());
    match cfg.flavour {
        KernelFlavour::Reference => reference::conv2d_grad_x(dz, k, h_in, dx, n, s),
        KernelFlavour::Blocked => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_grad_x_blocked(arena, dz, k, h_in, dx, n, s, threads);
        }
        KernelFlavour::Simd => {
            let threads = cfg.threads_for(n * s.positions() * s.patch_len() * s.cout);
            conv::conv2d_grad_x_simd(arena, dz, k, h_in, dx, n, s, threads);
        }
    }
}

/// Multiply-add FLOPs of one forward pass over a conv→GAP→dense chain:
/// `shapes` are the conv layers, `head = (c_last, out_width)`.
pub fn conv_fwd_flops(shapes: &[ConvShape], head: (usize, usize), n: usize) -> f64 {
    let convs: f64 = shapes.iter().map(|s| s.fwd_flops(n)).sum();
    convs + 2.0 * n as f64 * head.0 as f64 * head.1 as f64
}

/// FLOPs of one full conv train step: forward + dK (same cost) per
/// layer, plus dx for every layer but the first, plus the dense head's
/// forward/dW/dh.
pub fn conv_train_flops(shapes: &[ConvShape], head: (usize, usize), n: usize) -> f64 {
    let convs: f64 = shapes
        .iter()
        .enumerate()
        .map(|(l, s)| s.fwd_flops(n) * if l == 0 { 2.0 } else { 3.0 })
        .sum();
    let head_flops = 2.0 * n as f64 * head.0 as f64 * head.1 as f64;
    convs + 3.0 * head_flops
}

/// Multiply-add FLOPs (counting mul and add separately) of one forward
/// pass over a dense chain with layer widths `dims`, batch `n`.
pub fn dense_fwd_flops(dims: &[usize], n: usize) -> f64 {
    dims.windows(2).map(|p| 2.0 * n as f64 * p[0] as f64 * p[1] as f64).sum()
}

/// FLOPs of one full train step (forward + dW + dh backprop) over a
/// dense chain: the backward roughly doubles the forward, minus the
/// first layer's `dh` which is never materialized.
pub fn dense_train_flops(dims: &[usize], n: usize) -> f64 {
    let fwd = dense_fwd_flops(dims, n);
    let dh: f64 = dims
        .windows(2)
        .skip(1)
        .map(|p| 2.0 * n as f64 * p[0] as f64 * p[1] as f64)
        .sum();
    2.0 * fwd + dh
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_recycles_buffers() {
        let mut a = Arena::new();
        let b1 = a.take(100);
        assert_eq!(b1.len(), 100);
        assert!(b1.iter().all(|&v| v == 0.0));
        let cap = b1.capacity();
        a.put(b1);
        assert_eq!(a.idle_buffers(), 1);
        // a smaller request reuses the same allocation
        let b2 = a.take(40);
        assert_eq!(b2.len(), 40);
        assert_eq!(b2.capacity(), cap);
        assert_eq!(a.idle_buffers(), 0);
        a.put(b2);
        // zeroed even after being dirtied
        let mut b3 = a.take(40);
        b3.iter_mut().for_each(|v| *v = 7.0);
        a.put(b3);
        let b4 = a.take(40);
        assert!(b4.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn arena_prefers_best_fit() {
        let mut a = Arena::new();
        let small = a.take(10);
        let big = a.take(1000);
        let (smallcap, bigcap) = (small.capacity(), big.capacity());
        a.put(big);
        a.put(small);
        let got = a.take(8);
        assert_eq!(got.capacity(), smallcap, "smallest fitting buffer wins");
        a.put(got);
        let got = a.take(500);
        assert_eq!(got.capacity(), bigcap);
    }

    #[test]
    fn config_resolves_sane_defaults() {
        let cfg = KernelConfig::blocked(0);
        assert_eq!(cfg.threads, 1);
        assert_eq!(cfg.flavour, KernelFlavour::Blocked);
        let s = KernelConfig::simd(0);
        assert_eq!(s.threads, 1);
        assert_eq!(s.flavour, KernelFlavour::Simd);
        assert_eq!(s.flavour.as_str(), "simd");
        assert_eq!(KernelFlavour::Blocked.as_str(), "blocked");
        assert_eq!(KernelFlavour::Reference.as_str(), "reference");
        let r = KernelConfig::reference();
        assert_eq!(r.threads, 1);
        // tiny calls never thread
        let cfg = KernelConfig::blocked(8);
        assert_eq!(cfg.threads_for(100), 1);
        assert_eq!(cfg.threads_for(PAR_THRESHOLD_FLOPS), 8);
        let env = KernelConfig::from_env();
        assert!(env.threads >= 1);
    }

    #[test]
    fn flop_model_counts_cnn_lite() {
        // cnn_lite: 16×16×3 → conv(16, s2) → conv(32, s2) → GAP → 100
        let s1 = ConvShape::same(16, 16, 3, 16, 3, 3, 2);
        let s2 = ConvShape::same(s1.oh, s1.ow, 16, 32, 3, 3, 2);
        let shapes = [s1, s2];
        let n = 128.0;
        let fwd = conv_fwd_flops(&shapes, (32, 100), 128);
        let want = 2.0 * n * (64.0 * 27.0 * 16.0 + 16.0 * 144.0 * 32.0 + 32.0 * 100.0);
        assert_eq!(fwd, want);
        let train = conv_train_flops(&shapes, (32, 100), 128);
        // backward = forward again (dK/dW) + dx for every non-first
        // conv layer + the head's dh (dz·Wᵀ)
        let dx2 = 2.0 * n * 16.0 * 144.0 * 32.0;
        let head_dh = 2.0 * n * 32.0 * 100.0;
        assert_eq!(train, 2.0 * fwd + dx2 + head_dh);
    }

    #[test]
    fn flop_model_counts_mlp() {
        // 784-256-256-10 at n=128: fwd = 2n(784·256 + 256·256 + 256·10)
        let dims = [784, 256, 256, 10];
        let fwd = dense_fwd_flops(&dims, 128);
        assert_eq!(fwd, 2.0 * 128.0 * (784.0 * 256.0 + 256.0 * 256.0 + 256.0 * 10.0));
        let train = dense_train_flops(&dims, 128);
        let dh = 2.0 * 128.0 * (256.0 * 256.0 + 256.0 * 10.0);
        assert_eq!(train, 2.0 * fwd + dh);
    }
}
