//! Naive row-major kernels: the PR-1 `NativeBackend` loops, kept
//! verbatim as the property-test oracle for the blocked path
//! (`tests/kernel_parity.rs`) and selectable at runtime via
//! `OBFTF_NATIVE_KERNELS=reference` so benches can measure the
//! blocked-kernel speedup against the exact code it replaced.

/// `out = act(h · W + b)`, one batch row at a time (ref.py
/// `matmul_bias_act`).
pub fn matmul_bias_act(
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
) {
    for i in 0..n {
        let row = &h[i * din..(i + 1) * din];
        let orow = &mut out[i * dout..(i + 1) * dout];
        orow.copy_from_slice(b);
        for (k, &hv) in row.iter().enumerate() {
            if hv == 0.0 {
                continue; // adding 0·w is exact; skipping is too
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += hv * wv;
            }
        }
    }
    if relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// `dw = hᵀ · dz`, `db = Σᵢ dz[i]`, accumulating batch rows in
/// ascending order.
pub fn grad_weights(
    h: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    dw.fill(0.0);
    db.fill(0.0);
    for i in 0..n {
        let drow = &dz[i * dout..(i + 1) * dout];
        for (dbv, &dv) in db.iter_mut().zip(drow) {
            *dbv += dv;
        }
        let hrow = &h[i * din..(i + 1) * din];
        for (k, &hv) in hrow.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let wrow = &mut dw[k * dout..(k + 1) * dout];
            for (g, &dv) in wrow.iter_mut().zip(drow) {
                *g += hv * dv;
            }
        }
    }
}

/// `dh[i][k] = (h[i][k] > 0) · Σₒ dz[i][o] · w[k][o]` — ReLU-gated
/// `dz · Wᵀ`; `h` is the activation of the layer whose input gradient
/// is computed.
pub fn grad_input(
    dz: &[f32],
    w: &[f32],
    h: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    dh.fill(0.0);
    for i in 0..n {
        let drow = &dz[i * dout..(i + 1) * dout];
        let hrow = &h[i * din..(i + 1) * din];
        let orow = &mut dh[i * din..(i + 1) * din];
        for (k, o) in orow.iter_mut().enumerate() {
            if hrow[k] <= 0.0 {
                continue; // ReLU gate
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            let mut s = 0.0f32;
            for (&dv, &wv) in drow.iter().zip(wrow) {
                s += dv * wv;
            }
            *o = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matmul_by_hand() {
        // h = [[1, 2]], w = [[1, 0], [0, 1]], b = [10, 20]
        let h = [1.0f32, 2.0];
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let b = [10.0f32, 20.0];
        let mut out = [0.0f32; 2];
        matmul_bias_act(&h, &w, &b, &mut out, 1, 2, 2, false);
        assert_eq!(out, [11.0, 22.0]);
        // relu clamps negatives
        let b = [-5.0f32, 20.0];
        matmul_bias_act(&h, &w, &b, &mut out, 1, 2, 2, true);
        assert_eq!(out, [0.0, 22.0]);
    }

    #[test]
    fn grad_weights_by_hand() {
        // two rows: h = [[1, 0], [2, 1]], dz = [[3], [4]]
        let h = [1.0f32, 0.0, 2.0, 1.0];
        let dz = [3.0f32, 4.0];
        let mut dw = [0.0f32; 2];
        let mut db = [0.0f32; 1];
        grad_weights(&h, &dz, &mut dw, &mut db, 2, 2, 1);
        assert_eq!(dw, [1.0 * 3.0 + 2.0 * 4.0, 0.0 * 3.0 + 1.0 * 4.0]);
        assert_eq!(db, [7.0]);
    }

    #[test]
    fn grad_input_gates_on_activation() {
        // h = [[1, -1]] (second unit inactive), w = [[1], [1]], dz = [[5]]
        let h = [1.0f32, -1.0];
        let w = [1.0f32, 1.0];
        let dz = [5.0f32];
        let mut dh = [9.0f32; 2];
        grad_input(&dz, &w, &h, &mut dh, 1, 2, 1);
        assert_eq!(dh, [5.0, 0.0]);
    }
}
