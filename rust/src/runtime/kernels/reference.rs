//! Naive row-major kernels: the PR-1 `NativeBackend` loops, kept
//! verbatim as the property-test oracle for the blocked path
//! (`tests/kernel_parity.rs`) and selectable at runtime via
//! `OBFTF_NATIVE_KERNELS=reference` so benches can measure the
//! blocked-kernel speedup against the exact code it replaced.
//!
//! The conv family (`conv2d_*`) follows the same contract: direct
//! seven-deep loops over the SAME-padded geometry of
//! [`super::conv::ConvShape`], no im2col, no packing — the oracle the
//! blocked im2col/GEMM lowering is property-tested against
//! (`tests/conv_parity.rs`).

use super::conv::{relu_gate, ConvShape};

/// `out = act(h · W + b)`, one batch row at a time (ref.py
/// `matmul_bias_act`).
pub fn matmul_bias_act(
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
) {
    for i in 0..n {
        let row = &h[i * din..(i + 1) * din];
        let orow = &mut out[i * dout..(i + 1) * dout];
        orow.copy_from_slice(b);
        for (k, &hv) in row.iter().enumerate() {
            if hv == 0.0 {
                continue; // adding 0·w is exact; skipping is too
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += hv * wv;
            }
        }
    }
    if relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// `dw = hᵀ · dz`, `db = Σᵢ dz[i]`, accumulating batch rows in
/// ascending order.
pub fn grad_weights(
    h: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    dw.fill(0.0);
    db.fill(0.0);
    for i in 0..n {
        let drow = &dz[i * dout..(i + 1) * dout];
        for (dbv, &dv) in db.iter_mut().zip(drow) {
            *dbv += dv;
        }
        let hrow = &h[i * din..(i + 1) * din];
        for (k, &hv) in hrow.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let wrow = &mut dw[k * dout..(k + 1) * dout];
            for (g, &dv) in wrow.iter_mut().zip(drow) {
                *g += hv * dv;
            }
        }
    }
}

/// `dh[i][k] = (h[i][k] > 0) · Σₒ dz[i][o] · w[k][o]` — ReLU-gated
/// `dz · Wᵀ`; `h` is the activation of the layer whose input gradient
/// is computed.
pub fn grad_input(
    dz: &[f32],
    w: &[f32],
    h: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
) {
    dh.fill(0.0);
    for i in 0..n {
        let drow = &dz[i * dout..(i + 1) * dout];
        let hrow = &h[i * din..(i + 1) * din];
        let orow = &mut dh[i * din..(i + 1) * din];
        for (k, o) in orow.iter_mut().enumerate() {
            if hrow[k] <= 0.0 {
                continue; // ReLU gate
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            let mut s = 0.0f32;
            for (&dv, &wv) in drow.iter().zip(wrow) {
                s += dv * wv;
            }
            *o = s;
        }
    }
}

/// Plain `dh = dz · Wᵀ` (no ReLU gate): the head-to-pool gradient of
/// the conv chain, where the pooled activation is a linear node.
pub fn dz_wt(dz: &[f32], w: &[f32], dh: &mut [f32], n: usize, din: usize, dout: usize) {
    for i in 0..n {
        let drow = &dz[i * dout..(i + 1) * dout];
        let orow = &mut dh[i * din..(i + 1) * din];
        for (k, o) in orow.iter_mut().enumerate() {
            let wrow = &w[k * dout..(k + 1) * dout];
            let mut s = 0.0f32;
            for (&dv, &wv) in drow.iter().zip(wrow) {
                s += dv * wv;
            }
            *o = s;
        }
    }
}

/// Direct `out = act(conv2d(x, k) + b)` over SAME-padded NHWC images,
/// HWIO weights; one output position at a time (the conv oracle).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bias_act(
    x: &[f32],
    k: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    s: &ConvShape,
    relu: bool,
) {
    for i in 0..n {
        let img = &x[i * s.in_elems()..(i + 1) * s.in_elems()];
        for oy in 0..s.oh {
            for ox in 0..s.ow {
                let row = (i * s.oh + oy) * s.ow + ox;
                let orow = &mut out[row * s.cout..(row + 1) * s.cout];
                orow.copy_from_slice(b);
                for ky in 0..s.kh {
                    let y = (oy * s.stride + ky) as isize - s.pad_top as isize;
                    if y < 0 || y as usize >= s.h {
                        continue;
                    }
                    for kx in 0..s.kw {
                        let xx = (ox * s.stride + kx) as isize - s.pad_left as isize;
                        if xx < 0 || xx as usize >= s.w {
                            continue;
                        }
                        for c in 0..s.cin {
                            let hv = img[(y as usize * s.w + xx as usize) * s.cin + c];
                            if hv == 0.0 {
                                continue; // adding 0·w is exact; skipping is too
                            }
                            let wat = ((ky * s.kw + kx) * s.cin + c) * s.cout;
                            let wrow = &k[wat..wat + s.cout];
                            for (o, &wv) in orow.iter_mut().zip(wrow) {
                                *o += hv * wv;
                            }
                        }
                    }
                }
                if relu {
                    for v in orow.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// Direct `dk = Σ x-patch ⊗ dz`, `db = Σ dz` (sum over batch and
/// spatial positions, patch rows reduced in ascending order).
pub fn conv2d_grad_w(
    x: &[f32],
    dz: &[f32],
    dk: &mut [f32],
    db: &mut [f32],
    n: usize,
    s: &ConvShape,
) {
    dk.fill(0.0);
    db.fill(0.0);
    for i in 0..n {
        let img = &x[i * s.in_elems()..(i + 1) * s.in_elems()];
        for oy in 0..s.oh {
            for ox in 0..s.ow {
                let row = (i * s.oh + oy) * s.ow + ox;
                let drow = &dz[row * s.cout..(row + 1) * s.cout];
                for (dbv, &dv) in db.iter_mut().zip(drow) {
                    *dbv += dv;
                }
                for ky in 0..s.kh {
                    let y = (oy * s.stride + ky) as isize - s.pad_top as isize;
                    if y < 0 || y as usize >= s.h {
                        continue;
                    }
                    for kx in 0..s.kw {
                        let xx = (ox * s.stride + kx) as isize - s.pad_left as isize;
                        if xx < 0 || xx as usize >= s.w {
                            continue;
                        }
                        for c in 0..s.cin {
                            let hv = img[(y as usize * s.w + xx as usize) * s.cin + c];
                            if hv == 0.0 {
                                continue;
                            }
                            let wat = ((ky * s.kw + kx) * s.cin + c) * s.cout;
                            let krow = &mut dk[wat..wat + s.cout];
                            for (g, &dv) in krow.iter_mut().zip(drow) {
                                *g += hv * dv;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Direct input gradient: scatter `dz · Wᵀ` back onto the input image
/// in ascending `(oy, ox, ky, kx)` order, then ReLU-gate by the
/// layer's input activation `h_in`. `dx` is fully overwritten.
pub fn conv2d_grad_x(
    dz: &[f32],
    k: &[f32],
    h_in: &[f32],
    dx: &mut [f32],
    n: usize,
    s: &ConvShape,
) {
    dx.fill(0.0);
    for i in 0..n {
        let img = &mut dx[i * s.in_elems()..(i + 1) * s.in_elems()];
        for oy in 0..s.oh {
            for ox in 0..s.ow {
                let row = (i * s.oh + oy) * s.ow + ox;
                let drow = &dz[row * s.cout..(row + 1) * s.cout];
                for ky in 0..s.kh {
                    let y = (oy * s.stride + ky) as isize - s.pad_top as isize;
                    if y < 0 || y as usize >= s.h {
                        continue;
                    }
                    for kx in 0..s.kw {
                        let xx = (ox * s.stride + kx) as isize - s.pad_left as isize;
                        if xx < 0 || xx as usize >= s.w {
                            continue;
                        }
                        for c in 0..s.cin {
                            let wat = ((ky * s.kw + kx) * s.cin + c) * s.cout;
                            let wrow = &k[wat..wat + s.cout];
                            let mut sum = 0.0f32;
                            for (&dv, &wv) in drow.iter().zip(wrow) {
                                sum += dv * wv;
                            }
                            img[(y as usize * s.w + xx as usize) * s.cin + c] += sum;
                        }
                    }
                }
            }
        }
    }
    relu_gate(dx, h_in);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matmul_by_hand() {
        // h = [[1, 2]], w = [[1, 0], [0, 1]], b = [10, 20]
        let h = [1.0f32, 2.0];
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let b = [10.0f32, 20.0];
        let mut out = [0.0f32; 2];
        matmul_bias_act(&h, &w, &b, &mut out, 1, 2, 2, false);
        assert_eq!(out, [11.0, 22.0]);
        // relu clamps negatives
        let b = [-5.0f32, 20.0];
        matmul_bias_act(&h, &w, &b, &mut out, 1, 2, 2, true);
        assert_eq!(out, [0.0, 22.0]);
    }

    #[test]
    fn grad_weights_by_hand() {
        // two rows: h = [[1, 0], [2, 1]], dz = [[3], [4]]
        let h = [1.0f32, 0.0, 2.0, 1.0];
        let dz = [3.0f32, 4.0];
        let mut dw = [0.0f32; 2];
        let mut db = [0.0f32; 1];
        grad_weights(&h, &dz, &mut dw, &mut db, 2, 2, 1);
        assert_eq!(dw, [1.0 * 3.0 + 2.0 * 4.0, 0.0 * 3.0 + 1.0 * 4.0]);
        assert_eq!(db, [7.0]);
    }

    #[test]
    fn grad_input_gates_on_activation() {
        // h = [[1, -1]] (second unit inactive), w = [[1], [1]], dz = [[5]]
        let h = [1.0f32, -1.0];
        let w = [1.0f32, 1.0];
        let dz = [5.0f32];
        let mut dh = [9.0f32; 2];
        grad_input(&dz, &w, &h, &mut dh, 1, 2, 1);
        assert_eq!(dh, [5.0, 0.0]);
    }

    #[test]
    fn dz_wt_is_ungated() {
        let w = [1.0f32, 2.0]; // 2×1
        let dz = [5.0f32];
        let mut dh = [0.0f32; 2];
        dz_wt(&dz, &w, &mut dh, 1, 2, 1);
        assert_eq!(dh, [5.0, 10.0]);
    }

    #[test]
    fn conv_identity_kernel_recovers_input() {
        // 1×1 kernel, stride 1, identity weight: conv is a pointwise
        // dense map; with w = I and b = 0 the output is the input.
        let s = ConvShape::same(2, 2, 2, 2, 1, 1, 1);
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let k = [1.0f32, 0.0, 0.0, 1.0]; // [1,1,2,2] identity
        let b = [0.0f32; 2];
        let mut out = [9.0f32; 8];
        conv2d_bias_act(&x, &k, &b, &mut out, 1, &s, false);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_averaging_kernel_on_padded_edge() {
        // 3×3 ones kernel over a 2×2 single-channel image, stride 1:
        // every output = sum of the whole image region it covers.
        let s = ConvShape::same(2, 2, 1, 1, 3, 3, 1);
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let k = [1.0f32; 9];
        let b = [0.0f32];
        let mut out = [0.0f32; 4];
        conv2d_bias_act(&x, &k, &b, &mut out, 1, &s, false);
        // SAME pad (top 1, left 1): each 2×2 output sees all 4 pixels
        assert_eq!(out, [10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn conv_grad_w_by_hand() {
        // 1×1 conv = dense over positions: dk = Σ_pos x·dz, db = Σ dz
        let s = ConvShape::same(1, 2, 1, 1, 1, 1, 1);
        let x = [3.0f32, 4.0];
        let dz = [0.5f32, 0.25];
        let (mut dk, mut db) = ([0.0f32; 1], [0.0f32; 1]);
        conv2d_grad_w(&x, &dz, &mut dk, &mut db, 1, &s);
        assert_eq!(dk, [3.0 * 0.5 + 4.0 * 0.25]);
        assert_eq!(db, [0.75]);
    }

    #[test]
    fn conv_grad_x_gates_and_scatters() {
        // 1×1 conv, w = [2]: dx = 2·dz, gated by h_in
        let s = ConvShape::same(1, 2, 1, 1, 1, 1, 1);
        let dz = [5.0f32, 7.0];
        let k = [2.0f32];
        let h_in = [1.0f32, -1.0];
        let mut dx = [9.0f32; 2];
        conv2d_grad_x(&dz, &k, &h_in, &mut dx, 1, &s);
        assert_eq!(dx, [10.0, 0.0]);
    }
}
