//! Explicit AVX2+FMA microkernels for the [`MR`]×[`NR`] register tile,
//! plus the bf16 fast-scoring GEMM.
//!
//! Two families live here, with very different numeric contracts:
//!
//! * **f32 training kernels** ([`matmul_bias_act`], [`grad_weights`],
//!   [`dz_wt`], [`grad_input`]) — the same packing, tiling, sharding
//!   and per-element reduction order as [`super::gemm`], with the inner
//!   loops written as explicit 8-lane AVX2 intrinsics. They are
//!   **bit-identical** to the scalar blocked path: every lane performs
//!   the same `mul` then `add` (never a fused `fmadd`, whose single
//!   rounding would diverge), the ReLU epilogue is a `cmp lt` +
//!   `andnot` (preserving `-0.0` and NaN exactly like the scalar
//!   `if *v < 0.0`), and remainder columns go through the same stack
//!   tile copy. The house determinism invariant — fixed per-element
//!   reduction order, thread-count invariance, gathered == masked —
//!   therefore holds unchanged, and `tests/kernel_parity.rs` pins
//!   `simd` bitwise-equal to `blocked`.
//!
//! * **bf16 fast-scoring** ([`matmul_bias_act_bf16`]) — the
//!   inference-fleet forward only. Weights and activations are packed
//!   as bf16 (round-to-nearest-even, half the memory traffic on a
//!   bandwidth-bound scoring pass) and accumulated in f32, with FMA
//!   allowed since the contract is relaxed-tolerance against the f32
//!   forward, not bitwise. Training math never routes through this
//!   path.
//!
//! Every public entry point checks [`available`] at runtime
//! (`is_x86_feature_detected!`) and falls back to the scalar blocked
//! path when AVX2+FMA is missing or the target is not x86_64, so
//! `OBFTF_NATIVE_KERNELS=simd` is safe on any machine.

#![allow(clippy::too_many_arguments)] // kernels take flat slices + dims

use super::gemm;
use super::pool::par_rows;
use super::{Arena, MR, NR};

/// Whether the AVX2+FMA microkernels can run on this machine (the
/// detection itself is cached by std after the first probe).
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human-readable CPU feature summary for `obftf config
/// --print-effective`.
pub fn cpu_features() -> &'static str {
    if available() {
        "avx2+fma"
    } else {
        "avx2+fma unavailable (scalar blocked fallback)"
    }
}

// ---------------------------------------------------------------------------
// bf16 conversions (shared by the AVX2 and scalar scoring paths, so the
// packed operands are identical bits on every machine). The canonical
// definitions live in `data::tensor` — the wire codec uses the same
// rounding for `param_precision = bf16` broadcasts — re-exported here
// for the kernel call sites.
// ---------------------------------------------------------------------------

pub use crate::data::tensor::{bf16_to_f32, f32_to_bf16};

/// View the first `len` u16 slots of an f32 arena buffer. bf16 panels
/// ride the f32 [`Arena`] (alignment 4 ≥ 2, zeroed f32 = bf16 +0.0) so
/// scoring scratch recycles across steps like every other buffer.
fn as_u16_mut(buf: &mut [f32], len: usize) -> &mut [u16] {
    debug_assert!(buf.len() * 2 >= len);
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u16, len) }
}

fn as_u16(buf: &[f32], len: usize) -> &[u16] {
    debug_assert!(buf.len() * 2 >= len);
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u16, len) }
}

/// Pack a `rows×cols` row-major f32 matrix into bf16 `NR`-wide column
/// panels — the bf16 analogue of [`gemm::pack_panels`], zero-padded.
fn pack_panels_bf16(src: &[f32], rows: usize, cols: usize, dst: &mut [u16]) {
    let npanels = cols.div_ceil(NR);
    for p in 0..npanels {
        let o0 = p * NR;
        let valid = NR.min(cols - o0);
        let panel = &mut dst[p * rows * NR..(p + 1) * rows * NR];
        for (r, line) in panel.chunks_exact_mut(NR).enumerate() {
            for (c, slot) in line.iter_mut().enumerate().take(valid) {
                *slot = f32_to_bf16(src[r * cols + o0 + c]);
            }
            line[valid..].fill(0);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 microkernels (x86_64 only; every caller guards on `available()`)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::NR;
    use super::bf16_to_f32;
    use std::arch::x86_64::*;

    /// Forward microkernel: `M` batch rows × one `NR`-wide panel, bias
    /// in registers, optional fused ReLU. Bit-identical to the scalar
    /// tile in [`super::gemm`]: separate `mul`+`add` per lane (no FMA),
    /// ReLU via `cmp(v, 0, LT_OQ)` + `andnot` (keeps `-0.0` and NaN
    /// exactly like the scalar `if *v < 0.0 { *v = 0.0 }`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mk_forward<const M: usize>(
        h: &[f32],
        i0: usize,
        din: usize,
        panel: &[f32],
        bias: &[f32],
        out: &mut [f32],
        dout: usize,
        o0: usize,
        valid: usize,
        relu: bool,
    ) {
        let mut lo = [_mm256_loadu_ps(bias.as_ptr()); M];
        let mut hi = [_mm256_loadu_ps(bias.as_ptr().add(8)); M];
        for (k, line) in panel.chunks_exact(NR).enumerate() {
            let wlo = _mm256_loadu_ps(line.as_ptr());
            let whi = _mm256_loadu_ps(line.as_ptr().add(8));
            for (r, (al, ah)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let hv = _mm256_set1_ps(*h.get_unchecked((i0 + r) * din + k));
                *al = _mm256_add_ps(*al, _mm256_mul_ps(hv, wlo));
                *ah = _mm256_add_ps(*ah, _mm256_mul_ps(hv, whi));
            }
        }
        let zero = _mm256_setzero_ps();
        let mut tile = [0.0f32; NR];
        for (r, (al, ah)) in lo.iter().zip(hi.iter()).enumerate() {
            let (mut vl, mut vh) = (*al, *ah);
            if relu {
                vl = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(vl, zero), vl);
                vh = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(vh, zero), vh);
            }
            _mm256_storeu_ps(tile.as_mut_ptr(), vl);
            _mm256_storeu_ps(tile.as_mut_ptr().add(8), vh);
            let at = (i0 + r) * dout + o0;
            out[at..at + valid].copy_from_slice(&tile[..valid]);
        }
    }

    /// Weight-gradient microkernel: `M` rows of `dW` × one `NR`-wide
    /// `dz` panel, reducing batch rows `0..n` in ascending order — the
    /// same order and `mul`+`add` lanes as the scalar tile.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mk_grad_w<const M: usize>(
        ht: &[f32],
        n: usize,
        k0: usize,
        dzpan: &[f32],
        chunk: &mut [f32],
        k0loc: usize,
        dout: usize,
        o0: usize,
        valid: usize,
    ) {
        let mut lo = [_mm256_setzero_ps(); M];
        let mut hi = [_mm256_setzero_ps(); M];
        for (i, line) in dzpan.chunks_exact(NR).enumerate() {
            let dlo = _mm256_loadu_ps(line.as_ptr());
            let dhi = _mm256_loadu_ps(line.as_ptr().add(8));
            for (r, (al, ah)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let hv = _mm256_set1_ps(*ht.get_unchecked((k0 + r) * n + i));
                *al = _mm256_add_ps(*al, _mm256_mul_ps(hv, dlo));
                *ah = _mm256_add_ps(*ah, _mm256_mul_ps(hv, dhi));
            }
        }
        let mut tile = [0.0f32; NR];
        for (r, (al, ah)) in lo.iter().zip(hi.iter()).enumerate() {
            _mm256_storeu_ps(tile.as_mut_ptr(), *al);
            _mm256_storeu_ps(tile.as_mut_ptr().add(8), *ah);
            let at = (k0loc + r) * dout + o0;
            chunk[at..at + valid].copy_from_slice(&tile[..valid]);
        }
    }

    /// `dst[c] += dv * wtline[c]` over a full `din`-wide Wᵀ line — the
    /// vectorized inner axpy of the `dz·Wᵀ` kernel. The 8-lane body
    /// plus scalar tail performs the identical `mul`+`add` on each
    /// element exactly once, in ascending order.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(dst: &mut [f32], wtline: &[f32], dv: f32) {
        let din = dst.len();
        let dvb = _mm256_set1_ps(dv);
        let mut c = 0;
        while c + 8 <= din {
            let a = _mm256_loadu_ps(dst.as_ptr().add(c));
            let w = _mm256_loadu_ps(wtline.as_ptr().add(c));
            _mm256_storeu_ps(dst.as_mut_ptr().add(c), _mm256_add_ps(a, _mm256_mul_ps(dvb, w)));
            c += 8;
        }
        for (a, &wv) in dst[c..].iter_mut().zip(&wtline[c..]) {
            *a += dv * wv;
        }
    }

    /// ReLU gate `if hv <= 0.0 { *d = 0.0 }` over one activation row:
    /// `cmp(hv, 0, LE_OQ)` + `andnot` keeps NaN activations passing
    /// the gradient exactly like the scalar comparison.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gate_row(dst: &mut [f32], hrow: &[f32]) {
        let din = dst.len();
        let zero = _mm256_setzero_ps();
        let mut c = 0;
        while c + 8 <= din {
            let hv = _mm256_loadu_ps(hrow.as_ptr().add(c));
            let d = _mm256_loadu_ps(dst.as_ptr().add(c));
            let keep = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LE_OQ>(hv, zero), d);
            _mm256_storeu_ps(dst.as_mut_ptr().add(c), keep);
            c += 8;
        }
        for (d, &hv) in dst[c..].iter_mut().zip(&hrow[c..]) {
            if hv <= 0.0 {
                *d = 0.0;
            }
        }
    }

    /// Expand 8 packed bf16 values to an f32 vector: zero-extend to 32
    /// bits, shift into the top half, reinterpret.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn load_bf16_8(p: *const u16) -> __m256 {
        let half = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(half)))
    }

    /// bf16 scoring microkernel: bf16 weight panel × bf16 activations,
    /// f32 accumulation with FMA (relaxed tolerance — scoring only).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mk_forward_bf16<const M: usize>(
        hb: &[u16],
        i0: usize,
        din: usize,
        panel: &[u16],
        bias: &[f32],
        out: &mut [f32],
        dout: usize,
        o0: usize,
        valid: usize,
        relu: bool,
    ) {
        let mut lo = [_mm256_loadu_ps(bias.as_ptr()); M];
        let mut hi = [_mm256_loadu_ps(bias.as_ptr().add(8)); M];
        for (k, line) in panel.chunks_exact(NR).enumerate() {
            let wlo = load_bf16_8(line.as_ptr());
            let whi = load_bf16_8(line.as_ptr().add(8));
            for (r, (al, ah)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let hv = _mm256_set1_ps(bf16_to_f32(*hb.get_unchecked((i0 + r) * din + k)));
                *al = _mm256_fmadd_ps(hv, wlo, *al);
                *ah = _mm256_fmadd_ps(hv, whi, *ah);
            }
        }
        let zero = _mm256_setzero_ps();
        let mut tile = [0.0f32; NR];
        for (r, (al, ah)) in lo.iter().zip(hi.iter()).enumerate() {
            let (mut vl, mut vh) = (*al, *ah);
            if relu {
                vl = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(vl, zero), vl);
                vh = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(vh, zero), vh);
            }
            _mm256_storeu_ps(tile.as_mut_ptr(), vl);
            _mm256_storeu_ps(tile.as_mut_ptr().add(8), vh);
            let at = (i0 + r) * dout + o0;
            out[at..at + valid].copy_from_slice(&tile[..valid]);
        }
    }
}

// ---------------------------------------------------------------------------
// f32 training kernels (bit-identical to super::gemm)
// ---------------------------------------------------------------------------

/// Dispatch one `m`-row forward tile onto the AVX2 microkernel.
#[cfg(target_arch = "x86_64")]
fn fwd_tile(
    m: usize,
    h: &[f32],
    i: usize,
    din: usize,
    panel: &[f32],
    bias: &[f32],
    out: &mut [f32],
    dout: usize,
    o0: usize,
    valid: usize,
    relu: bool,
) {
    unsafe {
        match m {
            4 => x86::mk_forward::<4>(h, i, din, panel, bias, out, dout, o0, valid, relu),
            3 => x86::mk_forward::<3>(h, i, din, panel, bias, out, dout, o0, valid, relu),
            2 => x86::mk_forward::<2>(h, i, din, panel, bias, out, dout, o0, valid, relu),
            _ => x86::mk_forward::<1>(h, i, din, panel, bias, out, dout, o0, valid, relu),
        }
    }
}

/// SIMD `out = act(h · W + b)`; bit-identical to
/// [`gemm::matmul_bias_act`], falling back to it when AVX2+FMA is
/// unavailable.
pub fn matmul_bias_act(
    arena: &mut Arena,
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        let npanels = dout.div_ceil(NR);
        let mut wpack = arena.take(npanels * din * NR);
        gemm::pack_panels(w, din, dout, &mut wpack);
        let mut bpad = arena.take(npanels * NR);
        bpad[..dout].copy_from_slice(b);
        par_rows(out, n, dout, threads, |s, e, chunk| {
            let rows = e - s;
            let hloc = &h[s * din..e * din];
            for p in 0..npanels {
                let panel = &wpack[p * din * NR..(p + 1) * din * NR];
                let bias = &bpad[p * NR..(p + 1) * NR];
                let o0 = p * NR;
                let valid = NR.min(dout - o0);
                let mut i = 0;
                while i < rows {
                    let m = MR.min(rows - i);
                    fwd_tile(m, hloc, i, din, panel, bias, chunk, dout, o0, valid, relu);
                    i += m;
                }
            }
        });
        arena.put(bpad);
        arena.put(wpack);
        return;
    }
    gemm::matmul_bias_act(arena, h, w, b, out, n, din, dout, relu, threads);
}

/// Dispatch one `m`-row weight-gradient tile onto the AVX2 microkernel.
#[cfg(target_arch = "x86_64")]
fn gw_tile(
    m: usize,
    ht: &[f32],
    n: usize,
    k0: usize,
    dzpan: &[f32],
    chunk: &mut [f32],
    kloc: usize,
    dout: usize,
    o0: usize,
    valid: usize,
) {
    unsafe {
        match m {
            4 => x86::mk_grad_w::<4>(ht, n, k0, dzpan, chunk, kloc, dout, o0, valid),
            3 => x86::mk_grad_w::<3>(ht, n, k0, dzpan, chunk, kloc, dout, o0, valid),
            2 => x86::mk_grad_w::<2>(ht, n, k0, dzpan, chunk, kloc, dout, o0, valid),
            _ => x86::mk_grad_w::<1>(ht, n, k0, dzpan, chunk, kloc, dout, o0, valid),
        }
    }
}

/// SIMD `dw = hᵀ·dz`, `db = Σᵢ dz[i]`; bit-identical to
/// [`gemm::grad_weights`].
pub fn grad_weights(
    arena: &mut Arena,
    h: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        // db: one sequential pass in batch order, exactly as the scalar
        // path (its reduction order is thread-count-free)
        db.fill(0.0);
        for drow in dz.chunks_exact(dout) {
            for (d, &v) in db.iter_mut().zip(drow) {
                *d += v;
            }
        }
        let mut ht = arena.take(din * n);
        for (i, hrow) in h.chunks_exact(din).enumerate() {
            for (k, &hv) in hrow.iter().enumerate() {
                ht[k * n + i] = hv;
            }
        }
        let npanels = dout.div_ceil(NR);
        let mut dzp = arena.take(npanels * n * NR);
        gemm::pack_panels(dz, n, dout, &mut dzp);
        par_rows(dw, din, dout, threads, |k0, k1, chunk| {
            let rows = k1 - k0;
            for p in 0..npanels {
                let dzpan = &dzp[p * n * NR..(p + 1) * n * NR];
                let o0 = p * NR;
                let valid = NR.min(dout - o0);
                let mut k = 0;
                while k < rows {
                    let m = MR.min(rows - k);
                    gw_tile(m, &ht, n, k0 + k, dzpan, chunk, k, dout, o0, valid);
                    k += m;
                }
            }
        });
        arena.put(dzp);
        arena.put(ht);
        return;
    }
    gemm::grad_weights(arena, h, dz, dw, db, n, din, dout, threads);
}

/// Shared SIMD `dh = dz · Wᵀ` core with the optional fused ReLU gate —
/// the same structure as the scalar `dz_wt_impl`: Wᵀ lines ascend `o`,
/// masked-out rows are skipped on the identical `dv == 0.0` test, and
/// the gate zeroes after accumulation, so results are bit-identical.
#[cfg(target_arch = "x86_64")]
fn dz_wt_impl_simd(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    gate: Option<&[f32]>,
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    let mut wt = arena.take(dout * din);
    for (k, wrow) in w.chunks_exact(dout).enumerate() {
        for (o, &wv) in wrow.iter().enumerate() {
            wt[o * din + k] = wv;
        }
    }
    par_rows(dh, n, din, threads, |s, e, chunk| {
        let rows = e - s;
        let mut i = 0;
        while i < rows {
            let m = MR.min(rows - i);
            chunk[i * din..(i + m) * din].fill(0.0);
            for (o, wtline) in wt.chunks_exact(din).enumerate() {
                for r in 0..m {
                    let dv = dz[(s + i + r) * dout + o];
                    if dv == 0.0 {
                        continue; // masked-out rows add exact zeros
                    }
                    let dst = &mut chunk[(i + r) * din..(i + r + 1) * din];
                    unsafe { x86::axpy(dst, wtline, dv) };
                }
            }
            if let Some(h) = gate {
                for r in 0..m {
                    let hrow = &h[(s + i + r) * din..(s + i + r + 1) * din];
                    let dst = &mut chunk[(i + r) * din..(i + r + 1) * din];
                    unsafe { x86::gate_row(dst, hrow) };
                }
            }
            i += m;
        }
    });
    arena.put(wt);
}

/// SIMD plain `dh = dz · Wᵀ` (no gate); bit-identical to
/// [`gemm::dz_wt`].
pub fn dz_wt(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        dz_wt_impl_simd(arena, dz, w, None, dh, n, din, dout, threads);
        return;
    }
    gemm::dz_wt(arena, dz, w, dh, n, din, dout, threads);
}

/// SIMD ReLU-gated `dh = dz · Wᵀ`; bit-identical to
/// [`gemm::grad_input`].
pub fn grad_input(
    arena: &mut Arena,
    dz: &[f32],
    w: &[f32],
    h: &[f32],
    dh: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if available() {
        dz_wt_impl_simd(arena, dz, w, Some(h), dh, n, din, dout, threads);
        return;
    }
    gemm::grad_input(arena, dz, w, h, dh, n, din, dout, threads);
}

// ---------------------------------------------------------------------------
// bf16 fast-scoring forward (inference fleet only; relaxed tolerance)
// ---------------------------------------------------------------------------

/// Scalar bf16 scoring microkernel — the portable fallback. Uses the
/// identical bf16 conversions as the AVX2 path (the packed operands
/// are the same bits) but plain mul+add accumulation, so the two paths
/// agree to the relaxed scoring tolerance, not bitwise.
fn mk_forward_bf16_scalar<const M: usize>(
    hb: &[u16],
    i0: usize,
    din: usize,
    panel: &[u16],
    bias: &[f32],
    out: &mut [f32],
    dout: usize,
    o0: usize,
    valid: usize,
    relu: bool,
) {
    let mut acc = [[0.0f32; NR]; M];
    for row in acc.iter_mut() {
        row.copy_from_slice(bias);
    }
    for (k, line) in panel.chunks_exact(NR).enumerate() {
        for (r, row) in acc.iter_mut().enumerate() {
            let hv = bf16_to_f32(hb[(i0 + r) * din + k]);
            for (a, &wv) in row.iter_mut().zip(line) {
                *a += hv * bf16_to_f32(wv);
            }
        }
    }
    for (r, row) in acc.iter_mut().enumerate() {
        if relu {
            for v in row.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        let at = (i0 + r) * dout + o0;
        out[at..at + valid].copy_from_slice(&row[..valid]);
    }
}

/// Dispatch one `m`-row bf16 tile onto the AVX2 or scalar microkernel.
fn bf16_tile(
    use_avx: bool,
    m: usize,
    hb: &[u16],
    i: usize,
    din: usize,
    panel: &[u16],
    bias: &[f32],
    out: &mut [f32],
    dout: usize,
    o0: usize,
    valid: usize,
    relu: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if use_avx {
        unsafe {
            match m {
                4 => x86::mk_forward_bf16::<4>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
                3 => x86::mk_forward_bf16::<3>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
                2 => x86::mk_forward_bf16::<2>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
                _ => x86::mk_forward_bf16::<1>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
            }
        }
        return;
    }
    let _ = use_avx;
    match m {
        4 => mk_forward_bf16_scalar::<4>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
        3 => mk_forward_bf16_scalar::<3>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
        2 => mk_forward_bf16_scalar::<2>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
        _ => mk_forward_bf16_scalar::<1>(hb, i, din, panel, bias, out, dout, o0, valid, relu),
    }
}

/// bf16 fast-scoring `out = act(h · W + b)`: weights *and* activations
/// round to bf16 (RNE), accumulation stays f32, output is f32. Runs
/// the AVX2+FMA microkernel when available, else the scalar fallback
/// over the same packed operands. **Scoring only** — per-example
/// losses feed selection, never the backward — under the relaxed
/// parity contract pinned in `tests/kernel_parity.rs`. Non-finite
/// inputs stay non-finite (bf16 keeps ±Inf and quiets NaN), so
/// poisoned losses still propagate to the selection layer.
pub fn matmul_bias_act_bf16(
    arena: &mut Arena,
    h: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    din: usize,
    dout: usize,
    relu: bool,
    threads: usize,
) {
    let npanels = dout.div_ceil(NR);
    let wlen = npanels * din * NR;
    let mut wpack = arena.take(wlen.div_ceil(2));
    pack_panels_bf16(w, din, dout, as_u16_mut(&mut wpack, wlen));
    let hlen = n * din;
    let mut hpack = arena.take(hlen.div_ceil(2));
    {
        let hb = as_u16_mut(&mut hpack, hlen);
        for (slot, &v) in hb.iter_mut().zip(h) {
            *slot = f32_to_bf16(v);
        }
    }
    let mut bpad = arena.take(npanels * NR);
    bpad[..dout].copy_from_slice(b);
    let wview = as_u16(&wpack, wlen);
    let hview = as_u16(&hpack, hlen);
    let use_avx = available();
    par_rows(out, n, dout, threads, |s, e, chunk| {
        let rows = e - s;
        let hloc = &hview[s * din..e * din];
        for p in 0..npanels {
            let panel = &wview[p * din * NR..(p + 1) * din * NR];
            let bias = &bpad[p * NR..(p + 1) * NR];
            let o0 = p * NR;
            let valid = NR.min(dout - o0);
            let mut i = 0;
            while i < rows {
                let m = MR.min(rows - i);
                bf16_tile(use_avx, m, hloc, i, din, panel, bias, chunk, dout, o0, valid, relu);
                i += m;
            }
        }
    });
    arena.put(bpad);
    arena.put(hpack);
    arena.put(wpack);
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::data::rng::Rng;

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    /// Same remainder-hitting shapes as the gemm suite.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 2, 5),
        (4, 16, 16),
        (5, 17, 31),
        (8, 17, 10),
        (13, 7, 33),
        (16, 32, 48),
    ];

    #[test]
    fn simd_forward_bitwise_equals_blocked() {
        for &(n, din, dout) in SHAPES {
            for threads in [1, 3] {
                for relu in [false, true] {
                    let mut rng = Rng::seed_from(42);
                    let h = fill(&mut rng, n * din);
                    let w = fill(&mut rng, din * dout);
                    let b = fill(&mut rng, dout);
                    let mut arena = Arena::new();
                    let mut want = vec![0.0f32; n * dout];
                    let t = threads;
                    gemm::matmul_bias_act(&mut arena, &h, &w, &b, &mut want, n, din, dout, relu, t);
                    let mut got = vec![0.0f32; n * dout];
                    matmul_bias_act(&mut arena, &h, &w, &b, &mut got, n, din, dout, relu, t);
                    assert_eq!(got, want, "fwd {n}x{din}x{dout} t{threads} relu={relu}");
                }
            }
        }
    }

    #[test]
    fn simd_backward_bitwise_equals_blocked() {
        for &(n, din, dout) in SHAPES {
            let mut rng = Rng::seed_from(7);
            let h = fill(&mut rng, n * din);
            let dz = fill(&mut rng, n * dout);
            let w = fill(&mut rng, din * dout);
            let acts: Vec<f32> = fill(&mut rng, n * din).into_iter().map(|v| v.max(0.0)).collect();
            let mut arena = Arena::new();
            let (mut w1, mut b1) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
            let (mut w2, mut b2) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
            gemm::grad_weights(&mut arena, &h, &dz, &mut w1, &mut b1, n, din, dout, 2);
            grad_weights(&mut arena, &h, &dz, &mut w2, &mut b2, n, din, dout, 2);
            assert_eq!(w1, w2, "dw {n}x{din}x{dout}");
            assert_eq!(b1, b2, "db {n}x{din}x{dout}");
            let (mut g1, mut g2) = (vec![0.0f32; n * din], vec![1.0f32; n * din]);
            gemm::grad_input(&mut arena, &dz, &w, &acts, &mut g1, n, din, dout, 2);
            grad_input(&mut arena, &dz, &w, &acts, &mut g2, n, din, dout, 2);
            assert_eq!(g1, g2, "dh {n}x{din}x{dout}");
            let (mut p1, mut p2) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
            gemm::dz_wt(&mut arena, &dz, &w, &mut p1, n, din, dout, 2);
            dz_wt(&mut arena, &dz, &w, &mut p2, n, din, dout, 2);
            assert_eq!(p1, p2, "dz_wt {n}x{din}x{dout}");
        }
    }

    #[test]
    fn bf16_conversions_round_trip_and_preserve_specials() {
        // exactly-representable values survive the round trip
        for v in [0.0f32, 1.0, -2.5, 0.15625, -96.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "{v} must be exact in bf16");
        }
        assert_eq!(bf16_to_f32(f32_to_bf16(-0.0)).to_bits(), (-0.0f32).to_bits());
        // round-to-nearest-even: an exact tie rounds to the even mantissa
        let tie = f32::from_bits(0x3F80_8000); // 1.0 + 2^-8
        assert_eq!(f32_to_bf16(tie) & 1, 0, "ties must round to even");
        // specials: ±Inf exact, NaN stays NaN (quieted, never Inf)
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // rounding error is bounded by 2^-8 relative
        let mut rng = Rng::seed_from(11);
        for _ in 0..1000 {
            let v = rng.normal() as f32;
            let err = (bf16_to_f32(f32_to_bf16(v)) - v).abs();
            assert!(err <= v.abs() / 256.0, "bf16 round error too large for {v}");
        }
    }

    #[test]
    fn bf16_forward_tracks_f32_within_scoring_tolerance() {
        for &(n, din, dout) in SHAPES {
            for threads in [1, 3] {
                let mut rng = Rng::seed_from(5);
                let h = fill(&mut rng, n * din);
                let w = fill(&mut rng, din * dout);
                let b = fill(&mut rng, dout);
                let mut want = vec![0.0f32; n * dout];
                reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, true);
                let mut arena = Arena::new();
                let mut got = vec![0.0f32; n * dout];
                matmul_bias_act_bf16(&mut arena, &h, &w, &b, &mut got, n, din, dout, true, threads);
                // per-element bound: bf16 rounds both operands to 2^-8
                // relative, so the dot product drifts with the term
                // magnitude sum — ~√din for unit-normal data, doubled
                // for headroom over the cancellation tail (the tight
                // ≤1e-2 network-scale contract lives in kernel_parity)
                let scale: f32 = 2.0 * (1.0 + (din as f32).sqrt());
                for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
                    let tol = 1e-2 * wv.abs().max(1.0) * scale;
                    assert!(
                        (g - wv).abs() <= tol,
                        "bf16[{i}] {g} vs f32 {wv} ({n}x{din}x{dout} t{threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn bf16_forward_is_thread_count_invariant() {
        let (n, din, dout) = (13, 29, 21);
        let mut rng = Rng::seed_from(3);
        let h = fill(&mut rng, n * din);
        let w = fill(&mut rng, din * dout);
        let b = fill(&mut rng, dout);
        let mut arena = Arena::new();
        let (mut o1, mut o4) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
        matmul_bias_act_bf16(&mut arena, &h, &w, &b, &mut o1, n, din, dout, false, 1);
        matmul_bias_act_bf16(&mut arena, &h, &w, &b, &mut o4, n, din, dout, false, 4);
        assert_eq!(o1, o4, "bf16 forward must be thread-count invariant");
    }

    #[test]
    fn bf16_forward_propagates_non_finite_inputs() {
        let (n, din, dout) = (2, 4, 3);
        let mut h = vec![0.5f32; n * din];
        h[1] = f32::NAN; // poison row 0
        let w = vec![0.25f32; din * dout];
        let b = vec![0.0f32; dout];
        let mut arena = Arena::new();
        let mut out = vec![0.0f32; n * dout];
        matmul_bias_act_bf16(&mut arena, &h, &w, &b, &mut out, n, din, dout, false, 1);
        assert!(out[..dout].iter().all(|v| v.is_nan()), "row 0 must stay NaN: {out:?}");
        assert!(out[dout..].iter().all(|v| v.is_finite()), "row 1 must stay finite");
    }

    #[test]
    fn availability_probe_is_stable() {
        // the value is runner-dependent, but it must not flap and the
        // feature summary must always render
        assert_eq!(available(), available());
        assert!(!cpu_features().is_empty());
    }
}
