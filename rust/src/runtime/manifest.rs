//! `artifacts/manifest.json` — the python→rust interchange contract.
//!
//! `aot.py` emits one entry per model describing tensor shapes, dtypes
//! and the parameter layout, plus the HLO-text filename for every
//! (executable, flavour) pair. The runtime refuses to start on a
//! missing/inconsistent manifest rather than guessing shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Kernel flavour of an artifact set (DESIGN.md `abl-kernel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavour {
    /// L1 Pallas kernels (interpret-mode), the paper-faithful path.
    Pallas,
    /// Pure-jnp lowering (XLA-native fusion), the fast CPU path.
    Jnp,
}

impl Flavour {
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavour::Pallas => "pallas",
            Flavour::Jnp => "jnp",
        }
    }
}

impl std::str::FromStr for Flavour {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "pallas" => Ok(Flavour::Pallas),
            "jnp" => Ok(Flavour::Jnp),
            other => bail!("unknown flavour {other:?}; expected pallas | jnp"),
        }
    }
}

/// The six executables every model exports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Exe {
    Init,
    FwdLoss,
    TrainStep,
    Grads,
    Apply,
    Eval,
}

impl Exe {
    pub const ALL: [Exe; 6] =
        [Exe::Init, Exe::FwdLoss, Exe::TrainStep, Exe::Grads, Exe::Apply, Exe::Eval];

    pub fn as_str(&self) -> &'static str {
        match self {
            Exe::Init => "init",
            Exe::FwdLoss => "fwd_loss",
            Exe::TrainStep => "train_step",
            Exe::Grads => "grads",
            Exe::Apply => "apply",
            Exe::Eval => "eval",
        }
    }
}

/// One parameter tensor's spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One model's manifest entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub task: String,
    pub x_shape: Vec<usize>,
    pub num_classes: usize,
    pub y_dtype: String,
    pub params: Vec<ParamEntry>,
    /// `"{exe}:{flavour}"` → HLO text filename.
    pub executables: BTreeMap<String, String>,
}

impl ModelEntry {
    pub fn is_classification(&self) -> bool {
        self.task == "classification"
    }

    /// Artifact filename for `(exe, flavour)`.
    pub fn artifact(&self, exe: Exe, flavour: Flavour) -> Result<&str> {
        let key = format!("{}:{}", exe.as_str(), flavour.as_str());
        self.executables
            .get(&key)
            .map(String::as_str)
            .with_context(|| format!("manifest has no executable {key:?}"))
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    fn from_json(j: &Json) -> Result<ModelEntry> {
        let params = j
            .need("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.need("name")?.as_str()?.to_string(),
                    shape: p.need("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let executables = j
            .need("executables")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(ModelEntry {
            task: j.need("task")?.as_str()?.to_string(),
            x_shape: j.need("x_shape")?.as_usize_vec()?,
            num_classes: j.need("num_classes")?.as_usize()?,
            y_dtype: j.need("y_dtype")?.as_str()?.to_string(),
            params,
            executables,
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub batch: usize,
    pub models: BTreeMap<String, ModelEntry>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {path:?} — run `make artifacts` (or set OBFTF_ARTIFACTS)")
        })?;
        let j = json::parse(&text).context("manifest.json does not parse")?;
        let models = j
            .need("models")?
            .as_obj()?
            .iter()
            .map(|(name, entry)| {
                Ok((
                    name.clone(),
                    ModelEntry::from_json(entry)
                        .with_context(|| format!("model {name}"))?,
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        let m = Manifest {
            version: j.need("version")?.as_usize()?,
            batch: j.need("batch")?.as_usize()?,
            models,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation + artifact-file existence check.
    pub fn validate(&self) -> Result<()> {
        if self.version != 1 {
            bail!("unsupported manifest version {}", self.version);
        }
        if self.batch == 0 {
            bail!("manifest batch size is 0");
        }
        if self.models.is_empty() {
            bail!("manifest lists no models");
        }
        for (name, entry) in &self.models {
            if entry.task != "classification" && entry.task != "regression" {
                bail!("model {name}: unknown task {:?}", entry.task);
            }
            if entry.is_classification() && entry.num_classes < 2 {
                bail!("model {name}: classification with {} classes", entry.num_classes);
            }
            if entry.params.is_empty() {
                bail!("model {name}: no parameters");
            }
            for (key, fname) in &entry.executables {
                let p = self.dir.join(fname);
                if !p.exists() {
                    bail!(
                        "model {name}: artifact {key} -> {fname} missing from {:?}",
                        self.dir
                    );
                }
            }
            for exe in Exe::ALL {
                for fl in [Flavour::Pallas, Flavour::Jnp] {
                    entry.artifact(exe, fl).with_context(|| format!("model {name}"))?;
                }
            }
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| {
            format!(
                "model {name:?} not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn artifact_path(&self, model: &str, exe: Exe, flavour: Flavour) -> Result<PathBuf> {
        Ok(self.dir.join(self.model(model)?.artifact(exe, flavour)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn write_toy_manifest(dir: &Path, drop_artifact: Option<&str>) {
        let mut exes = String::new();
        for exe in Exe::ALL {
            for fl in ["pallas", "jnp"] {
                let fname = format!("m_{}.{fl}.hlo.txt", exe.as_str());
                if Some(fname.as_str()) != drop_artifact {
                    std::fs::write(dir.join(&fname), "HloModule m").unwrap();
                }
                exes.push_str(&format!(
                    "\"{}:{fl}\": \"{fname}\",",
                    exe.as_str()
                ));
            }
        }
        exes.pop(); // trailing comma
        let doc = format!(
            r#"{{
  "version": 1,
  "batch": 8,
  "models": {{
    "m": {{
      "task": "regression",
      "x_shape": [1],
      "num_classes": 0,
      "y_dtype": "f32",
      "params": [{{"name": "w", "shape": [1, 1]}}],
      "executables": {{{exes}}}
    }}
  }}
}}"#
        );
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
    }

    #[test]
    fn load_validate_roundtrip() {
        let dir = TempDir::new("manifest").unwrap();
        write_toy_manifest(dir.path(), None);
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.batch, 8);
        let e = m.model("m").unwrap();
        assert_eq!(e.artifact(Exe::Init, Flavour::Jnp).unwrap(), "m_init.jnp.hlo.txt");
        assert_eq!(e.params[0], ParamEntry { name: "w".into(), shape: vec![1, 1] });
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_artifact_fails_validation() {
        let dir = TempDir::new("manifest").unwrap();
        write_toy_manifest(dir.path(), Some("m_eval.jnp.hlo.txt"));
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn missing_manifest_has_actionable_error() {
        let dir = TempDir::new("manifest").unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "err: {err}");
    }

    #[test]
    fn flavour_parse() {
        use std::str::FromStr;
        assert_eq!(Flavour::from_str("pallas").unwrap(), Flavour::Pallas);
        assert_eq!(Flavour::from_str("jnp").unwrap(), Flavour::Jnp);
        assert!(Flavour::from_str("cuda").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp"));
            assert_eq!(m.batch, 128);
        }
    }
}
