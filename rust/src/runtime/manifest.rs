//! `artifacts/manifest.json` — the python→rust interchange contract —
//! plus the synthesized **native** manifest used when no artifacts are
//! built.
//!
//! `aot.py` emits one entry per model describing tensor shapes, dtypes
//! and the parameter layout, plus the HLO-text filename for every
//! (executable, flavour) pair. The runtime refuses to start on an
//! inconsistent manifest rather than guessing shapes. When the
//! artifacts directory is absent entirely, [`Manifest::load_or_native`]
//! synthesizes entries for all four paper models — the dense chains
//! (linreg, mlp) and the conv chains (cnn, cnn_lite, whose stride
//! schedule rides in `conv_strides`) — so a fresh checkout trains
//! every workload, Table 3 included, without Python, JAX or PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Batch size of the synthesized native manifest (matches the
/// `python/compile/model.py` `BATCH` the AOT artifacts are lowered at).
pub const NATIVE_BATCH: usize = 128;

/// Kernel flavour of an artifact set (DESIGN.md `abl-kernel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Flavour {
    /// Pure-Rust CPU backend (no artifacts, no PJRT) — the hermetic
    /// default on a fresh checkout.
    Native,
    /// L1 Pallas kernels (interpret-mode), the paper-faithful path.
    Pallas,
    /// Pure-jnp lowering (XLA-native fusion), the fast CPU path.
    Jnp,
}

impl Flavour {
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavour::Native => "native",
            Flavour::Pallas => "pallas",
            Flavour::Jnp => "jnp",
        }
    }

    /// Whether this flavour executes on-disk HLO artifacts (vs the
    /// built-in native backend).
    pub fn needs_artifacts(&self) -> bool {
        !matches!(self, Flavour::Native)
    }
}

impl std::fmt::Display for Flavour {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Flavour {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Flavour::Native),
            "pallas" => Ok(Flavour::Pallas),
            "jnp" => Ok(Flavour::Jnp),
            other => bail!("unknown flavour {other:?}; expected native | pallas | jnp"),
        }
    }
}

/// The six executables every model exports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Exe {
    Init,
    FwdLoss,
    TrainStep,
    Grads,
    Apply,
    Eval,
}

impl Exe {
    pub const ALL: [Exe; 6] =
        [Exe::Init, Exe::FwdLoss, Exe::TrainStep, Exe::Grads, Exe::Apply, Exe::Eval];

    pub fn as_str(&self) -> &'static str {
        match self {
            Exe::Init => "init",
            Exe::FwdLoss => "fwd_loss",
            Exe::TrainStep => "train_step",
            Exe::Grads => "grads",
            Exe::Apply => "apply",
            Exe::Eval => "eval",
        }
    }
}

/// One parameter tensor's spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One model's manifest entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub task: String,
    pub x_shape: Vec<usize>,
    pub num_classes: usize,
    pub y_dtype: String,
    pub params: Vec<ParamEntry>,
    /// Conv stride schedule for conv→GAP→dense models (one entry per
    /// conv layer, SAME padding implied — the geometry the native
    /// backend needs that weight shapes alone cannot carry). Empty for
    /// dense-chain models and for artifact manifests (whose HLO encodes
    /// the geometry; conv models there run via the `pjrt` feature).
    pub conv_strides: Vec<usize>,
    /// `"{exe}:{flavour}"` → HLO text filename (`"<builtin>"` for the
    /// native flavour, which has no on-disk artifact).
    pub executables: BTreeMap<String, String>,
}

impl ModelEntry {
    pub fn is_classification(&self) -> bool {
        self.task == "classification"
    }

    /// Layer widths `[d_in, h_1, …, d_out]` when this entry is a dense
    /// chain of (weight, bias) pairs over flat features — the form the
    /// native backend executes: each weight's input width chains onto
    /// the previous layer and each bias matches its weight's output
    /// width. `None` for conv/non-chain entries.
    pub fn dense_dims(&self) -> Option<Vec<usize>> {
        if self.x_shape.len() != 1 || self.params.is_empty() || self.params.len() % 2 != 0 {
            return None;
        }
        let mut dims = vec![self.x_shape[0]];
        for pair in self.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            if w.shape.len() != 2 || b.shape.len() != 1 || w.shape[1] != b.shape[0] {
                return None;
            }
            if w.shape[0] != *dims.last().expect("dims starts non-empty") {
                return None;
            }
            dims.push(w.shape[1]);
        }
        Some(dims)
    }

    /// The conv geometry of a conv-chain entry: one SAME-padded
    /// [`ConvShape`] per conv layer plus the `(head_in, head_out)`
    /// dense-head widths. `None` for dense entries, for conv entries
    /// without a stride schedule (artifact manifests), and for
    /// malformed parameter lists — full validation with error messages
    /// lives in the native backend's topology parser.
    ///
    /// [`ConvShape`]: super::kernels::ConvShape
    pub fn conv_chain(&self) -> Option<(Vec<super::kernels::ConvShape>, (usize, usize))> {
        use super::kernels::ConvShape;
        if self.x_shape.len() != 3 || self.conv_strides.is_empty() {
            return None;
        }
        if self.params.len() != 2 * (self.conv_strides.len() + 1) {
            return None;
        }
        if self.x_shape.iter().any(|&d| d == 0) {
            return None;
        }
        let (mut h, mut w, mut cin) = (self.x_shape[0], self.x_shape[1], self.x_shape[2]);
        let mut shapes = Vec::with_capacity(self.conv_strides.len());
        for (&stride, pair) in self.conv_strides.iter().zip(self.params.chunks(2)) {
            let k = &pair[0];
            if k.shape.len() != 4 || k.shape[2] != cin || stride == 0 {
                return None;
            }
            if k.shape.iter().any(|&d| d == 0) {
                return None;
            }
            let cs = ConvShape::same(h, w, cin, k.shape[3], k.shape[0], k.shape[1], stride);
            (h, w, cin) = (cs.oh, cs.ow, cs.cout);
            shapes.push(cs);
        }
        let head = &self.params[2 * shapes.len()];
        if head.shape.len() != 2 || head.shape[0] != cin {
            return None;
        }
        Some((shapes, (cin, head.shape[1])))
    }

    /// Artifact filename for `(exe, flavour)`.
    pub fn artifact(&self, exe: Exe, flavour: Flavour) -> Result<&str> {
        let key = format!("{}:{}", exe.as_str(), flavour.as_str());
        self.executables
            .get(&key)
            .map(String::as_str)
            .with_context(|| format!("manifest has no executable {key:?}"))
    }

    /// The flavours this entry lists executables for (sorted, deduped).
    pub fn flavours(&self) -> Vec<Flavour> {
        let mut out: Vec<Flavour> = Vec::new();
        for key in self.executables.keys() {
            if let Some((_, suffix)) = key.rsplit_once(':') {
                if let Ok(fl) = suffix.parse::<Flavour>() {
                    if !out.contains(&fl) {
                        out.push(fl);
                    }
                }
            }
        }
        out.sort();
        out
    }

    pub fn has_flavour(&self, flavour: Flavour) -> bool {
        self.flavours().contains(&flavour)
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    fn from_json(j: &Json) -> Result<ModelEntry> {
        let params = j
            .need("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.need("name")?.as_str()?.to_string(),
                    shape: p.need("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let executables = j
            .need("executables")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;
        let conv_strides = match j.get("conv_strides") {
            Some(v) => v.as_usize_vec()?,
            None => vec![],
        };
        Ok(ModelEntry {
            task: j.need("task")?.as_str()?.to_string(),
            x_shape: j.need("x_shape")?.as_usize_vec()?,
            num_classes: j.need("num_classes")?.as_usize()?,
            y_dtype: j.need("y_dtype")?.as_str()?.to_string(),
            params,
            conv_strides,
            executables,
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub batch: usize,
    pub models: BTreeMap<String, ModelEntry>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {path:?} — run `make artifacts` (or set OBFTF_ARTIFACTS)")
        })?;
        let j = json::parse(&text).context("manifest.json does not parse")?;
        let models = j
            .need("models")?
            .as_obj()?
            .iter()
            .map(|(name, entry)| {
                Ok((
                    name.clone(),
                    ModelEntry::from_json(entry)
                        .with_context(|| format!("model {name}"))?,
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        let m = Manifest {
            version: j.need("version")?.as_usize()?,
            batch: j.need("batch")?.as_usize()?,
            models,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Load `dir/manifest.json` when present, otherwise synthesize the
    /// [`Manifest::native`] manifest — a fresh checkout with no
    /// `artifacts/` directory starts up on the pure-Rust backend
    /// instead of refusing to run.
    pub fn load_or_native(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::native(dir))
        }
    }

    /// Synthesize the artifact-free manifest: the models the native CPU
    /// backend executes (linreg, mlp, cnn, cnn_lite), all six
    /// executables tagged with the `native` flavour and no on-disk
    /// files.
    pub fn native(dir: &Path) -> Manifest {
        fn entry(
            task: &str,
            x_shape: Vec<usize>,
            num_classes: usize,
            y_dtype: &str,
            params: Vec<(&str, Vec<usize>)>,
            conv_strides: Vec<usize>,
        ) -> ModelEntry {
            let executables = Exe::ALL
                .iter()
                .map(|e| (format!("{}:native", e.as_str()), "<builtin>".to_string()))
                .collect();
            ModelEntry {
                task: task.to_string(),
                x_shape,
                num_classes,
                y_dtype: y_dtype.to_string(),
                params: params
                    .into_iter()
                    .map(|(name, shape)| ParamEntry { name: name.to_string(), shape })
                    .collect(),
                conv_strides,
                executables,
            }
        }

        /// Conv stack on 16×16×3 with per-layer (width, stride), 3×3
        /// SAME kernels, GAP, dense head to 100 classes — mirrors
        /// `python/compile/model.py::_make_cnn`.
        fn cnn_entry(widths_strides: &[(usize, usize)]) -> ModelEntry {
            let mut params: Vec<(String, Vec<usize>)> = Vec::new();
            let mut cin = 3usize;
            let mut strides = Vec::new();
            for (li, &(cout, stride)) in widths_strides.iter().enumerate() {
                params.push((format!("k{}", li + 1), vec![3, 3, cin, cout]));
                params.push((format!("cb{}", li + 1), vec![cout]));
                strides.push(stride);
                cin = cout;
            }
            params.push(("wh".to_string(), vec![cin, 100]));
            params.push(("bh".to_string(), vec![100]));
            entry(
                "classification",
                vec![16, 16, 3],
                100,
                "i32",
                params.iter().map(|(n, s)| (n.as_str(), s.clone())).collect(),
                strides,
            )
        }

        let mut models = BTreeMap::new();
        // paper §4.1: y = 2x + 1 + noise, single-feature linear head
        models.insert(
            "linreg".to_string(),
            entry(
                "regression",
                vec![1],
                0,
                "f32",
                vec![("w", vec![1, 1]), ("b", vec![1])],
                vec![],
            ),
        );
        // paper §4.2: 784-256-256-10 MLP (matches python/compile/model.py)
        models.insert(
            "mlp".to_string(),
            entry(
                "classification",
                vec![784],
                10,
                "i32",
                vec![
                    ("w1", vec![784, 256]),
                    ("b1", vec![256]),
                    ("w2", vec![256, 256]),
                    ("b2", vec![256]),
                    ("w3", vec![256, 10]),
                    ("b3", vec![10]),
                ],
                vec![],
            ),
        );
        // paper §4.3 / Table 3: ResNet50-role conv stack and the
        // MobileNetV2-role lite stack (python/compile/model.py CNN /
        // CNN_LITE widths and stride schedules)
        models.insert("cnn".to_string(), cnn_entry(&[(32, 1), (64, 2), (128, 2)]));
        models.insert("cnn_lite".to_string(), cnn_entry(&[(16, 2), (32, 2)]));
        Manifest { version: 1, batch: NATIVE_BATCH, models, dir: dir.to_path_buf() }
    }

    /// Structural validation + artifact-file existence check (native
    /// executables are built in and have no files to check).
    pub fn validate(&self) -> Result<()> {
        if self.version != 1 {
            bail!("unsupported manifest version {}", self.version);
        }
        if self.batch == 0 {
            bail!("manifest batch size is 0");
        }
        if self.models.is_empty() {
            bail!("manifest lists no models");
        }
        for (name, entry) in &self.models {
            if entry.task != "classification" && entry.task != "regression" {
                bail!("model {name}: unknown task {:?}", entry.task);
            }
            if entry.is_classification() && entry.num_classes < 2 {
                bail!("model {name}: classification with {} classes", entry.num_classes);
            }
            if entry.params.is_empty() {
                bail!("model {name}: no parameters");
            }
            // structural subset of the conv invariants; the full set
            // lives in ModelEntry::conv_chain and the native backend's
            // parse_conv — keep the three in sync
            if !entry.conv_strides.is_empty() {
                if entry.x_shape.len() != 3 {
                    bail!(
                        "model {name}: conv_strides given but x_shape {:?} is not NHWC",
                        entry.x_shape
                    );
                }
                if entry.conv_strides.iter().any(|&s| s == 0) {
                    bail!("model {name}: conv stride 0");
                }
                // conv layers are (kernel, bias) pairs plus one dense
                // head pair after the pool
                if entry.params.len() != 2 * (entry.conv_strides.len() + 1) {
                    bail!(
                        "model {name}: {} conv strides need {} param tensors, got {}",
                        entry.conv_strides.len(),
                        2 * (entry.conv_strides.len() + 1),
                        entry.params.len()
                    );
                }
            }
            let flavours = entry.flavours();
            if flavours.is_empty() {
                bail!("model {name}: no executables with a recognizable flavour");
            }
            for (key, fname) in &entry.executables {
                let flavour = key.rsplit_once(':').and_then(|(_, s)| s.parse::<Flavour>().ok());
                if flavour.is_some_and(|f| !f.needs_artifacts()) {
                    continue;
                }
                let p = self.dir.join(fname);
                if !p.exists() {
                    bail!(
                        "model {name}: artifact {key} -> {fname} missing from {:?}",
                        self.dir
                    );
                }
            }
            for fl in flavours {
                for exe in Exe::ALL {
                    entry.artifact(exe, fl).with_context(|| format!("model {name}"))?;
                }
            }
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| {
            format!(
                "model {name:?} not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn artifact_path(&self, model: &str, exe: Exe, flavour: Flavour) -> Result<PathBuf> {
        Ok(self.dir.join(self.model(model)?.artifact(exe, flavour)?))
    }

    /// The flavour to run when the config says `auto`: `native`
    /// (hermetic) when listed; otherwise the best *executable* artifact
    /// flavour. Without the `pjrt` cargo feature the artifact flavours
    /// cannot execute at all, so `native` is the only sensible default
    /// even against an artifact manifest (its dense-chain models run
    /// straight off the parameter specs).
    pub fn default_flavour(&self) -> Flavour {
        let all_have = |f: Flavour| self.models.values().all(|e| e.has_flavour(f));
        if all_have(Flavour::Native) {
            return Flavour::Native;
        }
        #[cfg(feature = "pjrt")]
        {
            if all_have(Flavour::Jnp) {
                Flavour::Jnp
            } else {
                Flavour::Pallas
            }
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Flavour::Native
        }
    }

    /// Resolve a config flavour string: `"auto"` picks
    /// [`Manifest::default_flavour`], anything else parses strictly.
    pub fn resolve_flavour(&self, s: &str) -> Result<Flavour> {
        if s == "auto" {
            Ok(self.default_flavour())
        } else {
            s.parse()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn write_toy_manifest(dir: &Path, drop_artifact: Option<&str>) {
        let mut exes = String::new();
        for exe in Exe::ALL {
            for fl in ["pallas", "jnp"] {
                let fname = format!("m_{}.{fl}.hlo.txt", exe.as_str());
                if Some(fname.as_str()) != drop_artifact {
                    std::fs::write(dir.join(&fname), "HloModule m").unwrap();
                }
                exes.push_str(&format!(
                    "\"{}:{fl}\": \"{fname}\",",
                    exe.as_str()
                ));
            }
        }
        exes.pop(); // trailing comma
        let doc = format!(
            r#"{{
  "version": 1,
  "batch": 8,
  "models": {{
    "m": {{
      "task": "regression",
      "x_shape": [1],
      "num_classes": 0,
      "y_dtype": "f32",
      "params": [{{"name": "w", "shape": [1, 1]}}],
      "executables": {{{exes}}}
    }}
  }}
}}"#
        );
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
    }

    #[test]
    fn load_validate_roundtrip() {
        let dir = TempDir::new("manifest").unwrap();
        write_toy_manifest(dir.path(), None);
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.batch, 8);
        let e = m.model("m").unwrap();
        assert_eq!(e.artifact(Exe::Init, Flavour::Jnp).unwrap(), "m_init.jnp.hlo.txt");
        assert_eq!(e.params[0], ParamEntry { name: "w".into(), shape: vec![1, 1] });
        assert_eq!(e.flavours(), vec![Flavour::Pallas, Flavour::Jnp]);
        assert!(!e.has_flavour(Flavour::Native));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_artifact_fails_validation() {
        let dir = TempDir::new("manifest").unwrap();
        write_toy_manifest(dir.path(), Some("m_eval.jnp.hlo.txt"));
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn missing_manifest_has_actionable_error() {
        let dir = TempDir::new("manifest").unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "err: {err}");
    }

    #[test]
    fn flavour_parse() {
        use std::str::FromStr;
        assert_eq!(Flavour::from_str("native").unwrap(), Flavour::Native);
        assert_eq!(Flavour::from_str("pallas").unwrap(), Flavour::Pallas);
        assert_eq!(Flavour::from_str("jnp").unwrap(), Flavour::Jnp);
        assert!(Flavour::from_str("cuda").is_err());
        assert!(!Flavour::Native.needs_artifacts());
        assert!(Flavour::Jnp.needs_artifacts());
    }

    #[test]
    fn native_manifest_validates_without_files() {
        let dir = TempDir::new("native").unwrap();
        let m = Manifest::native(dir.path());
        m.validate().unwrap();
        assert_eq!(m.batch, NATIVE_BATCH);
        let mlp = m.model("mlp").unwrap();
        assert!(mlp.is_classification());
        assert_eq!(mlp.n_params(), 6);
        assert_eq!(mlp.flavours(), vec![Flavour::Native]);
        assert_eq!(mlp.artifact(Exe::TrainStep, Flavour::Native).unwrap(), "<builtin>");
        assert!(mlp.artifact(Exe::TrainStep, Flavour::Jnp).is_err());
        assert_eq!(m.default_flavour(), Flavour::Native);
    }

    #[test]
    fn native_manifest_synthesizes_conv_models() {
        let dir = TempDir::new("natconv").unwrap();
        let m = Manifest::native(dir.path());
        for (name, n_convs, widths) in
            [("cnn", 3usize, vec![32, 64, 128]), ("cnn_lite", 2, vec![16, 32])]
        {
            let e = m.model(name).unwrap();
            assert_eq!(e.x_shape, vec![16, 16, 3], "{name}");
            assert_eq!(e.num_classes, 100, "{name}");
            assert_eq!(e.conv_strides.len(), n_convs, "{name}");
            assert_eq!(e.n_params(), 2 * (n_convs + 1), "{name}");
            assert!(e.dense_dims().is_none(), "{name} is not a dense chain");
            let mut cin = 3;
            for (l, &cout) in widths.iter().enumerate() {
                assert_eq!(e.params[2 * l].shape, vec![3, 3, cin, cout], "{name} k{l}");
                assert_eq!(e.params[2 * l + 1].shape, vec![cout], "{name} cb{l}");
                cin = cout;
            }
            assert_eq!(e.params[2 * n_convs].shape, vec![cin, 100], "{name} head");
            assert!(e.has_flavour(Flavour::Native), "{name}");
        }
        // cnn matches the python model's stride schedule (1, 2, 2);
        // cnn_lite is (2, 2)
        assert_eq!(m.model("cnn").unwrap().conv_strides, vec![1, 2, 2]);
        assert_eq!(m.model("cnn_lite").unwrap().conv_strides, vec![2, 2]);
    }

    #[test]
    fn conv_chain_recovers_geometry() {
        let dir = TempDir::new("chain").unwrap();
        let m = Manifest::native(dir.path());
        let (shapes, head) = m.model("cnn_lite").unwrap().conv_chain().expect("conv chain");
        assert_eq!(shapes.len(), 2);
        assert_eq!((shapes[0].h, shapes[0].w, shapes[0].cin, shapes[0].cout), (16, 16, 3, 16));
        assert_eq!((shapes[0].oh, shapes[0].ow), (8, 8), "stride 2 halves 16×16");
        assert_eq!((shapes[1].oh, shapes[1].ow), (4, 4));
        assert_eq!(head, (32, 100));
        let (shapes, head) = m.model("cnn").unwrap().conv_chain().expect("conv chain");
        assert_eq!(shapes.len(), 3);
        assert_eq!((shapes[0].oh, shapes[0].ow), (16, 16), "stride 1 preserves 16×16");
        assert_eq!((shapes[2].oh, shapes[2].ow), (4, 4));
        assert_eq!(head, (128, 100));
        // dense entries have no conv chain
        assert!(m.model("mlp").unwrap().conv_chain().is_none());
        // and malformed conv entries say None rather than panicking
        let mut e = m.model("cnn_lite").unwrap().clone();
        e.params[0].shape = vec![3, 3, 9, 16];
        assert!(e.conv_chain().is_none());
    }

    #[test]
    fn conv_strides_are_validated() {
        let dir = TempDir::new("convval").unwrap();
        let mut m = Manifest::native(dir.path());
        m.models.get_mut("cnn_lite").unwrap().conv_strides = vec![2];
        assert!(m.validate().is_err(), "stride/param arity mismatch must fail");
        let mut m = Manifest::native(dir.path());
        m.models.get_mut("cnn_lite").unwrap().conv_strides = vec![0, 2];
        assert!(m.validate().is_err(), "zero stride must fail");
        let mut m = Manifest::native(dir.path());
        m.models.get_mut("mlp").unwrap().conv_strides = vec![1];
        assert!(m.validate().is_err(), "conv_strides on a flat model must fail");
    }

    #[test]
    fn conv_strides_parse_from_json() {
        let dir = TempDir::new("convjson").unwrap();
        let doc = r#"{
  "version": 1,
  "batch": 4,
  "models": {
    "c": {
      "task": "classification",
      "x_shape": [4, 4, 1],
      "num_classes": 2,
      "y_dtype": "i32",
      "conv_strides": [2],
      "params": [
        {"name": "k1", "shape": [3, 3, 1, 2]},
        {"name": "cb1", "shape": [2]},
        {"name": "wh", "shape": [2, 2]},
        {"name": "bh", "shape": [2]}
      ],
      "executables": {"init:native": "<builtin>", "fwd_loss:native": "<builtin>",
        "train_step:native": "<builtin>", "grads:native": "<builtin>",
        "apply:native": "<builtin>", "eval:native": "<builtin>"}
    }
  }
}"#;
        std::fs::write(dir.path().join("manifest.json"), doc).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.model("c").unwrap().conv_strides, vec![2]);
        // absent key defaults to empty (the toy manifest has none)
        let dir2 = TempDir::new("convjson2").unwrap();
        write_toy_manifest(dir2.path(), None);
        let m2 = Manifest::load(dir2.path()).unwrap();
        assert!(m2.model("m").unwrap().conv_strides.is_empty());
    }

    #[test]
    fn dense_dims_recovers_chain_widths() {
        let dir = TempDir::new("dims").unwrap();
        let m = Manifest::native(dir.path());
        let mlp = m.model("mlp").unwrap();
        let dims = mlp.dense_dims().expect("mlp is a dense chain");
        assert_eq!(dims.first(), Some(&mlp.x_shape[0]));
        assert_eq!(dims.len(), mlp.n_params() / 2 + 1);
        assert_eq!(dims.last(), Some(&mlp.num_classes));
        // non-chain entries (conv-shaped input / odd params /
        // non-chaining widths) say None
        let mut conv = mlp.clone();
        conv.x_shape = vec![8, 8, 1];
        assert!(conv.dense_dims().is_none());
        let mut odd = mlp.clone();
        odd.params.pop();
        assert!(odd.dense_dims().is_none());
        let mut broken = mlp.clone();
        broken.params[2].shape[0] += 1; // second weight no longer chains
        assert!(broken.dense_dims().is_none());
    }

    #[test]
    fn load_or_native_falls_back_when_artifacts_absent() {
        let dir = TempDir::new("fallback").unwrap();
        let m = Manifest::load_or_native(dir.path()).unwrap();
        assert!(m.models.contains_key("linreg"));
        assert!(m.models.contains_key("mlp"));
        // and prefers a real manifest when one exists
        write_toy_manifest(dir.path(), None);
        let m = Manifest::load_or_native(dir.path()).unwrap();
        assert!(m.models.contains_key("m"));
        // artifact flavours are only the default when they can execute
        #[cfg(feature = "pjrt")]
        assert_eq!(m.default_flavour(), Flavour::Jnp);
        #[cfg(not(feature = "pjrt"))]
        assert_eq!(m.default_flavour(), Flavour::Native);
    }

    #[test]
    fn resolve_flavour_auto_and_strict() {
        let dir = TempDir::new("resolve").unwrap();
        let m = Manifest::native(dir.path());
        assert_eq!(m.resolve_flavour("auto").unwrap(), Flavour::Native);
        assert_eq!(m.resolve_flavour("jnp").unwrap(), Flavour::Jnp);
        assert!(m.resolve_flavour("cuda").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp"));
            assert_eq!(m.batch, 128);
        }
    }
}
