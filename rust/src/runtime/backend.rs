//! The backend abstraction: one model's executor behind a trait object.
//!
//! A backend owns the resident parameters and runs the six model
//! executables (`init`, `fwd_loss`, `train_step`, `grads`, `apply`,
//! `eval`) on [`HostTensor`]s. Two implementations exist:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure-Rust CPU math
//!   ported from `python/compile/kernels/ref.py`; zero dependencies,
//!   always available;
//! * `PjrtBackend` (`pjrt` cargo feature) — AOT-lowered HLO artifacts
//!   executed through the PJRT C API.
//!
//! [`crate::runtime::Session`] wraps a `Box<dyn Backend>` and owns all
//! input validation, so backends can assume well-shaped tensors.

use anyhow::{bail, Result};

use crate::data::tensor::{HostTensor, TensorData};

/// Numeric precision of the *scoring* forward ([`Backend::fwd_loss`]):
/// the "ten forward" passes whose per-example losses feed selection.
/// Training (`train_step`/`grads`/`apply`) and eval always run exact
/// f32 regardless of this setting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScorePrecision {
    /// Exact f32 scoring (the default) — `fwd_loss` stays bit-identical
    /// to the training forward.
    #[default]
    F32,
    /// bf16 packed weight/activation panels with f32 accumulation —
    /// roughly half the memory traffic on the bandwidth-bound scoring
    /// pass, under a relaxed-tolerance accuracy contract. Async
    /// pipeline only: sync mode rejects it to stay bit-exact to serial.
    Bf16,
}

impl ScorePrecision {
    /// The config/CLI spelling of this precision.
    pub fn as_str(self) -> &'static str {
        match self {
            ScorePrecision::F32 => "f32",
            ScorePrecision::Bf16 => "bf16",
        }
    }

    /// Parse the config/CLI spelling (`f32` | `bf16`).
    pub fn parse(s: &str) -> Result<ScorePrecision> {
        match s {
            "f32" => Ok(ScorePrecision::F32),
            "bf16" => Ok(ScorePrecision::Bf16),
            other => bail!("unknown score_precision {other:?} (expected f32 | bf16)"),
        }
    }
}

impl std::fmt::Display for ScorePrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Cumulative execution counters for the perf pass.
///
/// `exec_ns` is wall time across all executable calls; `forward_ns` /
/// `backward_ns` attribute the kernel time inside those calls to the
/// step's two phases (forward = batched loss/eval passes, backward =
/// gradient + update math), so benches can attribute cost to the step
/// rather than to session construction (`compile_ns`). Backends that
/// cannot split phases may leave the phase counters at zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    pub executions: u64,
    pub exec_ns: u64,
    pub compile_ns: u64,
    pub forward_ns: u64,
    pub backward_ns: u64,
}

/// One model's executor: resident parameters + the six executables.
///
/// Inputs are validated by [`crate::runtime::Session`] before they
/// reach a backend: `x`/`y` have the compiled batch shape and dtype,
/// masks have batch length, and `selected` indices are in range.
pub trait Backend {
    /// Initialize parameters deterministically from `seed`.
    fn init(&mut self, seed: i32) -> Result<()>;

    /// "Ten forward": per-example losses for the whole batch.
    fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>>;

    /// "One backward": masked train step; parameters update in place.
    /// Returns the selected-subset mean loss.
    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32>;

    /// "One backward", gathered: run the backward only on the selected
    /// rows. Numerically equivalent to [`Backend::train_step`] with the
    /// matching mask, but O(|selected|) instead of O(batch).
    fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32>;

    /// Gradients for a masked shard (the data-parallel worker path).
    /// Returns (grads, selected mean loss over this shard).
    fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)>;

    /// Apply externally averaged gradients (the leader path).
    fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()>;

    /// Masked eval sums: `(sum_loss, sum_metric, count)`.
    fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)>;

    /// Copy the resident parameters to host (checkpointing / broadcast).
    fn params_to_host(&self) -> Result<Vec<HostTensor>>;

    /// Replace the resident parameters from host tensors.
    fn load_params(&mut self, params: &[HostTensor]) -> Result<()>;

    /// How many parameter tensors are currently resident (0 before
    /// `init`/`load_params`).
    fn n_resident_params(&self) -> usize;

    /// Cumulative execution counters.
    fn stats(&self) -> SessionStats;

    /// Human-readable execution platform (e.g. `"native-cpu"`).
    fn platform_name(&self) -> String;

    /// Select the precision of subsequent [`Backend::fwd_loss`] calls.
    /// Backends without a reduced-precision scoring path may ignore
    /// this (the default is a no-op): `ScorePrecision::F32` must always
    /// be honoured, `Bf16` is a best-effort fast path.
    fn set_score_precision(&mut self, _precision: ScorePrecision) {}
}

/// Gather `selected` rows of a batch into a `rows`-row sub-batch,
/// zero-padding when `rows > selected.len()`. `batch` is the row count
/// of `x`/`y`; indices must already be validated against it.
pub(crate) fn gather_rows(
    x: &HostTensor,
    y: &HostTensor,
    selected: &[usize],
    rows: usize,
    batch: usize,
) -> Result<(HostTensor, HostTensor)> {
    if selected.len() > rows {
        bail!("gather_rows: {} selected rows > target {rows}", selected.len());
    }
    let stride = x.element_count() / batch;
    let xv = x.as_f32()?;
    let mut gx = vec![0.0f32; rows * stride];
    for (row, &i) in selected.iter().enumerate() {
        if i >= batch {
            bail!("selected index {i} out of range");
        }
        gx[row * stride..(row + 1) * stride]
            .copy_from_slice(&xv[i * stride..(i + 1) * stride]);
    }
    let mut gshape = x.shape.clone();
    gshape[0] = rows;
    let gx = HostTensor { shape: gshape, data: TensorData::F32(gx) };
    let gy = match &y.data {
        TensorData::F32(v) => {
            let mut out = vec![0.0f32; rows];
            for (row, &i) in selected.iter().enumerate() {
                out[row] = v[i];
            }
            HostTensor { shape: vec![rows], data: TensorData::F32(out) }
        }
        TensorData::I32(v) => {
            let mut out = vec![0i32; rows];
            for (row, &i) in selected.iter().enumerate() {
                out[row] = v[i];
            }
            HostTensor { shape: vec![rows], data: TensorData::I32(out) }
        }
        TensorData::Bf16(_) => bail!("bf16 tensors are wire-only; expand_to_f32() before gather"),
    };
    Ok((gx, gy))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_precision_round_trips_and_rejects_junk() {
        assert_eq!(ScorePrecision::default(), ScorePrecision::F32);
        for p in [ScorePrecision::F32, ScorePrecision::Bf16] {
            assert_eq!(ScorePrecision::parse(p.as_str()).unwrap(), p);
            assert_eq!(format!("{p}"), p.as_str());
        }
        let err = ScorePrecision::parse("f16").unwrap_err().to_string();
        assert!(err.contains("f32 | bf16"), "err: {err}");
    }

    #[test]
    fn gather_rows_picks_and_pads() {
        let x = HostTensor::f32(vec![4, 2], vec![0., 1., 2., 3., 4., 5., 6., 7.]).unwrap();
        let y = HostTensor::i32(vec![4], vec![10, 11, 12, 13]).unwrap();
        let (gx, gy) = gather_rows(&x, &y, &[3, 1], 3, 4).unwrap();
        assert_eq!(gx.shape, vec![3, 2]);
        assert_eq!(gx.as_f32().unwrap(), &[6., 7., 2., 3., 0., 0.]);
        assert_eq!(gy.as_i32().unwrap(), &[13, 11, 0]);
    }

    #[test]
    fn gather_rows_rejects_bad_input() {
        let x = HostTensor::f32(vec![2, 1], vec![0., 1.]).unwrap();
        let y = HostTensor::f32(vec![2], vec![0., 1.]).unwrap();
        assert!(gather_rows(&x, &y, &[5], 1, 2).is_err());
        assert!(gather_rows(&x, &y, &[0, 1], 1, 2).is_err());
    }
}
