//! Execution runtime: the [`Backend`] abstraction and its two
//! implementations, plus the manifest contract and the leader/worker
//! engine.
//!
//! * [`manifest`] — the python→rust interchange contract (and the
//!   synthesized native manifest used when no artifacts exist);
//! * [`backend`]  — the `Backend` trait a [`Session`] dispatches onto;
//! * [`native`]   — pure-Rust CPU backend (hermetic default);
//! * [`kernels`]  — the native backend's blocked/SIMD-friendly,
//!   multi-threaded dense kernels plus their naive reference oracle;
//! * `pjrt`       — AOT HLO artifacts via the PJRT C API (`pjrt`
//!   cargo feature);
//! * [`session`]  — single-threaded model session with resident params;
//! * [`engine`]   — leader/worker thread pool for data-parallel steps.

pub mod backend;
pub mod engine;
pub mod kernels;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod session;

pub use backend::{Backend, ScorePrecision, SessionStats};
pub use engine::Engine;
pub use kernels::{Arena, KernelConfig, KernelFlavour};
pub use manifest::{Exe, Flavour, Manifest, ModelEntry, ParamEntry, NATIVE_BATCH};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{compile_hlo, from_literal, to_literal};
pub use session::Session;
