//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` + the
//! manifest) and execute them from the rust hot path.
//!
//! * [`manifest`] — the python→rust interchange contract;
//! * [`session`]  — single-threaded model session with resident params;
//! * [`engine`]   — leader/worker thread pool for data-parallel steps.

pub mod engine;
pub mod manifest;
pub mod session;

pub use engine::Engine;
pub use manifest::{Exe, Flavour, Manifest, ModelEntry, ParamEntry};
pub use session::{compile_hlo, from_literal, to_literal, Session, SessionStats};
