//! Pure-Rust native CPU backend: the hermetic execution path.
//!
//! Ports the oracles in `python/compile/kernels/ref.py` to Rust so the
//! full Algorithm-1 loop runs on any machine with no artifacts, JAX or
//! PJRT:
//!
//! * `matmul_bias_act` — `act(x · W + b)` with f32 accumulation, ReLU
//!   on hidden layers;
//! * `softmax_xent` / `mse` — per-example losses (stable logsumexp);
//! * `softmax_xent_grad` / `mse_grad` — head gradients with
//!   `dloss = mask / max(Σmask, 1)`, i.e. the masked-mean objective of
//!   `model.py::_masked_loss_fn`;
//! * `sgd_update` — `w − lr·g`.
//!
//! The dense math itself lives in [`super::kernels`]: cache-blocked,
//! register-tiled, multi-threaded kernels by default
//! (`OBFTF_NATIVE_THREADS` controls sharding,
//! `OBFTF_NATIVE_KERNELS=reference` selects the naive oracle loops),
//! with a scratch [`Arena`] recycling the per-step working set
//! (activations, packed panels, head gradients) across steps — in
//! steady state only the gradient tensors handed back to the caller
//! are freshly allocated.
//!
//! The backend executes two manifest topologies:
//!
//! * **dense chains** — alternating `(weight [d_in, d_out], bias
//!   [d_out])` pairs over flat features (linreg, the 784-256-256-10
//!   MLP);
//! * **conv chains** — NHWC input, SAME-padded 3×3 conv layers (HWIO
//!   kernels, strides from the manifest's `conv_strides`), global
//!   average pooling, and a dense head (cnn, cnn_lite) — mirroring
//!   `python/compile/model.py::_cnn_predict_generic`. Conv forward and
//!   backward lower onto the blocked GEMM tiles via im2col (see
//!   [`super::kernels::conv`]).
//!
//! `train_step` computes the same masked gradients as `grads` followed
//! by `apply`, so serial fused steps and the leader/worker
//! grads→average→apply protocol walk identical trajectories. The
//! gathered sub-batch step stays bit-identical to the masked full-batch
//! step on both topologies, at any thread count (every kernel reduction
//! runs in a fixed per-element order — see the [`super::kernels`]
//! module docs).

use anyhow::{bail, Result};

use super::backend::{gather_rows, Backend, ScorePrecision, SessionStats};
use super::kernels::{self, conv, Arena, ConvShape, KernelConfig};
use super::manifest::ModelEntry;
use crate::data::rng::Rng;
use crate::data::tensor::{HostTensor, TensorData};

/// Seed-mixing constant so parameter init draws are decorrelated from
/// dataset generators seeded with the same user seed.
const INIT_SEED_MIX: u64 = 0x6f62_6674_665f_696e; // "obftf_in"

/// Dense-chain topology: layer widths `[d_in, h_1, …, d_out]`.
struct DenseChain {
    dims: Vec<usize>,
    classification: bool,
}

impl DenseChain {
    fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }
}

/// Conv-chain topology: SAME-padded conv stack → global average pool →
/// dense head, over NHWC images.
struct ConvNet {
    convs: Vec<ConvShape>,
    /// Head input width (= the last conv layer's channel count).
    head_in: usize,
    /// Head output width (num_classes, or 1 for regression).
    out: usize,
    classification: bool,
}

/// What a manifest entry's parameter list executes as.
enum Topology {
    Dense(DenseChain),
    Conv(ConvNet),
}

impl Topology {
    fn classification(&self) -> bool {
        match self {
            Topology::Dense(c) => c.classification,
            Topology::Conv(c) => c.classification,
        }
    }

    /// Head width (the per-example logits/prediction width).
    fn out_width(&self) -> usize {
        match self {
            Topology::Dense(c) => *c.dims.last().expect("dims never empty"),
            Topology::Conv(c) => c.out,
        }
    }

    /// Flat input elements per example.
    #[cfg(test)]
    fn in_elems(&self) -> usize {
        match self {
            Topology::Dense(c) => c.dims[0],
            Topology::Conv(c) => c.convs[0].in_elems(),
        }
    }
}

/// Resolve a manifest entry into an executable topology, validating
/// shapes. Dense chains keep the PR-1 error contract; conv chains need
/// the manifest's `conv_strides` (artifact manifests without them run
/// conv models via the `pjrt` feature instead).
fn parse_topology(model: &str, entry: &ModelEntry) -> Result<Topology> {
    match entry.x_shape.len() {
        1 => parse_dense(model, entry),
        3 => parse_conv(model, entry),
        _ => bail!(
            "native backend supports flat-feature or NHWC models only; \
             model {model} has x_shape {:?} (use the pjrt feature for other layouts)",
            entry.x_shape
        ),
    }
}

fn parse_dense(model: &str, entry: &ModelEntry) -> Result<Topology> {
    if entry.params.is_empty() || entry.params.len() % 2 != 0 {
        bail!(
            "native backend expects (weight, bias) parameter pairs; \
             model {model} has {} tensors",
            entry.params.len()
        );
    }
    let mut dims = vec![entry.x_shape[0]];
    for pair in entry.params.chunks(2) {
        let (w, b) = (&pair[0], &pair[1]);
        if w.shape.len() != 2 || b.shape.len() != 1 || w.shape[1] != b.shape[0] {
            bail!(
                "model {model}: parameter pair {}/{} is not dense \
                 (shapes {:?} / {:?})",
                w.name,
                b.name,
                w.shape,
                b.shape
            );
        }
        let prev = *dims.last().expect("dims starts non-empty");
        if w.shape[0] != prev {
            bail!(
                "model {model}: layer input width {} does not chain onto \
                 previous width {prev}",
                w.shape[0]
            );
        }
        dims.push(w.shape[1]);
    }
    let classification = entry.is_classification();
    let out = *dims.last().expect("dims starts non-empty");
    check_head(model, entry, classification, out)?;
    Ok(Topology::Dense(DenseChain { dims, classification }))
}

fn parse_conv(model: &str, entry: &ModelEntry) -> Result<Topology> {
    if entry.conv_strides.is_empty() {
        bail!(
            "model {model}: NHWC input {:?} but the manifest carries no conv_strides; \
             artifact manifests run conv models via the pjrt feature",
            entry.x_shape
        );
    }
    let n_convs = entry.conv_strides.len();
    if entry.params.len() != 2 * (n_convs + 1) {
        bail!(
            "model {model}: {n_convs} conv layers + pooled head need {} param tensors, got {}",
            2 * (n_convs + 1),
            entry.params.len()
        );
    }
    if entry.x_shape.iter().any(|&d| d == 0) {
        bail!("model {model}: zero-sized x_shape {:?}", entry.x_shape);
    }
    let mut cin = entry.x_shape[2];
    for (l, (&stride, pair)) in
        entry.conv_strides.iter().zip(entry.params.chunks(2)).enumerate()
    {
        let (k, b) = (&pair[0], &pair[1]);
        if k.shape.len() != 4 || b.shape.len() != 1 || k.shape[3] != b.shape[0] {
            bail!(
                "model {model}: conv pair {}/{} is not HWIO kernel + bias \
                 (shapes {:?} / {:?})",
                k.name,
                b.name,
                k.shape,
                b.shape
            );
        }
        if k.shape[2] != cin {
            bail!(
                "model {model}: conv layer {l} input channels {} do not chain onto \
                 previous channels {cin}",
                k.shape[2]
            );
        }
        if stride == 0 {
            bail!("model {model}: conv layer {l} has stride 0");
        }
        if k.shape.iter().any(|&d| d == 0) {
            bail!("model {model}: conv layer {l} has a zero kernel dim {:?}", k.shape);
        }
        cin = k.shape[3];
    }
    let head = &entry.params[2 * n_convs..];
    let (hw, hb) = (&head[0], &head[1]);
    if hw.shape.len() != 2 || hb.shape.len() != 1 || hw.shape[1] != hb.shape[0] {
        bail!(
            "model {model}: head pair {}/{} is not dense (shapes {:?} / {:?})",
            hw.name,
            hb.name,
            hw.shape,
            hb.shape
        );
    }
    if hw.shape[0] != cin {
        bail!(
            "model {model}: head input width {} != pooled channels {cin}",
            hw.shape[0]
        );
    }
    let classification = entry.is_classification();
    check_head(model, entry, classification, hw.shape[1])?;
    // Geometry comes from the one shared walk (`ModelEntry::conv_chain`)
    // so the backend and the bench FLOP accounting can never disagree
    // on shapes. Conv-entry invariants live in three places — the
    // checks above (detailed errors), `conv_chain` (the geometry walk),
    // and the arity/stride subset in `Manifest::validate` — keep them
    // in sync when the topology rules change. The checks above mirror
    // every condition `conv_chain` rejects today; if it ever grows one
    // they miss, refuse to start rather than panic.
    let Some((convs, (head_in, out))) = entry.conv_chain() else {
        bail!("model {model}: parameter list does not form a conv chain");
    };
    Ok(Topology::Conv(ConvNet { convs, head_in, out, classification }))
}

fn check_head(model: &str, entry: &ModelEntry, classification: bool, out: usize) -> Result<()> {
    if classification && out != entry.num_classes {
        bail!("model {model}: head width {out} != num_classes {}", entry.num_classes);
    }
    if !classification && out != 1 {
        bail!("model {model}: regression head must have width 1, got {out}");
    }
    Ok(())
}

/// The pure-Rust CPU backend ([`Flavour::Native`]).
///
/// [`Flavour::Native`]: super::manifest::Flavour::Native
pub struct NativeBackend {
    topo: Topology,
    entry: ModelEntry,
    batch: usize,
    /// Resident parameters in manifest order (w_0, b_0, w_1, b_1, …).
    params: Vec<HostTensor>,
    stats: SessionStats,
    /// Kernel implementation + thread count (resolved once, at build).
    kcfg: KernelConfig,
    /// Precision of the scoring forward ([`Backend::fwd_loss`]) only —
    /// training and eval always run exact f32.
    score_precision: ScorePrecision,
    /// Recycled scratch buffers (activations, packed panels, head
    /// gradients) — see [`Arena`].
    scratch: Arena,
}

impl NativeBackend {
    /// Build from a manifest entry, validating that the parameter list
    /// forms a topology the native math can execute. Kernel flavour
    /// and thread count come from the environment
    /// (`OBFTF_NATIVE_KERNELS`, `OBFTF_NATIVE_THREADS`).
    pub fn new(model: &str, entry: &ModelEntry, batch: usize) -> Result<NativeBackend> {
        NativeBackend::with_kernel_config(model, entry, batch, KernelConfig::from_env())
    }

    /// Build with an explicit kernel configuration — the
    /// deterministic-by-construction path benches and property tests
    /// use to pin flavour/threads without touching the environment.
    pub fn with_kernel_config(
        model: &str,
        entry: &ModelEntry,
        batch: usize,
        kcfg: KernelConfig,
    ) -> Result<NativeBackend> {
        let t0 = std::time::Instant::now();
        let topo = parse_topology(model, entry)?;
        let stats = SessionStats {
            // clamp to 1 ns so stats always witness construction
            compile_ns: (t0.elapsed().as_nanos() as u64).max(1),
            ..Default::default()
        };
        Ok(NativeBackend {
            topo,
            entry: entry.clone(),
            batch,
            params: vec![],
            stats,
            kcfg,
            score_precision: ScorePrecision::F32,
            scratch: Arena::new(),
        })
    }

    fn bump(&mut self, t0: std::time::Instant) {
        self.stats.executions += 1;
        self.stats.exec_ns += t0.elapsed().as_nanos() as u64;
    }

    /// Per-example losses from head outputs (ref.py `softmax_xent` /
    /// `mse`).
    fn per_example_losses(&self, logits: &[f32], y: &HostTensor, n: usize) -> Result<Vec<f32>> {
        let c = self.topo.out_width();
        let mut out = vec![0.0f32; n];
        if self.topo.classification() {
            let labels = y.as_i32()?;
            for i in 0..n {
                let row = &logits[i * c..(i + 1) * c];
                let label = labels[i];
                if label < 0 || label as usize >= c {
                    bail!("label {label} outside [0, {c})");
                }
                out[i] = logsumexp(row) - row[label as usize];
            }
        } else {
            let targets = y.as_f32()?;
            for i in 0..n {
                let d = logits[i] - targets[i];
                out[i] = d * d;
            }
        }
        Ok(out)
    }

    /// Masked-mean loss gradients — the value-and-grad of
    /// `masked_mean(per_example_loss)` from `model.py`. Returns the
    /// gradients in manifest parameter order plus the selected mean
    /// loss. `mask.len()` is the row count (callers may pass gathered
    /// sub-batches smaller than the compiled batch).
    ///
    /// Also splits the elapsed kernel time into
    /// [`SessionStats::forward_ns`] / [`SessionStats::backward_ns`].
    fn compute_grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = std::time::Instant::now();
        let n = mask.len();
        let xs = x.as_f32()?;
        let c = self.topo.out_width();
        let acts =
            forward_topo(&self.topo, &self.params, &self.kcfg, &mut self.scratch, xs, n, false);
        let logits = acts.last().expect("every topology ends in a head");
        let losses = self.per_example_losses(logits, y, n)?;
        let denom = mask.iter().sum::<f32>().max(1.0);
        let sel_loss = losses.iter().zip(mask).map(|(l, m)| l * m).sum::<f32>() / denom;
        let fwd_ns = t0.elapsed().as_nanos() as u64;

        // head gradient dL/dz with dloss_i = mask_i / denom
        // (ref.py softmax_xent_grad / mse_grad)
        let mut dz = self.scratch.take(n * c);
        if self.topo.classification() {
            let labels = y.as_i32()?;
            for i in 0..n {
                let dl = mask[i] / denom;
                if dl == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let drow = &mut dz[i * c..(i + 1) * c];
                let mut sum = 0.0f32;
                for (d, &v) in drow.iter_mut().zip(row) {
                    *d = (v - m).exp();
                    sum += *d;
                }
                for d in drow.iter_mut() {
                    *d = *d / sum * dl;
                }
                drow[labels[i] as usize] -= dl;
            }
        } else {
            let targets = y.as_f32()?;
            for i in 0..n {
                let dl = mask[i] / denom;
                dz[i] = 2.0 * (logits[i] - targets[i]) * dl;
            }
        }

        let (params, kcfg, arena) = (&self.params, &self.kcfg, &mut self.scratch);
        let out = match &self.topo {
            Topology::Dense(chain) => dense_backward(chain, params, kcfg, arena, xs, &acts, dz, n)?,
            Topology::Conv(net) => conv_backward(net, params, kcfg, arena, xs, &acts, dz, n)?,
        };
        for a in acts {
            self.scratch.put(a);
        }
        self.stats.forward_ns += fwd_ns;
        self.stats.backward_ns += (t0.elapsed().as_nanos() as u64).saturating_sub(fwd_ns);
        Ok((out, sel_loss))
    }

    /// `w ← w − lr·g` over all resident parameters (ref.py
    /// `sgd_update`).
    fn sgd_update(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        if grads.len() != self.params.len() {
            bail!("apply got {} grads, expected {}", grads.len(), self.params.len());
        }
        for (p, g) in self.params.iter_mut().zip(grads) {
            let gv = g.as_f32()?;
            let TensorData::F32(pv) = &mut p.data else {
                bail!("non-f32 parameter");
            };
            if gv.len() != pv.len() {
                bail!("gradient size {} != parameter size {}", gv.len(), pv.len());
            }
            for (x, &d) in pv.iter_mut().zip(gv) {
                *x -= lr * d;
            }
        }
        Ok(())
    }
}

/// Forward pass over `n` rows; returns every intermediate activation
/// (the backward pass needs them all), with the head logits last.
///
/// * Dense: `acts[l] = act(input_l · W_l + b_l)` with `input_0 = x`,
///   ReLU on hidden layers, identity head (ref.py `matmul_bias_act`).
/// * Conv: `[conv act 0 … conv act L−1, pooled, logits]` — each conv
///   layer is SAME-padded + bias + ReLU, the pool is a global average,
///   the head is identity dense.
///
/// The input batch is read in place, never copied; activation buffers
/// come from `arena` and must be recycled back by the caller. A free
/// function over the backend's fields so callers can lend
/// `&mut self.scratch` while the parameters stay borrowed — the arena
/// is never moved out of the backend, even on error paths.
///
/// `bf16` selects the reduced-precision scoring GEMM for every matmul
/// in the pass (bf16 panels, f32 accumulation). Only `fwd_loss` ever
/// sets it; the training and eval forwards always pass `false`, so
/// their math stays exact f32 regardless of the scoring precision.
fn forward_topo(
    topo: &Topology,
    params: &[HostTensor],
    kcfg: &KernelConfig,
    arena: &mut Arena,
    x: &[f32],
    n: usize,
    bf16: bool,
) -> Vec<Vec<f32>> {
    match topo {
        Topology::Dense(chain) => {
            let nl = chain.n_layers();
            let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl);
            for l in 0..nl {
                let (din, dout) = (chain.dims[l], chain.dims[l + 1]);
                let w = params[2 * l].as_f32().expect("parameters are f32");
                let b = params[2 * l + 1].as_f32().expect("parameters are f32");
                let h: &[f32] = if l == 0 { x } else { &acts[l - 1] };
                let mut z = arena.take(n * dout);
                let relu = l + 1 < nl;
                kernels::matmul_bias_act_scored(
                    kcfg, arena, h, w, b, &mut z, n, din, dout, relu, bf16,
                );
                acts.push(z);
            }
            acts
        }
        Topology::Conv(net) => {
            let nl = net.convs.len();
            let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl + 2);
            for (l, cs) in net.convs.iter().enumerate() {
                let k = params[2 * l].as_f32().expect("parameters are f32");
                let b = params[2 * l + 1].as_f32().expect("parameters are f32");
                let h: &[f32] = if l == 0 { x } else { &acts[l - 1] };
                let mut z = arena.take(n * cs.out_elems());
                kernels::conv2d_bias_act_scored(kcfg, arena, h, k, b, &mut z, n, cs, true, bf16);
                acts.push(z);
            }
            let last = &net.convs[nl - 1];
            let mut pooled = arena.take(n * net.head_in);
            conv::global_avg_pool(&acts[nl - 1], &mut pooled, n, last.positions(), net.head_in);
            let wh = params[2 * nl].as_f32().expect("parameters are f32");
            let bh = params[2 * nl + 1].as_f32().expect("parameters are f32");
            let mut logits = arena.take(n * net.out);
            kernels::matmul_bias_act_scored(
                kcfg,
                arena,
                &pooled,
                wh,
                bh,
                &mut logits,
                n,
                net.head_in,
                net.out,
                false,
                bf16,
            );
            acts.push(pooled);
            acts.push(logits);
            acts
        }
    }
}

/// Dense-chain backward: `dW_l = actsᵀ_l · dz`, `db_l = Σ dz`,
/// `dh = dz · Wᵀ_l` gated by the ReLU (acts > 0 ⟺ pre-act > 0).
/// Consumes the head gradient buffer and recycles it into `arena`.
#[allow(clippy::too_many_arguments)]
fn dense_backward(
    chain: &DenseChain,
    params: &[HostTensor],
    kcfg: &KernelConfig,
    arena: &mut Arena,
    xs: &[f32],
    acts: &[Vec<f32>],
    mut dz: Vec<f32>,
    n: usize,
) -> Result<Vec<HostTensor>> {
    let nl = chain.n_layers();
    let mut grads: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..nl).map(|_| None).collect();
    for l in (0..nl).rev() {
        let (din, dout) = (chain.dims[l], chain.dims[l + 1]);
        let h: &[f32] = if l == 0 { xs } else { &acts[l - 1] };
        let mut dw = vec![0.0f32; din * dout];
        let mut db = vec![0.0f32; dout];
        kernels::grad_weights(kcfg, arena, h, &dz, &mut dw, &mut db, n, din, dout);
        if l > 0 {
            let w = params[2 * l].as_f32()?;
            let mut dh = arena.take(n * din);
            kernels::grad_input(kcfg, arena, &dz, w, h, &mut dh, n, din, dout);
            arena.put(std::mem::replace(&mut dz, dh));
        }
        grads[l] = Some((dw, db));
    }
    arena.put(dz);
    let mut out = Vec::with_capacity(2 * nl);
    for (l, g) in grads.into_iter().enumerate() {
        let (dw, db) = g.expect("filled by the backward loop");
        out.push(HostTensor::f32(vec![chain.dims[l], chain.dims[l + 1]], dw)?);
        out.push(HostTensor::f32(vec![chain.dims[l + 1]], db)?);
    }
    Ok(out)
}

/// Conv-chain backward: dense head gradients, the ungated pooled
/// gradient `dz · Whᵀ`, the global-average-pool spread (each position
/// inherits `1/positions` of its channel's gradient) gated by the last
/// conv ReLU, then per-conv-layer `dK`/`db` and the gated input
/// gradient, deepest layer first. Consumes `dz`, recycles every
/// intermediate into `arena`.
#[allow(clippy::too_many_arguments)]
fn conv_backward(
    net: &ConvNet,
    params: &[HostTensor],
    kcfg: &KernelConfig,
    arena: &mut Arena,
    xs: &[f32],
    acts: &[Vec<f32>],
    dz: Vec<f32>,
    n: usize,
) -> Result<Vec<HostTensor>> {
    let nl = net.convs.len();
    let pooled = &acts[nl];
    let (cl, out_w) = (net.head_in, net.out);
    // head dense gradients
    let mut dwh = vec![0.0f32; cl * out_w];
    let mut dbh = vec![0.0f32; out_w];
    kernels::grad_weights(kcfg, arena, pooled, &dz, &mut dwh, &mut dbh, n, cl, out_w);
    // pooled gradient — the pool output is a linear node, no gate
    let wh = params[2 * nl].as_f32()?;
    let mut dpool = arena.take(n * cl);
    kernels::matmul_dz_wt(kcfg, arena, &dz, wh, &mut dpool, n, cl, out_w);
    arena.put(dz);
    // spread through the global average pool, gated by the last conv
    // ReLU in the same pass
    let last = &net.convs[nl - 1];
    let mut dspat = arena.take(n * last.out_elems());
    conv::global_avg_pool_grad(&dpool, &mut dspat, Some(&acts[nl - 1]), n, last.positions(), cl);
    arena.put(dpool);
    // conv layers, deepest first
    let mut grads: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..nl).map(|_| None).collect();
    for l in (0..nl).rev() {
        let cs = &net.convs[l];
        let input: &[f32] = if l == 0 { xs } else { &acts[l - 1] };
        let mut dk = vec![0.0f32; cs.patch_len() * cs.cout];
        let mut db = vec![0.0f32; cs.cout];
        kernels::conv2d_grad_w(kcfg, arena, input, &dspat, &mut dk, &mut db, n, cs);
        if l > 0 {
            let k = params[2 * l].as_f32()?;
            let mut dx = arena.take(n * cs.in_elems());
            kernels::conv2d_grad_x(kcfg, arena, &dspat, k, input, &mut dx, n, cs);
            arena.put(std::mem::replace(&mut dspat, dx));
        }
        grads[l] = Some((dk, db));
    }
    arena.put(dspat);
    let mut out = Vec::with_capacity(2 * (nl + 1));
    for (l, g) in grads.into_iter().enumerate() {
        let (dk, db) = g.expect("filled by the backward loop");
        let cs = &net.convs[l];
        out.push(HostTensor::f32(vec![cs.kh, cs.kw, cs.cin, cs.cout], dk)?);
        out.push(HostTensor::f32(vec![cs.cout], db)?);
    }
    out.push(HostTensor::f32(vec![cl, out_w], dwh)?);
    out.push(HostTensor::f32(vec![out_w], dbh)?);
    Ok(out)
}

/// Numerically stable `log(Σ exp(row))` (ref.py `softmax_xent`).
fn logsumexp(row: &[f32]) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

impl Backend for NativeBackend {
    /// He initialization for weights (`N(0, 2/fan_in)`, with
    /// `fan_in = prod(shape[..-1])` — so HWIO conv kernels get
    /// `kh·kw·cin`), zeros for biases — the same scheme as
    /// `model.py::init_params`, drawn from the crate's deterministic
    /// [`Rng`] instead of JAX's PRNG.
    fn init(&mut self, seed: i32) -> Result<()> {
        let t0 = std::time::Instant::now();
        let mut rng = Rng::seed_from((seed as i64 as u64) ^ INIT_SEED_MIX);
        let mut params = Vec::with_capacity(self.entry.params.len());
        for spec in &self.entry.params {
            let count: usize = spec.shape.iter().product();
            let data = if spec.shape.len() == 1 {
                vec![0.0f32; count]
            } else {
                let fan_in: usize = spec.shape[..spec.shape.len() - 1].iter().product();
                let scale = (2.0 / fan_in as f64).sqrt();
                (0..count).map(|_| (scale * rng.normal()) as f32).collect()
            };
            params.push(HostTensor::f32(spec.shape.clone(), data)?);
        }
        self.params = params;
        self.bump(t0);
        Ok(())
    }

    fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>> {
        let t0 = std::time::Instant::now();
        let n = self.batch;
        let xs = x.as_f32()?;
        let bf16 = self.score_precision == ScorePrecision::Bf16;
        let acts =
            forward_topo(&self.topo, &self.params, &self.kcfg, &mut self.scratch, xs, n, bf16);
        let logits = acts.last().expect("every topology ends in a head");
        let losses = self.per_example_losses(logits, y, n);
        for a in acts {
            self.scratch.put(a);
        }
        let losses = losses?;
        self.stats.forward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(losses)
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let (grads, sel_loss) = self.compute_grads(x, y, mask)?;
        let t1 = std::time::Instant::now();
        self.sgd_update(&grads, lr)?;
        self.stats.backward_ns += t1.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sel_loss)
    }

    /// Gathered backward: rebuild an O(|selected|) sub-batch and run the
    /// masked step on it. Indices are gathered in ascending order, so
    /// every reduction visits the same nonzero terms in the same order
    /// as the masked full-batch step (whose masked-out rows contribute
    /// exact zeros) — the result is bit-identical to
    /// [`Backend::train_step`] with the matching mask. The kernels
    /// preserve this at any thread count and on both topologies:
    /// reductions never reorder across batch rows (see
    /// [`super::kernels`]).
    fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let k = selected.len();
        let mut sorted: Vec<usize> = selected.to_vec();
        sorted.sort_unstable();
        let (gx, gy) = gather_rows(x, y, &sorted, k, self.batch)?;
        let mask = vec![1.0f32; k];
        let (grads, sel_loss) = self.compute_grads(&gx, &gy, &mask)?;
        let t1 = std::time::Instant::now();
        self.sgd_update(&grads, lr)?;
        self.stats.backward_ns += t1.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sel_loss)
    }

    fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = std::time::Instant::now();
        let out = self.compute_grads(x, y, mask)?;
        self.bump(t0);
        Ok(out)
    }

    fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        let t0 = std::time::Instant::now();
        self.sgd_update(grads, lr)?;
        self.stats.backward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(())
    }

    fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)> {
        let t0 = std::time::Instant::now();
        let n = self.batch;
        let c = self.topo.out_width();
        let xs = x.as_f32()?;
        let acts =
            forward_topo(&self.topo, &self.params, &self.kcfg, &mut self.scratch, xs, n, false);
        let logits = acts.last().expect("every topology ends in a head");
        let losses = self.per_example_losses(logits, y, n)?;
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        if self.topo.classification() {
            let labels = y.as_i32()?;
            for i in 0..n {
                let m = mask[i] as f64;
                if m == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                let correct = if best == labels[i] as usize { 1.0 } else { 0.0 };
                sums.0 += losses[i] as f64 * m;
                sums.1 += correct * m;
                sums.2 += m;
            }
        } else {
            for i in 0..n {
                let m = mask[i] as f64;
                if m == 0.0 {
                    continue; // inf·0 on a diverged padded row would NaN the sums
                }
                sums.0 += losses[i] as f64 * m;
                sums.1 += losses[i] as f64 * m; // metric = squared error
                sums.2 += m;
            }
        }
        for a in acts {
            self.scratch.put(a);
        }
        self.stats.forward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sums)
    }

    fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        Ok(self.params.clone())
    }

    fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        if params.len() != self.entry.n_params() {
            bail!(
                "load_params got {} tensors, expected {}",
                params.len(),
                self.entry.n_params()
            );
        }
        for (t, spec) in params.iter().zip(&self.entry.params) {
            if t.shape != spec.shape {
                bail!("param {}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
            if !t.is_f32() {
                bail!("param {}: parameters must be f32", spec.name);
            }
        }
        self.params = params.to_vec();
        Ok(())
    }

    fn n_resident_params(&self) -> usize {
        self.params.len()
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }

    fn platform_name(&self) -> String {
        "native-cpu".to_string()
    }

    fn set_score_precision(&mut self, precision: ScorePrecision) {
        self.score_precision = precision;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamEntry;
    use std::collections::BTreeMap;

    fn chain_entry(task: &str, dims: &[usize], num_classes: usize) -> ModelEntry {
        let mut params = Vec::new();
        for (l, pair) in dims.windows(2).enumerate() {
            params.push(ParamEntry { name: format!("w{l}"), shape: vec![pair[0], pair[1]] });
            params.push(ParamEntry { name: format!("b{l}"), shape: vec![pair[1]] });
        }
        ModelEntry {
            task: task.to_string(),
            x_shape: vec![dims[0]],
            num_classes,
            y_dtype: if task == "classification" { "i32" } else { "f32" }.to_string(),
            params,
            conv_strides: vec![],
            executables: BTreeMap::new(),
        }
    }

    /// Tiny conv entry: `hw×hw×cin` input, 3×3 SAME conv layers with
    /// per-layer (width, stride), GAP, dense head to `num_classes`.
    fn conv_entry(
        hw: usize,
        cin: usize,
        widths_strides: &[(usize, usize)],
        num_classes: usize,
    ) -> ModelEntry {
        let mut params = Vec::new();
        let mut strides = Vec::new();
        let mut c = cin;
        for (l, &(cout, stride)) in widths_strides.iter().enumerate() {
            params.push(ParamEntry { name: format!("k{l}"), shape: vec![3, 3, c, cout] });
            params.push(ParamEntry { name: format!("cb{l}"), shape: vec![cout] });
            strides.push(stride);
            c = cout;
        }
        params.push(ParamEntry { name: "wh".into(), shape: vec![c, num_classes] });
        params.push(ParamEntry { name: "bh".into(), shape: vec![num_classes] });
        ModelEntry {
            task: "classification".to_string(),
            x_shape: vec![hw, hw, cin],
            num_classes,
            y_dtype: "i32".to_string(),
            params,
            conv_strides: strides,
            executables: BTreeMap::new(),
        }
    }

    fn backend(task: &str, dims: &[usize], num_classes: usize, batch: usize) -> NativeBackend {
        let entry = chain_entry(task, dims, num_classes);
        let mut b = NativeBackend::new("test", &entry, batch).unwrap();
        b.init(7).unwrap();
        b
    }

    fn conv_backend(entry: &ModelEntry, batch: usize, kcfg: KernelConfig) -> NativeBackend {
        let mut b = NativeBackend::with_kernel_config("ctest", entry, batch, kcfg).unwrap();
        b.init(7).unwrap();
        b
    }

    fn toy_batch(b: &NativeBackend, seed: u64) -> (HostTensor, HostTensor) {
        let n = b.batch;
        let din = b.topo.in_elems();
        let mut rng = Rng::seed_from(seed);
        let x = HostTensor::f32(
            vec![n, din],
            (0..n * din).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let y = if b.topo.classification() {
            HostTensor::i32(
                vec![n],
                (0..n).map(|_| rng.below(b.topo.out_width()) as i32).collect(),
            )
            .unwrap()
        } else {
            HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        (x, y)
    }

    fn forward_acts(b: &NativeBackend, x: &HostTensor, n: usize) -> Vec<Vec<f32>> {
        let mut arena = Arena::new();
        forward_topo(&b.topo, &b.params, &b.kcfg, &mut arena, x.as_f32().unwrap(), n, false)
    }

    #[test]
    fn rejects_non_dense_entries() {
        let mut entry = chain_entry("classification", &[4, 3], 3);
        entry.params[0].shape = vec![4, 3, 1];
        assert!(NativeBackend::new("bad", &entry, 8).is_err());

        let mut entry = chain_entry("classification", &[4, 3], 3);
        entry.params.pop();
        assert!(NativeBackend::new("odd", &entry, 8).is_err());

        // head width must match num_classes
        let entry = chain_entry("classification", &[4, 5], 3);
        assert!(NativeBackend::new("head", &entry, 8).is_err());

        let entry = chain_entry("regression", &[4, 2], 0);
        assert!(NativeBackend::new("reg", &entry, 8).is_err());
    }

    #[test]
    fn rejects_malformed_conv_entries() {
        // NHWC input without strides: the artifact-manifest case
        let mut entry = conv_entry(4, 2, &[(3, 2)], 2);
        entry.conv_strides.clear();
        let err = NativeBackend::new("c", &entry, 4).unwrap_err().to_string();
        assert!(err.contains("conv_strides"), "err: {err}");

        // channel chain broken
        let mut entry = conv_entry(4, 2, &[(3, 2), (5, 1)], 2);
        entry.params[2].shape = vec![3, 3, 4, 5];
        assert!(NativeBackend::new("c", &entry, 4).is_err());

        // head width must match pooled channels
        let mut entry = conv_entry(4, 2, &[(3, 2)], 2);
        entry.params[2].shape = vec![7, 2];
        assert!(NativeBackend::new("c", &entry, 4).is_err());

        // head classes mismatch
        let mut entry = conv_entry(4, 2, &[(3, 2)], 2);
        entry.num_classes = 9;
        assert!(NativeBackend::new("c", &entry, 4).is_err());

        // stride zero
        let mut entry = conv_entry(4, 2, &[(3, 2)], 2);
        entry.conv_strides[0] = 0;
        assert!(NativeBackend::new("c", &entry, 4).is_err());

        // a well-formed one builds
        assert!(NativeBackend::new("c", &conv_entry(4, 2, &[(3, 2)], 2), 4).is_ok());
    }

    #[test]
    fn softmax_xent_matches_brute_force() {
        let mut b = backend("classification", &[3, 5], 5, 4);
        let (x, y) = toy_batch(&b, 3);
        let losses = b.fwd_loss(&x, &y).unwrap();
        let acts = forward_acts(&b, &x, 4);
        let logits = acts.last().unwrap();
        let labels = y.as_i32().unwrap();
        for i in 0..4 {
            let row = &logits[i * 5..(i + 1) * 5];
            let z: f64 = row.iter().map(|&v| (v as f64).exp()).sum();
            let want = z.ln() - row[labels[i] as usize] as f64;
            assert!(
                (losses[i] as f64 - want).abs() < 1e-5,
                "row {i}: {} vs {want}",
                losses[i]
            );
            assert!(losses[i] >= 0.0);
        }
    }

    #[test]
    fn mse_loss_is_squared_error() {
        let mut b = backend("regression", &[2, 1], 0, 3);
        let (x, y) = toy_batch(&b, 5);
        let losses = b.fwd_loss(&x, &y).unwrap();
        let acts = forward_acts(&b, &x, 3);
        let preds = acts.last().unwrap();
        let targets = y.as_f32().unwrap();
        for i in 0..3 {
            let d = preds[i] - targets[i];
            assert!((losses[i] - d * d).abs() < 1e-6);
        }
    }

    /// Central-difference gradient check over every parameter of a
    /// two-hidden-layer classifier — validates the whole backward pass.
    #[test]
    fn gradients_match_finite_differences() {
        let n = 6;
        let mut b = backend("classification", &[4, 5, 3], 3, n);
        let (x, y) = toy_batch(&b, 11);
        let mask: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let (grads, _) = b.grads(&x, &y, &mask).unwrap();
        check_grads_fd(&mut b, &x, &y, &mask, &grads);
    }

    /// The same finite-difference check over a tiny conv net: one conv
    /// layer (stride 2) + GAP + head — validates the conv backward
    /// (dK, db, head grads) end to end.
    #[test]
    fn conv_gradients_match_finite_differences() {
        let n = 3;
        let entry = conv_entry(4, 2, &[(3, 2)], 2);
        let mut b = conv_backend(&entry, n, KernelConfig::blocked(1));
        let (x, y) = toy_batch(&b, 13);
        let mask = vec![1.0, 0.0, 1.0];
        let (grads, _) = b.grads(&x, &y, &mask).unwrap();
        check_grads_fd(&mut b, &x, &y, &mask, &grads);
    }

    /// And over two conv layers, where the conv input gradient
    /// (col2im + ReLU gate) participates.
    #[test]
    fn deep_conv_gradients_match_finite_differences() {
        let n = 2;
        let entry = conv_entry(5, 1, &[(2, 1), (3, 2)], 2);
        let mut b = conv_backend(&entry, n, KernelConfig::blocked(1));
        let (x, y) = toy_batch(&b, 17);
        let mask = vec![1.0, 1.0];
        let (grads, _) = b.grads(&x, &y, &mask).unwrap();
        check_grads_fd(&mut b, &x, &y, &mask, &grads);
    }

    fn check_grads_fd(
        b: &mut NativeBackend,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        grads: &[HostTensor],
    ) {
        let masked_loss = |b: &mut NativeBackend| -> f64 {
            let losses = b.fwd_loss(x, y).unwrap();
            let denom: f32 = mask.iter().sum::<f32>().max(1.0);
            (losses.iter().zip(mask).map(|(l, m)| l * m).sum::<f32>() / denom) as f64
        };

        let eps = 1e-3f32;
        for (pi, g) in grads.iter().enumerate() {
            let gv = g.as_f32().unwrap().to_vec();
            for vi in 0..gv.len() {
                let orig = {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    let o = pv[vi];
                    pv[vi] = o + eps;
                    o
                };
                let up = masked_loss(b);
                {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    pv[vi] = orig - eps;
                }
                let down = masked_loss(b);
                {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    pv[vi] = orig;
                }
                let numeric = (up - down) / (2.0 * eps as f64);
                let analytic = gv[vi] as f64;
                assert!(
                    (numeric - analytic).abs() < 1e-2 * analytic.abs().max(1e-1),
                    "param {pi}[{vi}]: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_step_equals_grads_plus_apply() {
        let n = 8;
        let mut fused = backend("classification", &[6, 4, 3], 3, n);
        let mut split = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&fused, 21);
        let mask = vec![1.0f32; n];

        let l1 = fused.train_step(&x, &y, &mask, 0.1).unwrap();
        let (g, l2) = split.grads(&x, &y, &mask).unwrap();
        split.apply(&g, 0.1).unwrap();

        assert_eq!(l1, l2);
        for (a, b) in fused.params.iter().zip(&split.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn gathered_step_is_bit_identical_to_masked_step() {
        let n = 10;
        let mut masked = backend("classification", &[3, 4, 2], 2, n);
        let mut gathered = backend("classification", &[3, 4, 2], 2, n);
        let (x, y) = toy_batch(&masked, 31);
        let selected = vec![7usize, 1, 4]; // unsorted on purpose
        let mut mask = vec![0.0f32; n];
        for &i in &selected {
            mask[i] = 1.0;
        }

        let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
        let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
        assert_eq!(lm, lg, "masked {lm} vs gathered {lg}");
        for (a, b) in masked.params.iter().zip(&gathered.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn conv_gathered_step_is_bit_identical_to_masked_step() {
        let n = 6;
        let entry = conv_entry(4, 2, &[(3, 1), (4, 2)], 3);
        for threads in [1usize, 3] {
            let cfg = KernelConfig::blocked(threads);
            let mut masked = conv_backend(&entry, n, cfg);
            let mut gathered = conv_backend(&entry, n, cfg);
            let (x, y) = toy_batch(&masked, 41);
            let selected = vec![5usize, 0, 2]; // unsorted on purpose
            let mut mask = vec![0.0f32; n];
            for &i in &selected {
                mask[i] = 1.0;
            }
            let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
            let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
            assert_eq!(lm, lg, "t{threads}: masked {lm} vs gathered {lg}");
            for (a, b) in masked.params.iter().zip(&gathered.params) {
                assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "t{threads}");
            }
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let entry = chain_entry("classification", &[4, 3], 3);
        let mut a = NativeBackend::new("t", &entry, 2).unwrap();
        let mut b = NativeBackend::new("t", &entry, 2).unwrap();
        a.init(42).unwrap();
        b.init(42).unwrap();
        assert_eq!(a.params, b.params);
        let mut c = NativeBackend::new("t", &entry, 2).unwrap();
        c.init(43).unwrap();
        assert_ne!(a.params, c.params);
        // biases start at zero, weights don't
        assert!(a.params[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(a.params[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn conv_init_scales_by_patch_fan_in() {
        // He init over a conv kernel draws with σ = sqrt(2 / (kh·kw·cin))
        let entry = conv_entry(4, 8, &[(32, 2)], 2);
        let mut b = NativeBackend::new("t", &entry, 2).unwrap();
        b.init(3).unwrap();
        let k = b.params[0].as_f32().unwrap();
        let var: f64 = k.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / k.len() as f64;
        let want = 2.0 / (3.0 * 3.0 * 8.0);
        assert!(
            (var - want).abs() < 0.3 * want,
            "kernel variance {var} vs He {want}"
        );
        assert!(b.params[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn eval_counts_and_accuracy_bounds() {
        let n = 16;
        let mut b = backend("classification", &[3, 4], 4, n);
        let (x, y) = toy_batch(&b, 9);
        let mask = vec![1.0f32; n];
        let (loss, metric, count) = b.eval_batch(&x, &y, &mask).unwrap();
        assert_eq!(count, n as f64);
        assert!(loss > 0.0);
        assert!((0.0..=count).contains(&metric));
        let zeros = vec![0.0f32; n];
        let zero = b.eval_batch(&x, &y, &zeros).unwrap();
        assert_eq!(zero, (0.0, 0.0, 0.0));
    }

    #[test]
    fn training_reduces_loss_on_learnable_data() {
        // y = 2x + 1, exactly representable by the linreg chain
        let n = 32;
        let mut b = backend("regression", &[1, 1], 0, n);
        let mut rng = Rng::seed_from(77);
        let xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let ys: Vec<f32> = xs.iter().map(|&v| 2.0 * v + 1.0).collect();
        let x = HostTensor::f32(vec![n, 1], xs).unwrap();
        let y = HostTensor::f32(vec![n], ys).unwrap();
        let mask = vec![1.0f32; n];
        let first = b.train_step(&x, &y, &mask, 0.3).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = b.train_step(&x, &y, &mask, 0.3).unwrap();
        }
        assert!(last < first * 0.05, "loss did not converge: {first} -> {last}");
    }

    #[test]
    fn stats_split_kernel_time_between_forward_and_backward() {
        let n = 8;
        let mut b = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&b, 13);
        let mask = vec![1.0f32; n];
        b.fwd_loss(&x, &y).unwrap();
        let s = b.stats();
        assert!(s.forward_ns > 0, "fwd_loss must attribute forward time");
        assert_eq!(s.backward_ns, 0, "fwd_loss must not attribute backward time");
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        let s = b.stats();
        assert!(s.backward_ns > 0, "train_step must attribute backward time");
        assert!(s.forward_ns + s.backward_ns <= s.exec_ns + s.compile_ns + 1_000_000);
    }

    #[test]
    fn scratch_arena_recycles_across_steps() {
        let n = 8;
        let mut b = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&b, 17);
        let mask = vec![1.0f32; n];
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        let idle = b.scratch.idle_buffers();
        assert!(idle > 0, "step must return scratch buffers to the arena");
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        assert_eq!(
            b.scratch.idle_buffers(),
            idle,
            "steady-state steps must reuse, not grow, the arena"
        );
    }

    #[test]
    fn conv_scratch_arena_recycles_across_steps() {
        let n = 4;
        let entry = conv_entry(4, 2, &[(3, 1), (4, 2)], 3);
        let mut b = conv_backend(&entry, n, KernelConfig::blocked(1));
        let (x, y) = toy_batch(&b, 19);
        let mask = vec![1.0f32; n];
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        let idle = b.scratch.idle_buffers();
        assert!(idle > 0, "conv step must return scratch buffers to the arena");
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        assert_eq!(
            b.scratch.idle_buffers(),
            idle,
            "steady-state conv steps must reuse, not grow, the arena"
        );
    }

    #[test]
    fn reference_and_blocked_kernels_agree_end_to_end() {
        let n = 12;
        let entry = chain_entry("classification", &[9, 7, 3], 3);
        let mut blocked =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::blocked(2)).unwrap();
        let mut naive =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::reference()).unwrap();
        blocked.init(5).unwrap();
        naive.init(5).unwrap();
        let (x, y) = toy_batch(&blocked, 29);
        let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        for _ in 0..3 {
            let lb = blocked.train_step(&x, &y, &mask, 0.1).unwrap();
            let ln = naive.train_step(&x, &y, &mask, 0.1).unwrap();
            assert!((lb - ln).abs() <= 1e-4 * ln.abs().max(1.0), "loss {lb} vs {ln}");
        }
        for (a, b) in blocked.params.iter().zip(&naive.params) {
            for (va, vb) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                assert!((va - vb).abs() <= 1e-4 * vb.abs().max(1.0), "{va} vs {vb}");
            }
        }
    }

    /// bf16 scoring changes only `fwd_loss`: the scores track the exact
    /// f32 losses within the relaxed tolerance, while training steps
    /// taken under either precision stay bit-identical.
    #[test]
    fn bf16_scoring_tracks_f32_and_leaves_training_exact() {
        let n = 8;
        let entry = chain_entry("classification", &[9, 7, 3], 3);
        let mut exact =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::blocked(2)).unwrap();
        let mut fast =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::blocked(2)).unwrap();
        exact.init(5).unwrap();
        fast.init(5).unwrap();
        fast.set_score_precision(ScorePrecision::Bf16);
        let (x, y) = toy_batch(&exact, 43);
        let mask = vec![1.0f32; n];
        for _ in 0..2 {
            let lf = exact.fwd_loss(&x, &y).unwrap();
            let lb = fast.fwd_loss(&x, &y).unwrap();
            // wide bound: unscaled normal features stress rounding past
            // the network-realistic ≤1e-2 contract pinned in
            // tests/kernel_parity.rs — here we only pin "tracks f32"
            for (a, b) in lf.iter().zip(&lb) {
                assert!((a - b).abs() <= 2e-2 * a.abs().max(1.0), "score {b} vs exact {a}");
            }
            let le = exact.train_step(&x, &y, &mask, 0.1).unwrap();
            let lt = fast.train_step(&x, &y, &mask, 0.1).unwrap();
            assert_eq!(le, lt, "training losses must stay bit-identical");
        }
        for (a, b) in exact.params.iter().zip(&fast.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "params must stay bit-identical");
        }
        // and switching back restores bit-exact scoring
        fast.set_score_precision(ScorePrecision::F32);
        assert_eq!(exact.fwd_loss(&x, &y).unwrap(), fast.fwd_loss(&x, &y).unwrap());
    }

    #[test]
    fn conv_reference_and_blocked_kernels_agree_end_to_end() {
        let n = 5;
        let entry = conv_entry(5, 2, &[(3, 1), (4, 2)], 3);
        let mut blocked = conv_backend(&entry, n, KernelConfig::blocked(2));
        let mut naive = conv_backend(&entry, n, KernelConfig::reference());
        let (x, y) = toy_batch(&blocked, 37);
        let mask: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        for _ in 0..3 {
            let lb = blocked.train_step(&x, &y, &mask, 0.1).unwrap();
            let ln = naive.train_step(&x, &y, &mask, 0.1).unwrap();
            assert!((lb - ln).abs() <= 1e-4 * ln.abs().max(1.0), "loss {lb} vs {ln}");
        }
        for (a, b) in blocked.params.iter().zip(&naive.params) {
            for (va, vb) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                assert!((va - vb).abs() <= 1e-4 * vb.abs().max(1.0), "{va} vs {vb}");
            }
        }
    }
}
