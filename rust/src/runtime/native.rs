//! Pure-Rust native CPU backend: the hermetic execution path.
//!
//! Ports the oracles in `python/compile/kernels/ref.py` to Rust so the
//! full Algorithm-1 loop runs on any machine with no artifacts, JAX or
//! PJRT:
//!
//! * `matmul_bias_act` — `act(x · W + b)` with f32 accumulation, ReLU
//!   on hidden layers;
//! * `softmax_xent` / `mse` — per-example losses (stable logsumexp);
//! * `softmax_xent_grad` / `mse_grad` — head gradients with
//!   `dloss = mask / max(Σmask, 1)`, i.e. the masked-mean objective of
//!   `model.py::_masked_loss_fn`;
//! * `sgd_update` — `w − lr·g`.
//!
//! The dense math itself lives in [`super::kernels`]: cache-blocked,
//! register-tiled, multi-threaded kernels by default
//! (`OBFTF_NATIVE_THREADS` controls sharding,
//! `OBFTF_NATIVE_KERNELS=reference` selects the naive oracle loops),
//! with a scratch [`Arena`] recycling the per-step working set
//! (activations, packed panels, head gradients) across steps — in
//! steady state only the gradient tensors handed back to the caller
//! are freshly allocated.
//!
//! The backend executes any model whose manifest entry is a **dense
//! chain**: alternating `(weight [d_in, d_out], bias [d_out])` pairs
//! over flat features — linreg and the 784-256-256-10 MLP. Convolution
//! models (cnn, cnn_lite) stay on the PJRT artifact path.
//!
//! `train_step` computes the same masked gradients as `grads` followed
//! by `apply`, so serial fused steps and the leader/worker
//! grads→average→apply protocol walk identical trajectories.

use anyhow::{bail, Result};

use super::backend::{gather_rows, Backend, SessionStats};
use super::kernels::{self, Arena, KernelConfig};
use super::manifest::ModelEntry;
use crate::data::rng::Rng;
use crate::data::tensor::{HostTensor, TensorData};

/// Seed-mixing constant so parameter init draws are decorrelated from
/// dataset generators seeded with the same user seed.
const INIT_SEED_MIX: u64 = 0x6f62_6674_665f_696e; // "obftf_in"

/// Dense-chain topology: layer widths `[d_in, h_1, …, d_out]`.
struct DenseChain {
    dims: Vec<usize>,
    classification: bool,
}

impl DenseChain {
    fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn out_width(&self) -> usize {
        *self.dims.last().expect("dims never empty")
    }
}

/// The pure-Rust CPU backend ([`Flavour::Native`]).
///
/// [`Flavour::Native`]: super::manifest::Flavour::Native
pub struct NativeBackend {
    chain: DenseChain,
    entry: ModelEntry,
    batch: usize,
    /// Resident parameters in manifest order (w_0, b_0, w_1, b_1, …).
    params: Vec<HostTensor>,
    stats: SessionStats,
    /// Kernel implementation + thread count (resolved once, at build).
    kcfg: KernelConfig,
    /// Recycled scratch buffers (activations, packed panels, head
    /// gradients) — see [`Arena`].
    scratch: Arena,
}

impl NativeBackend {
    /// Build from a manifest entry, validating that the parameter list
    /// forms a dense chain the native math can execute. Kernel flavour
    /// and thread count come from the environment
    /// (`OBFTF_NATIVE_KERNELS`, `OBFTF_NATIVE_THREADS`).
    pub fn new(model: &str, entry: &ModelEntry, batch: usize) -> Result<NativeBackend> {
        NativeBackend::with_kernel_config(model, entry, batch, KernelConfig::from_env())
    }

    /// Build with an explicit kernel configuration — the
    /// deterministic-by-construction path benches and property tests
    /// use to pin flavour/threads without touching the environment.
    pub fn with_kernel_config(
        model: &str,
        entry: &ModelEntry,
        batch: usize,
        kcfg: KernelConfig,
    ) -> Result<NativeBackend> {
        let t0 = std::time::Instant::now();
        if entry.x_shape.len() != 1 {
            bail!(
                "native backend supports flat-feature models only; \
                 model {model} has x_shape {:?} (use the pjrt feature for conv models)",
                entry.x_shape
            );
        }
        if entry.params.is_empty() || entry.params.len() % 2 != 0 {
            bail!(
                "native backend expects (weight, bias) parameter pairs; \
                 model {model} has {} tensors",
                entry.params.len()
            );
        }
        let mut dims = vec![entry.x_shape[0]];
        for pair in entry.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            if w.shape.len() != 2 || b.shape.len() != 1 || w.shape[1] != b.shape[0] {
                bail!(
                    "model {model}: parameter pair {}/{} is not dense \
                     (shapes {:?} / {:?})",
                    w.name,
                    b.name,
                    w.shape,
                    b.shape
                );
            }
            let prev = *dims.last().expect("dims starts non-empty");
            if w.shape[0] != prev {
                bail!(
                    "model {model}: layer input width {} does not chain onto \
                     previous width {prev}",
                    w.shape[0]
                );
            }
            dims.push(w.shape[1]);
        }
        let classification = entry.is_classification();
        let out = *dims.last().expect("dims starts non-empty");
        if classification && out != entry.num_classes {
            bail!("model {model}: head width {out} != num_classes {}", entry.num_classes);
        }
        if !classification && out != 1 {
            bail!("model {model}: regression head must have width 1, got {out}");
        }
        let stats = SessionStats {
            // clamp to 1 ns so stats always witness construction
            compile_ns: (t0.elapsed().as_nanos() as u64).max(1),
            ..Default::default()
        };
        Ok(NativeBackend {
            chain: DenseChain { dims, classification },
            entry: entry.clone(),
            batch,
            params: vec![],
            stats,
            kcfg,
            scratch: Arena::new(),
        })
    }

    fn bump(&mut self, t0: std::time::Instant) {
        self.stats.executions += 1;
        self.stats.exec_ns += t0.elapsed().as_nanos() as u64;
    }

    /// Per-example losses from head outputs (ref.py `softmax_xent` /
    /// `mse`).
    fn per_example_losses(&self, logits: &[f32], y: &HostTensor, n: usize) -> Result<Vec<f32>> {
        let c = self.chain.out_width();
        let mut out = vec![0.0f32; n];
        if self.chain.classification {
            let labels = y.as_i32()?;
            for i in 0..n {
                let row = &logits[i * c..(i + 1) * c];
                let label = labels[i];
                if label < 0 || label as usize >= c {
                    bail!("label {label} outside [0, {c})");
                }
                out[i] = logsumexp(row) - row[label as usize];
            }
        } else {
            let targets = y.as_f32()?;
            for i in 0..n {
                let d = logits[i] - targets[i];
                out[i] = d * d;
            }
        }
        Ok(out)
    }

    /// Masked-mean loss gradients — the value-and-grad of
    /// `masked_mean(per_example_loss)` from `model.py`. Returns the
    /// gradients in manifest parameter order plus the selected mean
    /// loss. `mask.len()` is the row count (callers may pass gathered
    /// sub-batches smaller than the compiled batch).
    ///
    /// Also splits the elapsed kernel time into
    /// [`SessionStats::forward_ns`] / [`SessionStats::backward_ns`].
    fn compute_grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = std::time::Instant::now();
        let n = mask.len();
        let xs = x.as_f32()?;
        let nl = self.chain.n_layers();
        let c = self.chain.out_width();
        let acts = forward_chain(&self.chain, &self.params, &self.kcfg, &mut self.scratch, xs, n);
        let logits = &acts[nl - 1];
        let losses = self.per_example_losses(logits, y, n)?;
        let denom = mask.iter().sum::<f32>().max(1.0);
        let sel_loss = losses.iter().zip(mask).map(|(l, m)| l * m).sum::<f32>() / denom;
        let fwd_ns = t0.elapsed().as_nanos() as u64;

        // head gradient dL/dz with dloss_i = mask_i / denom
        // (ref.py softmax_xent_grad / mse_grad)
        let mut dz = self.scratch.take(n * c);
        if self.chain.classification {
            let labels = y.as_i32()?;
            for i in 0..n {
                let dl = mask[i] / denom;
                if dl == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let drow = &mut dz[i * c..(i + 1) * c];
                let mut sum = 0.0f32;
                for (d, &v) in drow.iter_mut().zip(row) {
                    *d = (v - m).exp();
                    sum += *d;
                }
                for d in drow.iter_mut() {
                    *d = *d / sum * dl;
                }
                drow[labels[i] as usize] -= dl;
            }
        } else {
            let targets = y.as_f32()?;
            for i in 0..n {
                let dl = mask[i] / denom;
                dz[i] = 2.0 * (logits[i] - targets[i]) * dl;
            }
        }

        // backprop through the chain: dW_l = actsᵀ_l · dz, db_l = Σ dz,
        // dh = dz · Wᵀ_l gated by the ReLU (acts > 0 ⟺ pre-act > 0)
        let mut grads: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..nl).map(|_| None).collect();
        for l in (0..nl).rev() {
            let (din, dout) = (self.chain.dims[l], self.chain.dims[l + 1]);
            let h: &[f32] = if l == 0 { xs } else { &acts[l - 1] };
            let mut dw = vec![0.0f32; din * dout];
            let mut db = vec![0.0f32; dout];
            kernels::grad_weights(
                &self.kcfg,
                &mut self.scratch,
                h,
                &dz,
                &mut dw,
                &mut db,
                n,
                din,
                dout,
            );
            if l > 0 {
                let w = self.params[2 * l].as_f32()?;
                let mut dh = self.scratch.take(n * din);
                kernels::grad_input(
                    &self.kcfg,
                    &mut self.scratch,
                    &dz,
                    w,
                    h,
                    &mut dh,
                    n,
                    din,
                    dout,
                );
                self.scratch.put(std::mem::replace(&mut dz, dh));
            }
            grads[l] = Some((dw, db));
        }
        self.scratch.put(dz);
        for a in acts {
            self.scratch.put(a);
        }

        let mut out = Vec::with_capacity(2 * nl);
        for (l, g) in grads.into_iter().enumerate() {
            let (dw, db) = g.expect("filled by the backward loop");
            out.push(HostTensor::f32(
                vec![self.chain.dims[l], self.chain.dims[l + 1]],
                dw,
            )?);
            out.push(HostTensor::f32(vec![self.chain.dims[l + 1]], db)?);
        }
        self.stats.forward_ns += fwd_ns;
        self.stats.backward_ns += (t0.elapsed().as_nanos() as u64).saturating_sub(fwd_ns);
        Ok((out, sel_loss))
    }

    /// `w ← w − lr·g` over all resident parameters (ref.py
    /// `sgd_update`).
    fn sgd_update(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        if grads.len() != self.params.len() {
            bail!("apply got {} grads, expected {}", grads.len(), self.params.len());
        }
        for (p, g) in self.params.iter_mut().zip(grads) {
            let gv = g.as_f32()?;
            let TensorData::F32(pv) = &mut p.data else {
                bail!("non-f32 parameter");
            };
            if gv.len() != pv.len() {
                bail!("gradient size {} != parameter size {}", gv.len(), pv.len());
            }
            for (x, &d) in pv.iter_mut().zip(gv) {
                *x -= lr * d;
            }
        }
        Ok(())
    }
}

/// Forward pass over `n` rows: `acts[l] = act(input_l · W_l + b_l)`
/// where `input_0 = x` and `input_l = acts[l-1]` (ReLU on hidden
/// layers, identity on the head — ref.py `matmul_bias_act`). The input
/// batch is read in place, never copied; activation buffers come from
/// `arena` and must be recycled back by the caller. A free function
/// over the backend's fields so callers can lend `&mut self.scratch`
/// while the parameters stay borrowed — the arena is never moved out
/// of the backend, even on error paths.
fn forward_chain(
    chain: &DenseChain,
    params: &[HostTensor],
    kcfg: &KernelConfig,
    arena: &mut Arena,
    x: &[f32],
    n: usize,
) -> Vec<Vec<f32>> {
    let nl = chain.n_layers();
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl);
    for l in 0..nl {
        let (din, dout) = (chain.dims[l], chain.dims[l + 1]);
        let w = params[2 * l].as_f32().expect("parameters are f32");
        let b = params[2 * l + 1].as_f32().expect("parameters are f32");
        let h: &[f32] = if l == 0 { x } else { &acts[l - 1] };
        let mut z = arena.take(n * dout);
        let relu = l + 1 < nl;
        kernels::matmul_bias_act(kcfg, arena, h, w, b, &mut z, n, din, dout, relu);
        acts.push(z);
    }
    acts
}

/// Numerically stable `log(Σ exp(row))` (ref.py `softmax_xent`).
fn logsumexp(row: &[f32]) -> f32 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

impl Backend for NativeBackend {
    /// He initialization for weights (`N(0, 2/fan_in)`), zeros for
    /// biases — the same scheme as `model.py::init_params`, drawn from
    /// the crate's deterministic [`Rng`] instead of JAX's PRNG.
    fn init(&mut self, seed: i32) -> Result<()> {
        let t0 = std::time::Instant::now();
        let mut rng = Rng::seed_from((seed as i64 as u64) ^ INIT_SEED_MIX);
        let mut params = Vec::with_capacity(self.entry.params.len());
        for spec in &self.entry.params {
            let count: usize = spec.shape.iter().product();
            let data = if spec.shape.len() == 1 {
                vec![0.0f32; count]
            } else {
                let fan_in: usize = spec.shape[..spec.shape.len() - 1].iter().product();
                let scale = (2.0 / fan_in as f64).sqrt();
                (0..count).map(|_| (scale * rng.normal()) as f32).collect()
            };
            params.push(HostTensor::f32(spec.shape.clone(), data)?);
        }
        self.params = params;
        self.bump(t0);
        Ok(())
    }

    fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>> {
        let t0 = std::time::Instant::now();
        let n = self.batch;
        let xs = x.as_f32()?;
        let acts = forward_chain(&self.chain, &self.params, &self.kcfg, &mut self.scratch, xs, n);
        let logits = acts.last().expect("chain has at least one layer");
        let losses = self.per_example_losses(logits, y, n);
        for a in acts {
            self.scratch.put(a);
        }
        let losses = losses?;
        self.stats.forward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(losses)
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let (grads, sel_loss) = self.compute_grads(x, y, mask)?;
        let t1 = std::time::Instant::now();
        self.sgd_update(&grads, lr)?;
        self.stats.backward_ns += t1.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sel_loss)
    }

    /// Gathered backward: rebuild an O(|selected|) sub-batch and run the
    /// masked step on it. Indices are gathered in ascending order, so
    /// every reduction visits the same nonzero terms in the same order
    /// as the masked full-batch step (whose masked-out rows contribute
    /// exact zeros) — the result is bit-identical to
    /// [`Backend::train_step`] with the matching mask. The kernels
    /// preserve this at any thread count: reductions never reorder
    /// across batch rows (see [`super::kernels`]).
    fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let k = selected.len();
        let mut sorted: Vec<usize> = selected.to_vec();
        sorted.sort_unstable();
        let (gx, gy) = gather_rows(x, y, &sorted, k, self.batch)?;
        let mask = vec![1.0f32; k];
        let (grads, sel_loss) = self.compute_grads(&gx, &gy, &mask)?;
        let t1 = std::time::Instant::now();
        self.sgd_update(&grads, lr)?;
        self.stats.backward_ns += t1.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sel_loss)
    }

    fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = std::time::Instant::now();
        let out = self.compute_grads(x, y, mask)?;
        self.bump(t0);
        Ok(out)
    }

    fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        let t0 = std::time::Instant::now();
        self.sgd_update(grads, lr)?;
        self.stats.backward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(())
    }

    fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)> {
        let t0 = std::time::Instant::now();
        let n = self.batch;
        let c = self.chain.out_width();
        let xs = x.as_f32()?;
        let acts = forward_chain(&self.chain, &self.params, &self.kcfg, &mut self.scratch, xs, n);
        let logits = acts.last().expect("chain has at least one layer");
        let losses = self.per_example_losses(logits, y, n)?;
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        if self.chain.classification {
            let labels = y.as_i32()?;
            for i in 0..n {
                let m = mask[i] as f64;
                if m == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                let correct = if best == labels[i] as usize { 1.0 } else { 0.0 };
                sums.0 += losses[i] as f64 * m;
                sums.1 += correct * m;
                sums.2 += m;
            }
        } else {
            for i in 0..n {
                let m = mask[i] as f64;
                if m == 0.0 {
                    continue; // inf·0 on a diverged padded row would NaN the sums
                }
                sums.0 += losses[i] as f64 * m;
                sums.1 += losses[i] as f64 * m; // metric = squared error
                sums.2 += m;
            }
        }
        for a in acts {
            self.scratch.put(a);
        }
        self.stats.forward_ns += t0.elapsed().as_nanos() as u64;
        self.bump(t0);
        Ok(sums)
    }

    fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        Ok(self.params.clone())
    }

    fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        if params.len() != self.entry.n_params() {
            bail!(
                "load_params got {} tensors, expected {}",
                params.len(),
                self.entry.n_params()
            );
        }
        for (t, spec) in params.iter().zip(&self.entry.params) {
            if t.shape != spec.shape {
                bail!("param {}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
            if !t.is_f32() {
                bail!("param {}: parameters must be f32", spec.name);
            }
        }
        self.params = params.to_vec();
        Ok(())
    }

    fn n_resident_params(&self) -> usize {
        self.params.len()
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }

    fn platform_name(&self) -> String {
        "native-cpu".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamEntry;
    use std::collections::BTreeMap;

    fn chain_entry(task: &str, dims: &[usize], num_classes: usize) -> ModelEntry {
        let mut params = Vec::new();
        for (l, pair) in dims.windows(2).enumerate() {
            params.push(ParamEntry { name: format!("w{l}"), shape: vec![pair[0], pair[1]] });
            params.push(ParamEntry { name: format!("b{l}"), shape: vec![pair[1]] });
        }
        ModelEntry {
            task: task.to_string(),
            x_shape: vec![dims[0]],
            num_classes,
            y_dtype: if task == "classification" { "i32" } else { "f32" }.to_string(),
            params,
            executables: BTreeMap::new(),
        }
    }

    fn backend(task: &str, dims: &[usize], num_classes: usize, batch: usize) -> NativeBackend {
        let entry = chain_entry(task, dims, num_classes);
        let mut b = NativeBackend::new("test", &entry, batch).unwrap();
        b.init(7).unwrap();
        b
    }

    fn toy_batch(b: &NativeBackend, seed: u64) -> (HostTensor, HostTensor) {
        let n = b.batch;
        let din = b.chain.dims[0];
        let mut rng = Rng::seed_from(seed);
        let x = HostTensor::f32(
            vec![n, din],
            (0..n * din).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let y = if b.chain.classification {
            HostTensor::i32(
                vec![n],
                (0..n).map(|_| rng.below(b.chain.out_width()) as i32).collect(),
            )
            .unwrap()
        } else {
            HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        (x, y)
    }

    fn forward_acts(b: &NativeBackend, x: &HostTensor, n: usize) -> Vec<Vec<f32>> {
        let mut arena = Arena::new();
        forward_chain(&b.chain, &b.params, &b.kcfg, &mut arena, x.as_f32().unwrap(), n)
    }

    #[test]
    fn rejects_non_dense_entries() {
        let mut entry = chain_entry("classification", &[4, 3], 3);
        entry.params[0].shape = vec![4, 3, 1];
        assert!(NativeBackend::new("bad", &entry, 8).is_err());

        let mut entry = chain_entry("classification", &[4, 3], 3);
        entry.params.pop();
        assert!(NativeBackend::new("odd", &entry, 8).is_err());

        // head width must match num_classes
        let entry = chain_entry("classification", &[4, 5], 3);
        assert!(NativeBackend::new("head", &entry, 8).is_err());

        let entry = chain_entry("regression", &[4, 2], 0);
        assert!(NativeBackend::new("reg", &entry, 8).is_err());
    }

    #[test]
    fn softmax_xent_matches_brute_force() {
        let mut b = backend("classification", &[3, 5], 5, 4);
        let (x, y) = toy_batch(&b, 3);
        let losses = b.fwd_loss(&x, &y).unwrap();
        let acts = forward_acts(&b, &x, 4);
        let logits = acts.last().unwrap();
        let labels = y.as_i32().unwrap();
        for i in 0..4 {
            let row = &logits[i * 5..(i + 1) * 5];
            let z: f64 = row.iter().map(|&v| (v as f64).exp()).sum();
            let want = z.ln() - row[labels[i] as usize] as f64;
            assert!(
                (losses[i] as f64 - want).abs() < 1e-5,
                "row {i}: {} vs {want}",
                losses[i]
            );
            assert!(losses[i] >= 0.0);
        }
    }

    #[test]
    fn mse_loss_is_squared_error() {
        let mut b = backend("regression", &[2, 1], 0, 3);
        let (x, y) = toy_batch(&b, 5);
        let losses = b.fwd_loss(&x, &y).unwrap();
        let acts = forward_acts(&b, &x, 3);
        let preds = acts.last().unwrap();
        let targets = y.as_f32().unwrap();
        for i in 0..3 {
            let d = preds[i] - targets[i];
            assert!((losses[i] - d * d).abs() < 1e-6);
        }
    }

    /// Central-difference gradient check over every parameter of a
    /// two-hidden-layer classifier — validates the whole backward pass.
    #[test]
    fn gradients_match_finite_differences() {
        let n = 6;
        let mut b = backend("classification", &[4, 5, 3], 3, n);
        let (x, y) = toy_batch(&b, 11);
        let mask: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let (grads, _) = b.grads(&x, &y, &mask).unwrap();

        let masked_loss = |b: &mut NativeBackend| -> f64 {
            let losses = b.fwd_loss(&x, &y).unwrap();
            let denom: f32 = mask.iter().sum::<f32>().max(1.0);
            (losses.iter().zip(&mask).map(|(l, m)| l * m).sum::<f32>() / denom) as f64
        };

        let eps = 1e-3f32;
        for (pi, g) in grads.iter().enumerate() {
            let gv = g.as_f32().unwrap().to_vec();
            for vi in 0..gv.len() {
                let orig = {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    let o = pv[vi];
                    pv[vi] = o + eps;
                    o
                };
                let up = masked_loss(&mut b);
                {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    pv[vi] = orig - eps;
                }
                let down = masked_loss(&mut b);
                {
                    let TensorData::F32(pv) = &mut b.params[pi].data else { panic!() };
                    pv[vi] = orig;
                }
                let numeric = (up - down) / (2.0 * eps as f64);
                let analytic = gv[vi] as f64;
                assert!(
                    (numeric - analytic).abs() < 1e-2 * analytic.abs().max(1e-1),
                    "param {pi}[{vi}]: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_step_equals_grads_plus_apply() {
        let n = 8;
        let mut fused = backend("classification", &[6, 4, 3], 3, n);
        let mut split = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&fused, 21);
        let mask = vec![1.0f32; n];

        let l1 = fused.train_step(&x, &y, &mask, 0.1).unwrap();
        let (g, l2) = split.grads(&x, &y, &mask).unwrap();
        split.apply(&g, 0.1).unwrap();

        assert_eq!(l1, l2);
        for (a, b) in fused.params.iter().zip(&split.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn gathered_step_is_bit_identical_to_masked_step() {
        let n = 10;
        let mut masked = backend("classification", &[3, 4, 2], 2, n);
        let mut gathered = backend("classification", &[3, 4, 2], 2, n);
        let (x, y) = toy_batch(&masked, 31);
        let selected = vec![7usize, 1, 4]; // unsorted on purpose
        let mut mask = vec![0.0f32; n];
        for &i in &selected {
            mask[i] = 1.0;
        }

        let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
        let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
        assert_eq!(lm, lg, "masked {lm} vs gathered {lg}");
        for (a, b) in masked.params.iter().zip(&gathered.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let entry = chain_entry("classification", &[4, 3], 3);
        let mut a = NativeBackend::new("t", &entry, 2).unwrap();
        let mut b = NativeBackend::new("t", &entry, 2).unwrap();
        a.init(42).unwrap();
        b.init(42).unwrap();
        assert_eq!(a.params, b.params);
        let mut c = NativeBackend::new("t", &entry, 2).unwrap();
        c.init(43).unwrap();
        assert_ne!(a.params, c.params);
        // biases start at zero, weights don't
        assert!(a.params[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(a.params[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn eval_counts_and_accuracy_bounds() {
        let n = 16;
        let mut b = backend("classification", &[3, 4], 4, n);
        let (x, y) = toy_batch(&b, 9);
        let mask = vec![1.0f32; n];
        let (loss, metric, count) = b.eval_batch(&x, &y, &mask).unwrap();
        assert_eq!(count, n as f64);
        assert!(loss > 0.0);
        assert!((0.0..=count).contains(&metric));
        let zeros = vec![0.0f32; n];
        let zero = b.eval_batch(&x, &y, &zeros).unwrap();
        assert_eq!(zero, (0.0, 0.0, 0.0));
    }

    #[test]
    fn training_reduces_loss_on_learnable_data() {
        // y = 2x + 1, exactly representable by the linreg chain
        let n = 32;
        let mut b = backend("regression", &[1, 1], 0, n);
        let mut rng = Rng::seed_from(77);
        let xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let ys: Vec<f32> = xs.iter().map(|&v| 2.0 * v + 1.0).collect();
        let x = HostTensor::f32(vec![n, 1], xs).unwrap();
        let y = HostTensor::f32(vec![n], ys).unwrap();
        let mask = vec![1.0f32; n];
        let first = b.train_step(&x, &y, &mask, 0.3).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = b.train_step(&x, &y, &mask, 0.3).unwrap();
        }
        assert!(last < first * 0.05, "loss did not converge: {first} -> {last}");
    }

    #[test]
    fn stats_split_kernel_time_between_forward_and_backward() {
        let n = 8;
        let mut b = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&b, 13);
        let mask = vec![1.0f32; n];
        b.fwd_loss(&x, &y).unwrap();
        let s = b.stats();
        assert!(s.forward_ns > 0, "fwd_loss must attribute forward time");
        assert_eq!(s.backward_ns, 0, "fwd_loss must not attribute backward time");
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        let s = b.stats();
        assert!(s.backward_ns > 0, "train_step must attribute backward time");
        assert!(s.forward_ns + s.backward_ns <= s.exec_ns + s.compile_ns + 1_000_000);
    }

    #[test]
    fn scratch_arena_recycles_across_steps() {
        let n = 8;
        let mut b = backend("classification", &[6, 4, 3], 3, n);
        let (x, y) = toy_batch(&b, 17);
        let mask = vec![1.0f32; n];
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        let idle = b.scratch.idle_buffers();
        assert!(idle > 0, "step must return scratch buffers to the arena");
        b.train_step(&x, &y, &mask, 0.1).unwrap();
        assert_eq!(
            b.scratch.idle_buffers(),
            idle,
            "steady-state steps must reuse, not grow, the arena"
        );
    }

    #[test]
    fn reference_and_blocked_kernels_agree_end_to_end() {
        let n = 12;
        let entry = chain_entry("classification", &[9, 7, 3], 3);
        let mut blocked =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::blocked(2)).unwrap();
        let mut naive =
            NativeBackend::with_kernel_config("t", &entry, n, KernelConfig::reference()).unwrap();
        blocked.init(5).unwrap();
        naive.init(5).unwrap();
        let (x, y) = toy_batch(&blocked, 29);
        let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        for _ in 0..3 {
            let lb = blocked.train_step(&x, &y, &mask, 0.1).unwrap();
            let ln = naive.train_step(&x, &y, &mask, 0.1).unwrap();
            assert!((lb - ln).abs() <= 1e-4 * ln.abs().max(1.0), "loss {lb} vs {ln}");
        }
        for (a, b) in blocked.params.iter().zip(&naive.params) {
            for (va, vb) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                assert!((va - vb).abs() <= 1e-4 * vb.abs().max(1.0), "{va} vs {vb}");
            }
        }
    }
}
