//! PJRT artifact backend (`pjrt` cargo feature): one model × flavour,
//! all six AOT-lowered executables compiled, parameters held resident
//! as XLA `Literal`s.
//!
//! The `xla` crate's handles are `Rc`-backed (not `Send`); a
//! `PjrtBackend` therefore lives on exactly one thread. Multi-worker
//! execution builds one session per worker thread (see
//! [`crate::runtime::engine`]).
//!
//! Hot-path design: parameters never round-trip through `HostTensor`
//! between steps — `train_step` returns a tuple literal whose leading
//! elements simply *become* the new parameter literals. Only the scalar
//! selected-loss and the per-example loss vector are copied to host.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{gather_rows, Backend, SessionStats};
use super::manifest::{Exe, Flavour, Manifest, ModelEntry};
use crate::data::tensor::{HostTensor, TensorData};

/// One model's compiled executables + resident parameters.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    exes: HashMap<Exe, xla::PjRtLoadedExecutable>,
    /// Sub-batch `train_step_b{bb}` variants, keyed by compiled batch
    /// size `bb` (ascending); the gathered backward picks the smallest
    /// `bb ≥ |selection|` (see [`Backend::train_step_selected`]).
    gather_exes: std::collections::BTreeMap<usize, xla::PjRtLoadedExecutable>,
    entry: ModelEntry,
    batch: usize,
    params: Vec<xla::Literal>,
    /// `Cell` so [`PjrtBackend::run`] can take `&self` while callers
    /// hold borrows of `self.params` as executable inputs.
    stats: std::cell::Cell<SessionStats>,
}

/// Convert a host tensor into an XLA literal.
///
/// Uses `create_from_shape_and_untyped_data` — a single memcpy — rather
/// than `vec1().reshape()`, which copies twice (§Perf: 242 µs → ~60 µs
/// for a 128×784 batch).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    fn as_bytes<T>(v: &[T]) -> &[u8] {
        // SAFETY: f32/i32 are plain-old-data; the literal copies out of
        // this view before it returns.
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    let lit = match &t.data {
        TensorData::F32(v) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &t.shape,
                as_bytes(v),
            )
            .map_err(|e| anyhow::anyhow!("literal from f32 {:?}: {e:?}", t.shape))?
        }
        TensorData::I32(v) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &t.shape,
                as_bytes(v),
            )
            .map_err(|e| anyhow::anyhow!("literal from i32 {:?}: {e:?}", t.shape))?
        }
        TensorData::Bf16(_) => {
            bail!("bf16 tensors are wire-only; expand_to_f32() before device upload")
        }
    };
    Ok(lit)
}

/// Convert an XLA literal back to a host tensor.
pub fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.array_shape().map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().map_err(|e| anyhow::anyhow!("literal dtype: {e:?}"))?;
    match ty {
        xla::ElementType::F32 => Ok(HostTensor {
            shape: dims,
            data: TensorData::F32(
                l.to_vec().map_err(|e| anyhow::anyhow!("literal data: {e:?}"))?,
            ),
        }),
        xla::ElementType::S32 => Ok(HostTensor {
            shape: dims,
            data: TensorData::I32(
                l.to_vec().map_err(|e| anyhow::anyhow!("literal data: {e:?}"))?,
            ),
        }),
        other => bail!("unsupported artifact dtype {other:?}"),
    }
}

impl PjrtBackend {
    /// Compile all six executables of `model` from `manifest`.
    pub fn new(manifest: &Manifest, model: &str, flavour: Flavour) -> Result<PjrtBackend> {
        let entry = manifest.model(model)?.clone();
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => c,
            Err(e) => bail!("create PJRT CPU client: {e:?}"),
        };
        let mut exes = HashMap::new();
        let mut compile_ns = 0u64;
        for exe in Exe::ALL {
            let path = manifest.artifact_path(model, exe, flavour)?;
            let t0 = Instant::now();
            let compiled = compile_hlo(&client, &path)
                .with_context(|| format!("compiling {model}/{}", exe.as_str()))?;
            compile_ns += t0.elapsed().as_nanos() as u64;
            exes.insert(exe, compiled);
        }
        // optional sub-batch backward variants (train_step_b{bb}:{flavour})
        let mut gather_exes = std::collections::BTreeMap::new();
        let suffix = format!(":{}", flavour.as_str());
        for (key, fname) in &entry.executables {
            let Some(stem) = key.strip_suffix(&suffix) else { continue };
            let Some(bb) = stem.strip_prefix("train_step_b") else { continue };
            let Ok(bb) = bb.parse::<usize>() else { continue };
            let t0 = Instant::now();
            let compiled = compile_hlo(&client, &manifest.dir.join(fname))
                .with_context(|| format!("compiling {model}/{key}"))?;
            compile_ns += t0.elapsed().as_nanos() as u64;
            gather_exes.insert(bb, compiled);
        }
        Ok(PjrtBackend {
            client,
            exes,
            gather_exes,
            entry,
            batch: manifest.batch,
            params: vec![],
            stats: std::cell::Cell::new(SessionStats { compile_ns, ..Default::default() }),
        })
    }

    /// Execute one AOT executable and untuple its outputs. Takes `&self`
    /// (stats in a `Cell`) so callers can pass inputs borrowing
    /// `self.params` and re-assign them from the outputs afterwards.
    fn run(&self, exe: Exe, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let exec = self.exes.get(&exe).expect("all exes compiled in new()");
        let outs = run_exec(exec, exe.as_str(), inputs)?;
        self.bump(t0);
        Ok(outs)
    }

    fn bump(&self, t0: Instant) {
        let mut stats = self.stats.get();
        stats.executions += 1;
        stats.exec_ns += t0.elapsed().as_nanos() as u64;
        self.stats.set(stats);
    }
}

fn run_exec(
    exec: &xla::PjRtLoadedExecutable,
    label: &str,
    inputs: &[&xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exec
        .execute::<&xla::Literal>(inputs)
        .map_err(|e| anyhow::anyhow!("executing {label}: {e:?}"))?;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch output literal: {e:?}"))?;
    tuple.to_tuple().map_err(|e| anyhow::anyhow!("untuple output: {e:?}"))
}

impl Backend for PjrtBackend {
    /// Initialize parameters from `seed` (runs the `init` executable).
    fn init(&mut self, seed: i32) -> Result<()> {
        let seed_lit = xla::Literal::scalar(seed);
        let outs = self.run(Exe::Init, &[&seed_lit])?;
        if outs.len() != self.entry.n_params() {
            bail!(
                "init returned {} tensors, manifest declares {} params",
                outs.len(),
                self.entry.n_params()
            );
        }
        self.params = outs;
        Ok(())
    }

    fn fwd_loss(&mut self, x: &HostTensor, y: &HostTensor) -> Result<Vec<f32>> {
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&xl);
        inputs.push(&yl);
        let outs = self.run(Exe::FwdLoss, &inputs)?;
        let loss = from_literal(&outs[0])?;
        Ok(loss.as_f32()?.to_vec())
    }

    fn train_step(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml, &lrl]);
        let mut outs = self.run(Exe::TrainStep, &inputs)?;
        let loss_lit = outs.pop().expect("train_step returns params + loss");
        if outs.len() != self.entry.n_params() {
            bail!("train_step returned {} params, expected {}", outs.len(), self.entry.n_params());
        }
        self.params = outs;
        from_literal(&loss_lit)?.scalar_value()
    }

    /// Gathered backward on the smallest compiled sub-batch
    /// `bb ≥ |selected|` (falling back to the masked full-batch step
    /// when none fits). Numerically identical to [`Backend::train_step`]
    /// with the equivalent mask — the masked mean over gathered rows
    /// equals the masked mean over the full batch — but costs O(bb)
    /// instead of O(n) in the backward (EXPERIMENTS.md §Perf).
    fn train_step_selected(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        selected: &[usize],
        lr: f32,
    ) -> Result<f32> {
        let k = selected.len();
        // smallest compiled sub-batch that fits
        let bb = self
            .gather_exes
            .range(k..)
            .next()
            .map(|(&bb, _)| bb)
            .filter(|&bb| bb < self.batch);
        let Some(bb) = bb else {
            // no useful sub-batch: masked full-batch step
            let mut mask = vec![0.0f32; self.batch];
            for &i in selected {
                if i >= self.batch {
                    bail!("selected index {i} out of range");
                }
                mask[i] = 1.0;
            }
            return self.train_step(x, y, &mask, lr);
        };

        let (gx, gy) = gather_rows(x, y, selected, bb, self.batch)?;
        let mut mask = vec![0.0f32; bb];
        for m in mask.iter_mut().take(k) {
            *m = 1.0;
        }

        let xl = to_literal(&gx)?;
        let yl = to_literal(&gy)?;
        let ml = xla::Literal::vec1(&mask);
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml, &lrl]);
        let t0 = Instant::now();
        let exec = &self.gather_exes[&bb];
        let mut outs = run_exec(exec, &format!("train_step_b{bb}"), &inputs)?;
        self.bump(t0);
        let loss_lit = outs.pop().expect("train_step returns params + loss");
        if outs.len() != self.entry.n_params() {
            bail!("train_step_b{bb} returned {} params", outs.len());
        }
        self.params = outs;
        from_literal(&loss_lit)?.scalar_value()
    }

    fn grads(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml]);
        let mut outs = self.run(Exe::Grads, &inputs)?;
        let loss_lit = outs.pop().expect("grads returns grads + loss");
        let grads = outs.iter().map(from_literal).collect::<Result<Vec<_>>>()?;
        Ok((grads, from_literal(&loss_lit)?.scalar_value()?))
    }

    fn apply(&mut self, grads: &[HostTensor], lr: f32) -> Result<()> {
        let glits = grads.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let lrl = xla::Literal::scalar(lr);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend(glits.iter());
        inputs.push(&lrl);
        let outs = self.run(Exe::Apply, &inputs)?;
        if outs.len() != self.entry.n_params() {
            bail!("apply returned {} params, expected {}", outs.len(), self.entry.n_params());
        }
        self.params = outs;
        Ok(())
    }

    fn eval_batch(
        &mut self,
        x: &HostTensor,
        y: &HostTensor,
        mask: &[f32],
    ) -> Result<(f64, f64, f64)> {
        let xl = to_literal(x)?;
        let yl = to_literal(y)?;
        let ml = xla::Literal::vec1(mask);
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&xl, &yl, &ml]);
        let outs = self.run(Exe::Eval, &inputs)?;
        let s = from_literal(&outs[0])?.scalar_value()? as f64;
        let m = from_literal(&outs[1])?.scalar_value()? as f64;
        let c = from_literal(&outs[2])?.scalar_value()? as f64;
        Ok((s, m, c))
    }

    fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.params.iter().map(from_literal).collect()
    }

    fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        self.params = params.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    fn n_resident_params(&self) -> usize {
        self.params.len()
    }

    fn stats(&self) -> SessionStats {
        self.stats.get()
    }

    fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

/// Load HLO text and compile it on `client` (text, not serialized
/// proto, is the python→rust interchange format).
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse HLO text {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("XLA compile {path:?}: {e:?}"))
}
