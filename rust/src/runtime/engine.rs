//! Multi-worker execution engine: the leader/worker data-parallel
//! substrate (the paper trains sync data-parallel on 32 GPUs; here each
//! worker is a thread owning its own [`Session`] — backends may hold
//! non-`Send` handles (PJRT's are `Rc`-backed), so sessions are built
//! inside their worker thread and never shared).
//!
//! Protocol per step (see `coordinator::parallel`):
//!   1. leader shards the global batch;
//!   2. workers run `fwd_loss` on their shard concurrently;
//!   3. leader runs selection over the gathered global loss vector;
//!   4. workers run `grads` with their shard's slice of the mask;
//!   5. leader averages gradients (weighted by per-shard selected
//!      counts) and broadcasts `apply` — every worker's parameters stay
//!      bit-identical to the serial trainer.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Flavour, Manifest};
use super::session::Session;
use crate::data::tensor::HostTensor;

/// Requests the leader can send to a worker.
enum Req {
    Init { seed: i32 },
    LoadParams { params: Vec<HostTensor> },
    FwdLoss { x: HostTensor, y: HostTensor },
    Grads { x: HostTensor, y: HostTensor, mask: Vec<f32> },
    Apply { grads: Vec<HostTensor>, lr: f32 },
    Eval { x: HostTensor, y: HostTensor, mask: Vec<f32> },
    ParamsToHost,
    Shutdown,
}

/// Worker replies.
enum Rep {
    Ok,
    Losses(Vec<f32>),
    Grads(Vec<HostTensor>, f32),
    EvalSums(f64, f64, f64),
    Params(Vec<HostTensor>),
    Err(String),
}

struct WorkerHandle {
    tx: mpsc::Sender<Req>,
    rx: mpsc::Receiver<Rep>,
    handle: Option<JoinHandle<()>>,
}

/// A pool of PJRT worker threads for one model × flavour.
pub struct Engine {
    workers: Vec<WorkerHandle>,
    n_params: usize,
    /// Retained so detached sessions (async eval, debugging probes) can
    /// be re-materialized with the workers' current weights.
    manifest: Manifest,
    model: String,
    flavour: Flavour,
}

impl Engine {
    /// Spawn `n_workers` threads, each compiling its own copy of the
    /// model's executables. Fails fast if any worker fails to build.
    pub fn new(
        manifest: &Manifest,
        model: &str,
        flavour: Flavour,
        n_workers: usize,
    ) -> Result<Engine> {
        if n_workers == 0 {
            bail!("engine needs at least one worker");
        }
        let n_params = manifest.model(model)?.n_params();
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (req_tx, req_rx) = mpsc::channel::<Req>();
            let (rep_tx, rep_rx) = mpsc::channel::<Rep>();
            let manifest = manifest.clone();
            let model = model.to_string();
            let handle = std::thread::Builder::new()
                .name(format!("obftf-worker-{w}"))
                .spawn(move || worker_main(manifest, model, flavour, req_rx, rep_tx))
                .context("spawn worker thread")?;
            // first reply signals readiness (session compiled) or error
            let ready = rep_rx
                .recv()
                .map_err(|_| anyhow!("worker {w} died during startup"))?;
            if let Rep::Err(e) = ready {
                bail!("worker {w} failed to start: {e}");
            }
            workers.push(WorkerHandle { tx: req_tx, rx: rep_rx, handle: Some(handle) });
        }
        Ok(Engine {
            workers,
            n_params,
            manifest: manifest.clone(),
            model: model.to_string(),
            flavour,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Build a detached [`Session`] of the same model × flavour on the
    /// *calling* thread, loaded with the workers' current parameters —
    /// the weight-snapshot path async eval uses to score off the hot
    /// loop without borrowing a worker.
    pub fn fork_session(&self) -> Result<Session> {
        let mut s = Session::new(&self.manifest, &self.model, self.flavour)?;
        s.load_params(&self.params_to_host()?)?;
        Ok(s)
    }

    fn send(&self, w: usize, req: Req) -> Result<()> {
        self.workers[w]
            .tx
            .send(req)
            .map_err(|_| anyhow!("worker {w} channel closed (thread died?)"))
    }

    fn recv(&self, w: usize) -> Result<Rep> {
        self.workers[w]
            .rx
            .recv()
            .map_err(|_| anyhow!("worker {w} died mid-request"))
    }

    fn expect_ok(&self, w: usize) -> Result<()> {
        match self.recv(w)? {
            Rep::Ok => Ok(()),
            Rep::Err(e) => bail!("worker {w}: {e}"),
            _ => bail!("worker {w}: protocol violation"),
        }
    }

    /// Initialize worker 0 from `seed`, then broadcast the parameters so
    /// every worker starts bit-identical.
    pub fn init_broadcast(&self, seed: i32) -> Result<Vec<HostTensor>> {
        self.send(0, Req::Init { seed })?;
        self.expect_ok(0)?;
        self.send(0, Req::ParamsToHost)?;
        let params = match self.recv(0)? {
            Rep::Params(p) => p,
            Rep::Err(e) => bail!("worker 0: {e}"),
            _ => bail!("worker 0: protocol violation"),
        };
        self.broadcast_params(&params)?;
        Ok(params)
    }

    /// Load the same parameters into every worker.
    pub fn broadcast_params(&self, params: &[HostTensor]) -> Result<()> {
        for w in 0..self.workers.len() {
            self.send(w, Req::LoadParams { params: params.to_vec() })?;
        }
        for w in 0..self.workers.len() {
            self.expect_ok(w)?;
        }
        Ok(())
    }

    /// Run `fwd_loss` on per-worker shards concurrently.
    /// `shards[w]` = (x, y); returns per-worker loss vectors.
    pub fn fwd_loss_sharded(
        &self,
        shards: Vec<(HostTensor, HostTensor)>,
    ) -> Result<Vec<Vec<f32>>> {
        if shards.len() != self.workers.len() {
            bail!("{} shards for {} workers", shards.len(), self.workers.len());
        }
        for (w, (x, y)) in shards.into_iter().enumerate() {
            self.send(w, Req::FwdLoss { x, y })?;
        }
        let mut out = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            match self.recv(w)? {
                Rep::Losses(l) => out.push(l),
                Rep::Err(e) => bail!("worker {w}: {e}"),
                _ => bail!("worker {w}: protocol violation"),
            }
        }
        Ok(out)
    }

    /// Run `grads` on per-worker shards concurrently; returns each
    /// worker's (grads, selected-loss).
    pub fn grads_sharded(
        &self,
        shards: Vec<(HostTensor, HostTensor, Vec<f32>)>,
    ) -> Result<Vec<(Vec<HostTensor>, f32)>> {
        if shards.len() != self.workers.len() {
            bail!("{} shards for {} workers", shards.len(), self.workers.len());
        }
        for (w, (x, y, mask)) in shards.into_iter().enumerate() {
            self.send(w, Req::Grads { x, y, mask })?;
        }
        let mut out = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            match self.recv(w)? {
                Rep::Grads(g, l) => out.push((g, l)),
                Rep::Err(e) => bail!("worker {w}: {e}"),
                _ => bail!("worker {w}: protocol violation"),
            }
        }
        Ok(out)
    }

    /// Broadcast one `apply` with the averaged gradients.
    pub fn apply_broadcast(&self, grads: &[HostTensor], lr: f32) -> Result<()> {
        if grads.len() != self.n_params {
            bail!("apply_broadcast got {} grads, expected {}", grads.len(), self.n_params);
        }
        for w in 0..self.workers.len() {
            self.send(w, Req::Apply { grads: grads.to_vec(), lr })?;
        }
        for w in 0..self.workers.len() {
            self.expect_ok(w)?;
        }
        Ok(())
    }

    /// Sharded eval; returns summed `(loss, metric, count)`.
    pub fn eval_sharded(
        &self,
        shards: Vec<(HostTensor, HostTensor, Vec<f32>)>,
    ) -> Result<(f64, f64, f64)> {
        if shards.len() != self.workers.len() {
            bail!("{} shards for {} workers", shards.len(), self.workers.len());
        }
        for (w, (x, y, mask)) in shards.into_iter().enumerate() {
            self.send(w, Req::Eval { x, y, mask })?;
        }
        let mut sums = (0.0, 0.0, 0.0);
        for w in 0..self.workers.len() {
            match self.recv(w)? {
                Rep::EvalSums(a, b, c) => {
                    sums.0 += a;
                    sums.1 += b;
                    sums.2 += c;
                }
                Rep::Err(e) => bail!("worker {w}: {e}"),
                _ => bail!("worker {w}: protocol violation"),
            }
        }
        Ok(sums)
    }

    /// Fetch parameters from worker 0 (all workers are identical).
    pub fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.send(0, Req::ParamsToHost)?;
        match self.recv(0)? {
            Rep::Params(p) => Ok(p),
            Rep::Err(e) => bail!("worker 0: {e}"),
            _ => bail!("worker 0: protocol violation"),
        }
    }

    /// The workers' shared parameters in wire form — the byte-level
    /// snapshot a cross-process consumer (checkpoint shipper, remote
    /// fleet) reads without touching `HostTensor` internals.
    pub fn params_to_bytes(&self) -> Result<Vec<u8>> {
        Ok(crate::data::tensor::tensors_to_bytes(&self.params_to_host()?))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Req::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_main(
    manifest: Manifest,
    model: String,
    flavour: Flavour,
    rx: mpsc::Receiver<Req>,
    tx: mpsc::Sender<Rep>,
) {
    let mut session = match Session::new(&manifest, &model, flavour) {
        Ok(s) => {
            let _ = tx.send(Rep::Ok);
            s
        }
        Err(e) => {
            let _ = tx.send(Rep::Err(format!("{e:#}")));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        let rep = match req {
            Req::Shutdown => return,
            Req::Init { seed } => session.init(seed).map(|_| Rep::Ok),
            Req::LoadParams { params } => session.load_params(&params).map(|_| Rep::Ok),
            Req::FwdLoss { x, y } => session.fwd_loss(&x, &y).map(Rep::Losses),
            Req::Grads { x, y, mask } => {
                session.grads(&x, &y, &mask).map(|(g, l)| Rep::Grads(g, l))
            }
            Req::Apply { grads, lr } => session.apply(&grads, lr).map(|_| Rep::Ok),
            Req::Eval { x, y, mask } => {
                session.eval_batch(&x, &y, &mask).map(|(a, b, c)| Rep::EvalSums(a, b, c))
            }
            Req::ParamsToHost => session.params_to_host().map(Rep::Params),
        };
        let msg = match rep {
            Ok(r) => r,
            Err(e) => Rep::Err(format!("{e:#}")),
        };
        if tx.send(msg).is_err() {
            return; // leader gone
        }
    }
}

/// Average per-worker gradients weighted by selected counts so that the
/// result equals the serial global masked mean:
/// `g = Σ_w k_w·g_w / Σ_w k_w` (workers with `k_w = 0` contribute 0).
pub fn weighted_average_grads(
    per_worker: &[(Vec<HostTensor>, f32)],
    counts: &[usize],
) -> Result<(Vec<HostTensor>, f32)> {
    if per_worker.is_empty() || per_worker.len() != counts.len() {
        bail!("mismatched grads/counts");
    }
    let total: usize = counts.iter().sum();
    if total == 0 {
        bail!("no selected examples across workers");
    }
    let n_params = per_worker[0].0.len();
    let mut avg: Vec<HostTensor> = per_worker[0]
        .0
        .iter()
        .map(|t| HostTensor::zeros_f32(t.shape.clone()))
        .collect();
    let mut loss = 0.0f64;
    for ((grads, l), &k) in per_worker.iter().zip(counts) {
        if k == 0 {
            continue;
        }
        if grads.len() != n_params {
            bail!("worker grad count mismatch");
        }
        let wgt = k as f64 / total as f64;
        loss += wgt * *l as f64;
        for (a, g) in avg.iter_mut().zip(grads) {
            let gv = g.as_f32()?;
            let crate::data::tensor::TensorData::F32(av) = &mut a.data else {
                bail!("non-f32 gradient");
            };
            for (x, &y) in av.iter_mut().zip(gv) {
                *x += wgt as f32 * y;
            }
        }
    }
    Ok((avg, loss as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensor::HostTensor;

    #[test]
    fn fork_session_matches_worker_params() {
        let dir = crate::testkit::TempDir::new("engine").unwrap();
        let m = Manifest::native(dir.path());
        let engine = Engine::new(&m, "linreg", Flavour::Native, 2).unwrap();
        engine.init_broadcast(9).unwrap();
        let forked = engine.fork_session().unwrap();
        assert_eq!(
            forked.params_to_host().unwrap(),
            engine.params_to_host().unwrap(),
            "fork must carry the workers' weights bit-identically"
        );
    }

    #[test]
    fn params_to_bytes_matches_host_snapshot() {
        let dir = crate::testkit::TempDir::new("engine").unwrap();
        let m = Manifest::native(dir.path());
        let engine = Engine::new(&m, "linreg", Flavour::Native, 1).unwrap();
        engine.init_broadcast(4).unwrap();
        let bytes = engine.params_to_bytes().unwrap();
        let decoded = crate::data::tensor::tensors_from_bytes(&bytes).unwrap();
        assert_eq!(decoded, engine.params_to_host().unwrap());
    }

    #[test]
    fn weighted_average_matches_manual() {
        let g1 = vec![HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap()];
        let g2 = vec![HostTensor::f32(vec![2], vec![3.0, 4.0]).unwrap()];
        let (avg, loss) =
            weighted_average_grads(&[(g1, 1.0), (g2, 3.0)], &[1, 3]).unwrap();
        let v = avg[0].as_f32().unwrap();
        // weights 0.25 / 0.75
        assert!((v[0] - (0.25 + 2.25)).abs() < 1e-6);
        assert!((v[1] - (0.5 + 3.0)).abs() < 1e-6);
        assert!((loss - 2.5).abs() < 1e-6);
    }

    #[test]
    fn zero_count_workers_are_skipped() {
        let g1 = vec![HostTensor::f32(vec![1], vec![5.0]).unwrap()];
        let g2 = vec![HostTensor::f32(vec![1], vec![100.0]).unwrap()];
        let (avg, _) = weighted_average_grads(&[(g1, 1.0), (g2, 9.0)], &[2, 0]).unwrap();
        assert_eq!(avg[0].as_f32().unwrap(), &[5.0]);
    }

    #[test]
    fn all_zero_counts_error() {
        let g1 = vec![HostTensor::f32(vec![1], vec![5.0]).unwrap()];
        assert!(weighted_average_grads(&[(g1, 0.0)], &[0]).is_err());
    }
}
