//! Test substrates (offline: no `proptest` / `tempfile`).
//!
//! * [`TempDir`] — unique scratch directory, removed on drop;
//! * [`propcheck`] — seeded randomized property harness: runs `cases`
//!   generated inputs through a property, reporting the failing seed so
//!   a failure reproduces deterministically.
//!
//! Exposed as a normal module (not `#[cfg(test)]`) so integration tests
//! and benches can use it; it has no cost unless called.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::rng::Rng;

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A scratch directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "obftf-{tag}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Seeded property check: generate `cases` inputs with `gen`, assert
/// `prop` on each. On failure, panics with the per-case seed so the
/// exact case can be replayed with `propcheck_one`.
pub fn propcheck<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = 0x0bf7f_5eedu64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64 * 0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Replay a single propcheck case by seed.
pub fn propcheck_one<T: std::fmt::Debug>(
    seed: u64,
    mut generate: impl FnMut(&mut Rng) -> T,
    prop: impl FnOnce(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seed_from(seed);
    let input = generate(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("replayed case (seed {seed:#x}) failed:\n  input: {input:?}\n  {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_removes() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.file("x.txt"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn tempdirs_are_unique() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn propcheck_passes_good_property() {
        propcheck(
            "sum-nonneg",
            50,
            |rng| (0..8).map(|_| rng.uniform()).collect::<Vec<f64>>(),
            |xs| {
                if xs.iter().sum::<f64>() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative sum".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn propcheck_reports_failures() {
        propcheck(
            "always-fails",
            3,
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }
}
