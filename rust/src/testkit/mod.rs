//! Test substrates (offline: no `proptest` / `tempfile`).
//!
//! * [`TempDir`] — unique scratch directory, removed on drop;
//! * [`propcheck`] — seeded randomized property harness: runs `cases`
//!   generated inputs through a property, reporting the failing seed so
//!   a failure reproduces deterministically;
//! * [`cases`] — shared case generators (tensor fills, kernel shapes,
//!   conv geometries, mask patterns, cache writer plans) so property
//!   tests compose one vocabulary instead of re-rolling ad-hoc copies.
//!
//! Exposed as a normal module (not `#[cfg(test)]`) so integration tests
//! and benches can use it; it has no cost unless called.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::rng::Rng;

pub mod cases {
    //! Shared randomized-case generators for the property tests.
    //!
    //! `kernel_parity.rs`, `conv_parity.rs` and `sharded_cache.rs` all
    //! draw their inputs from here: tensor fills, dense kernel shapes
    //! straddling the register-tile sizes, awkward conv geometries
    //! (1×1 images, kernel ≥ image, non-tile-multiple channels),
    //! periodic row masks, labelled batches and per-writer cache op
    //! plans.

    use crate::data::dataset::Batch;
    use crate::data::rng::Rng;
    use crate::data::tensor::HostTensor;
    use crate::runtime::kernels::{MR, NR};

    /// `len` standard-normal f32 draws.
    pub fn normal_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    /// ReLU-like activations: standard-normal clamped at zero, so about
    /// half the entries are *exactly* 0.0 (the gate pattern backward
    /// kernels must honour).
    pub fn relu_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.normal() as f32).max(0.0)).collect()
    }

    /// A labelled classification batch: `n×features` normal features
    /// (scaled to keep logits tame) and uniform labels in `0..classes`.
    pub fn class_batch(
        n: usize,
        features: usize,
        classes: usize,
        seed: u64,
    ) -> (HostTensor, HostTensor) {
        let mut rng = Rng::seed_from(seed);
        let x = HostTensor::f32(
            vec![n, features],
            (0..n * features).map(|_| rng.normal() as f32 * 0.4).collect(),
        )
        .expect("consistent shape");
        let y = HostTensor::i32(vec![n], (0..n).map(|_| rng.below(classes) as i32).collect())
            .expect("consistent shape");
        (x, y)
    }

    /// Zero every row of `buf` except each `period`-th one
    /// (`period == 0` zeroes them all — the all-masked-out batch).
    /// Mirrors how masked-out examples carry exact-zero head gradients.
    pub fn zero_rows_except_period(buf: &mut [f32], row_elems: usize, period: usize) {
        for (i, row) in buf.chunks_exact_mut(row_elems).enumerate() {
            if period == 0 || i % period != 0 {
                row.fill(0.0);
            }
        }
    }

    /// Dense kernel shape `(n, din, dout)` deliberately straddling the
    /// `MR`/`NR` register-tile sizes (every remainder path gets hit).
    pub fn dense_dims(rng: &mut Rng) -> (usize, usize, usize) {
        (
            1 + rng.below(3 * MR + 2),
            1 + rng.below(2 * NR + 3),
            1 + rng.below(2 * NR + 3),
        )
    }

    /// Awkward conv geometry `(h, w, cin, cout, k, stride)`: images down
    /// to 1×1, kernels that can exceed the image (SAME padding covers
    /// the overhang), strides past the image size, and channel counts
    /// straddling the `NR` panel width.
    pub fn conv_geometry(rng: &mut Rng) -> (usize, usize, usize, usize, usize, usize) {
        (
            1 + rng.below(5),
            1 + rng.below(5),
            1 + rng.below(4),
            1 + rng.below(NR + 3),
            1 + rng.below(3),
            1 + rng.below(3),
        )
    }

    /// Per-writer loss-cache op plans: writer `w` owns ids ≡ `w` mod
    /// `writers` (so per-id write order is each writer's program
    /// order), each op a `(id, loss, stamp)` with the loss derived from
    /// id and stamp so content mismatches are self-describing.
    pub fn writer_plans(
        rng: &mut Rng,
        capacity: usize,
        writers: usize,
        ops_per_writer: usize,
    ) -> Vec<Vec<(usize, f32, u64)>> {
        let mut plans = Vec::with_capacity(writers);
        for w in 0..writers {
            let owned = (capacity - w).div_ceil(writers);
            let mut plan = Vec::with_capacity(ops_per_writer);
            for _ in 0..ops_per_writer {
                let id = w + writers * rng.below(owned);
                let stamp = rng.below(50) as u64;
                let loss = id as f32 * 0.25 + stamp as f32;
                plan.push((id, loss, stamp));
            }
            plans.push(plan);
        }
        plans
    }

    /// Awkward wire-protocol loss payloads for the proto roundtrip
    /// tests: empty, single-row, non-finite losses (NaN/±inf/-0.0) and
    /// max-version stamps.
    pub fn wire_losses(rng: &mut Rng) -> (Vec<u64>, Vec<f32>, u64) {
        let n = match rng.below(4) {
            0 => 0,
            1 => 1,
            _ => 1 + rng.below(40),
        };
        let ids = (0..n).map(|_| rng.below(10_000) as u64).collect();
        let losses = (0..n)
            .map(|_| match rng.below(6) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => -0.0,
                _ => rng.normal() as f32,
            })
            .collect();
        let stamp = match rng.below(4) {
            0 => u64::MAX,
            1 => u64::MAX - 1,
            _ => rng.below(1 << 20) as u64,
        };
        (ids, losses, stamp)
    }

    /// Awkward [`Batch`] payloads for the wire codec: tiny and odd row
    /// counts, `real` anywhere in `0..=rows` (0 = all-padding batch,
    /// rows = no padding), f32 or i32 targets, padding ids
    /// `usize::MAX`.
    pub fn wire_batch(rng: &mut Rng) -> Batch {
        let rows = 1 + rng.below(7);
        let feat = 1 + rng.below(5);
        let real = rng.below(rows + 1);
        let x = HostTensor::f32(vec![rows, feat], normal_vec(rng, rows * feat))
            .expect("consistent shape");
        let y = if rng.below(2) == 0 {
            HostTensor::f32(vec![rows], normal_vec(rng, rows)).expect("consistent shape")
        } else {
            HostTensor::i32(vec![rows], (0..rows).map(|_| rng.below(10) as i32).collect())
                .expect("consistent shape")
        };
        let mut valid_mask = vec![0.0f32; rows];
        let mut ids = vec![usize::MAX; rows];
        for (row, (m, id)) in valid_mask.iter_mut().zip(ids.iter_mut()).enumerate().take(real) {
            *m = 1.0;
            *id = rng.below(1 << 20) + row;
        }
        Batch { x, y, valid_mask, real, ids }
    }

    /// Relative-tolerance elementwise comparison, reporting the first
    /// offending index — the shared parity assertion.
    pub fn check_close(got: &[f32], want: &[f32], rel_tol: f32, what: &str) -> Result<(), String> {
        if got.len() != want.len() {
            return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
        }
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if (g - w).abs() > rel_tol * w.abs().max(1.0) {
                return Err(format!("{what}[{i}]: got {g} vs want {w}"));
            }
        }
        Ok(())
    }
}

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A scratch directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "obftf-{tag}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Seeded property check: generate `cases` inputs with `gen`, assert
/// `prop` on each. On failure, panics with the per-case seed so the
/// exact case can be replayed with `propcheck_one`.
pub fn propcheck<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = 0x0bf7f_5eedu64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64 * 0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Replay a single propcheck case by seed.
pub fn propcheck_one<T: std::fmt::Debug>(
    seed: u64,
    mut generate: impl FnMut(&mut Rng) -> T,
    prop: impl FnOnce(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seed_from(seed);
    let input = generate(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("replayed case (seed {seed:#x}) failed:\n  input: {input:?}\n  {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_removes() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.file("x.txt"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn tempdirs_are_unique() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn propcheck_passes_good_property() {
        propcheck(
            "sum-nonneg",
            50,
            |rng| (0..8).map(|_| rng.uniform()).collect::<Vec<f64>>(),
            |xs| {
                if xs.iter().sum::<f64>() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative sum".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn propcheck_reports_failures() {
        propcheck(
            "always-fails",
            3,
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn gen_fills_have_expected_structure() {
        let mut rng = Rng::seed_from(1);
        let v = cases::normal_vec(&mut rng, 512);
        assert_eq!(v.len(), 512);
        assert!(v.iter().any(|&x| x < 0.0) && v.iter().any(|&x| x > 0.0));
        let r = cases::relu_vec(&mut rng, 512);
        assert!(r.iter().all(|&x| x >= 0.0));
        let zeros = r.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 100, "ReLU fill should have many exact zeros, got {zeros}");
    }

    #[test]
    fn gen_masking_and_shapes() {
        let mut buf = vec![1.0f32; 12];
        cases::zero_rows_except_period(&mut buf, 3, 2);
        assert_eq!(buf, vec![1., 1., 1., 0., 0., 0., 1., 1., 1., 0., 0., 0.]);
        let mut all = vec![1.0f32; 6];
        cases::zero_rows_except_period(&mut all, 3, 0);
        assert!(all.iter().all(|&v| v == 0.0));
        let mut rng = Rng::seed_from(2);
        for _ in 0..50 {
            let (n, din, dout) = cases::dense_dims(&mut rng);
            assert!(n >= 1 && din >= 1 && dout >= 1);
            let (h, w, cin, cout, k, s) = cases::conv_geometry(&mut rng);
            assert!(h >= 1 && w >= 1 && cin >= 1 && cout >= 1 && k >= 1 && s >= 1);
        }
    }

    #[test]
    fn gen_class_batch_is_deterministic() {
        let (x1, y1) = cases::class_batch(4, 3, 5, 9);
        let (x2, y2) = cases::class_batch(4, 3, 5, 9);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.shape, vec![4, 3]);
        assert!(y1.as_i32().unwrap().iter().all(|&l| (0..5).contains(&l)));
    }

    #[test]
    fn gen_writer_plans_partition_ids() {
        let mut rng = Rng::seed_from(3);
        let plans = cases::writer_plans(&mut rng, 40, 3, 25);
        assert_eq!(plans.len(), 3);
        for (w, plan) in plans.iter().enumerate() {
            assert_eq!(plan.len(), 25);
            for &(id, loss, stamp) in plan {
                assert_eq!(id % 3, w, "writer {w} must own id {id}");
                assert!(id < 40);
                assert_eq!(loss, id as f32 * 0.25 + stamp as f32);
            }
        }
    }

    #[test]
    fn gen_wire_payloads_cover_awkward_cases() {
        let mut rng = Rng::seed_from(7);
        let (mut empty, mut single, mut nonfinite, mut maxstamp, mut all_pad, mut no_pad) =
            (false, false, false, false, false, false);
        for _ in 0..200 {
            let (ids, losses, stamp) = cases::wire_losses(&mut rng);
            assert_eq!(ids.len(), losses.len());
            empty |= ids.is_empty();
            single |= ids.len() == 1;
            nonfinite |= losses.iter().any(|l| !l.is_finite());
            maxstamp |= stamp == u64::MAX;
            let b = cases::wire_batch(&mut rng);
            assert_eq!(b.valid_mask.len(), b.ids.len());
            assert_eq!(b.x.shape[0], b.valid_mask.len());
            assert_eq!(b.valid_mask.iter().filter(|&&m| m > 0.0).count(), b.real);
            all_pad |= b.real == 0;
            no_pad |= b.real == b.valid_mask.len();
        }
        assert!(
            empty && single && nonfinite && maxstamp && all_pad && no_pad,
            "generators must cover the awkward corners"
        );
    }

    #[test]
    fn gen_check_close_reports_index() {
        assert!(cases::check_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, "t").is_ok());
        let err = cases::check_close(&[1.0, 2.5], &[1.0, 2.0], 1e-4, "t").unwrap_err();
        assert!(err.contains("t[1]"), "err: {err}");
        assert!(cases::check_close(&[1.0], &[1.0, 2.0], 1e-6, "t").is_err());
    }
}
