//! # obftf — One Backward from Ten Forward
//!
//! Production reproduction of *“One Backward from Ten Forward,
//! Subsampling for Large-Scale Deep Learning”* (Dong et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the streaming training coordinator: data
//!   ingestion, batching, the paper's loss-aware *selection* algorithms
//!   (the system contribution), the subset-approximation solver, the
//!   leader/worker data-parallel runtime, metrics, checkpoints, CLI.
//! * **L2 (`python/compile/model.py`)** — the models (linreg / MLP /
//!   CNN), AOT-lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the dense
//!   layers, per-example losses and SGD updates.
//!
//! Python never runs at training time. Execution goes through the
//! [`runtime::Backend`] abstraction: the **native** flavour is a
//! pure-Rust CPU backend (ports of the `ref.py` oracles) that runs on a
//! fresh checkout with no artifacts, JAX or PJRT; the **pallas** /
//! **jnp** flavours load `artifacts/*.hlo.txt` through the PJRT C API
//! (`pjrt` cargo feature) after a one-time `make artifacts`.
//!
//! ## Quick start
//!
//! ```no_run
//! use obftf::config::TrainConfig;
//! use obftf::coordinator::Trainer;
//!
//! let mut cfg = TrainConfig::default();
//! cfg.model = "mlp".into();
//! cfg.method = obftf::sampling::Method::Obftf;
//! cfg.sampling_ratio = 0.25;
//! cfg.epochs = 3;
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final eval: {:?}", report.final_eval);
//! ```

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod solver;
pub mod testkit;
pub mod util;

pub use config::TrainConfig;
pub use coordinator::Trainer;
pub use sampling::Method;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$OBFTF_ARTIFACTS`, else `artifacts/`
/// relative to the crate root (works from `cargo run`/`test`/`bench`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("OBFTF_ARTIFACTS") {
        return std::path::PathBuf::from(dir);
    }
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}
