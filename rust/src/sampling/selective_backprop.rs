//! Selective-Backprop baseline (Jiang et al. 2019; paper appendix
//! `"prob"`).
//!
//! Keep example `i` with probability
//! `(1 − e^{−2γL_i}) / (1 + e^{−2γL_i}) = tanh(γ·L_i)`
//! — higher loss, higher chance of a backward pass.
//!
//! The raw rule's realized count depends on the loss scale, which makes
//! cross-method comparisons at a fixed sampling ratio unfair; with
//! `calibrate = true` (default) the probabilities are rescaled so their
//! sum equals the budget (expected count = b) while preserving the
//! loss-proportional *shape*. Set `calibrate = false` for the verbatim
//! paper rule.

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SelectiveBackprop {
    pub gamma: f32,
    pub calibrate: bool,
}

impl SelectiveBackprop {
    pub fn new(gamma: f32) -> Self {
        SelectiveBackprop { gamma, calibrate: true }
    }

    pub fn raw(gamma: f32) -> Self {
        SelectiveBackprop { gamma, calibrate: false }
    }
}

impl Sampler for SelectiveBackprop {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let vi = valid_indices(valid);
        if vi.is_empty() || budget == 0 {
            return vec![];
        }
        let mut probs: Vec<f64> = vi
            .iter()
            .map(|&i| ((self.gamma * losses[i]) as f64).tanh().max(0.0))
            .collect();
        if self.calibrate {
            let sum: f64 = probs.iter().sum();
            if sum > 1e-12 {
                let scale = budget as f64 / sum;
                for p in probs.iter_mut() {
                    *p = (*p * scale).min(1.0);
                }
            } else {
                // all losses ≈ 0: degenerate to uniform at the budget rate
                let r = budget as f64 / vi.len() as f64;
                for p in probs.iter_mut() {
                    *p = r;
                }
            }
        }
        let mut out: Vec<usize> = vi
            .iter()
            .zip(&probs)
            .filter(|(_, &p)| rng.bernoulli(p))
            .map(|(&i, _)| i)
            .collect();
        if out.is_empty() {
            out.push(vi[rng.below(vi.len())]);
        }
        out
    }

    fn name(&self) -> &'static str {
        "selective_backprop"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefers_high_loss_examples() {
        // half the batch has loss 0.01, half loss 5.0
        let mut losses = vec![0.01f32; 64];
        losses.extend(vec![5.0f32; 64]);
        let valid = vec![1.0f32; 128];
        let mut rng = Rng::seed_from(11);
        let mut s = SelectiveBackprop::new(1.0);
        let mut low = 0usize;
        let mut high = 0usize;
        for _ in 0..50 {
            for i in s.select(&losses, &valid, 32, &mut rng) {
                if i < 64 {
                    low += 1;
                } else {
                    high += 1;
                }
            }
        }
        assert!(high > 10 * low, "high {high} low {low}");
    }

    #[test]
    fn calibrated_count_tracks_budget() {
        let losses: Vec<f32> = (0..256).map(|i| 0.1 + i as f32 / 64.0).collect();
        let valid = vec![1.0f32; 256];
        let mut rng = Rng::seed_from(13);
        let mut s = SelectiveBackprop::new(1.0);
        let total: usize = (0..30)
            .map(|_| s.select(&losses, &valid, 64, &mut rng).len())
            .sum();
        let mean = total as f64 / 30.0;
        assert!((52.0..76.0).contains(&mean), "mean count {mean}");
    }

    #[test]
    fn raw_rule_matches_tanh_probability_scale() {
        // gamma large → p ≈ 1 for any positive loss → selects ~everything
        let losses = vec![3.0f32; 64];
        let valid = vec![1.0f32; 64];
        let mut rng = Rng::seed_from(17);
        let mut s = SelectiveBackprop::raw(10.0);
        let sel = s.select(&losses, &valid, 4, &mut rng);
        assert!(sel.len() > 56, "selected {}", sel.len());
    }

    #[test]
    fn zero_losses_degenerate_to_uniform() {
        let losses = vec![0.0f32; 100];
        let valid = vec![1.0f32; 100];
        let mut rng = Rng::seed_from(19);
        let mut s = SelectiveBackprop::new(1.0);
        let counts: Vec<usize> = (0..20)
            .map(|_| s.select(&losses, &valid, 25, &mut rng).len())
            .collect();
        let mean = counts.iter().sum::<usize>() as f64 / 20.0;
        assert!((15.0..35.0).contains(&mean), "mean {mean}");
    }
}
