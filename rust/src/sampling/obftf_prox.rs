//! OBFTF-prox (paper appendix `"OBFTF_prox"`): the O(n log n)
//! approximation of the subset problem — sort by loss descending, then
//! take a strided slice.
//!
//! A stride of `n/(b+1)` over the sorted order is a quantile sketch of
//! the loss distribution, so the selected subset's mean tracks the batch
//! mean without solving anything. The verbatim paper rule:
//! `ind_sorted[floor(i · n/(b+1))]` for `i = 1..=b`.

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct ObftfProx;

impl Sampler for ObftfProx {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        _rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let mut vi = valid_indices(valid);
        let n = vi.len();
        let b = budget.min(n);
        if b == 0 {
            return vec![];
        }
        vi.sort_by(|&a, &c| losses[c].partial_cmp(&losses[a]).unwrap());
        let stride = n as f64 / (b + 1) as f64;
        let mut out = Vec::with_capacity(b);
        for i in 1..=b {
            let q = ((i as f64 * stride).floor() as usize).min(n - 1);
            out.push(vi[q]);
        }
        out.sort_unstable();
        out.dedup(); // stride < 1 can repeat positions when b ≈ n
        out
    }

    fn name(&self) -> &'static str {
        "obftf_prox"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strided_pick_spans_the_loss_range() {
        let losses: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let valid = vec![1.0f32; 100];
        let mut rng = Rng::seed_from(0);
        let got = ObftfProx.select(&losses, &valid, 9, &mut rng);
        assert_eq!(got.len(), 9);
        // neither extreme should be over-represented: mean of selected
        // losses tracks the batch mean (49.5)
        let mean: f32 = got.iter().map(|&i| losses[i]).sum::<f32>() / 9.0;
        assert!((39.5..59.5).contains(&mean), "selected mean {mean}");
    }

    #[test]
    fn skips_the_single_largest_loss() {
        // stride starts at i=1, so the max-loss example (an outlier) is
        // skipped unless b ≈ n — the robustness property.
        let mut losses = vec![1.0f32; 20];
        losses[4] = 1e6;
        let valid = vec![1.0f32; 20];
        let mut rng = Rng::seed_from(0);
        let got = ObftfProx.select(&losses, &valid, 4, &mut rng);
        assert!(!got.contains(&4));
    }

    #[test]
    fn handles_budget_close_to_n() {
        let losses: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let valid = vec![1.0f32; 8];
        let mut rng = Rng::seed_from(0);
        let got = ObftfProx.select(&losses, &valid, 8, &mut rng);
        assert!(!got.is_empty());
        assert!(got.len() <= 8);
    }
}
