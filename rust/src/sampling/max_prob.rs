//! Max-prob baseline (paper Table 3 "Max prob."): keep the `b` examples
//! with the *highest* loss — the deterministic "biggest losers" rule.
//!
//! Fast early progress, but collapses on noisy data: mislabelled or
//! outlier examples have persistently high loss and monopolize the
//! backward budget (the Table 3 accuracy collapse this repo reproduces).

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct MaxProb;

impl Sampler for MaxProb {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        _rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let mut vi = valid_indices(valid);
        let b = budget.min(vi.len());
        if b == 0 {
            return vec![];
        }
        vi.sort_by(|&a, &c| losses[c].partial_cmp(&losses[a]).unwrap());
        vi.truncate(b);
        vi
    }

    fn name(&self) -> &'static str {
        "max_prob"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_highest_losses() {
        let losses = vec![5.0, 1.0, 3.0, 0.5, 4.0];
        let valid = vec![1.0f32; 5];
        let mut rng = Rng::seed_from(0);
        let mut got = MaxProb.select(&losses, &valid, 2, &mut rng);
        got.sort_unstable();
        assert_eq!(got, vec![0, 4]);
    }

    #[test]
    fn outliers_monopolize_budget() {
        let mut losses = vec![1.0f32; 10];
        losses[2] = 500.0;
        losses[8] = 900.0;
        let valid = vec![1.0f32; 10];
        let mut rng = Rng::seed_from(0);
        let mut got = MaxProb.select(&losses, &valid, 2, &mut rng);
        got.sort_unstable();
        assert_eq!(got, vec![2, 8]);
    }
}
