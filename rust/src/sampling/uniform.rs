//! Uniform subsampling baseline (paper §4, appendix `"uniform"`).
//!
//! Bernoulli(ratio) per example, exactly as the paper's reference code:
//! the realized count varies around the budget; at least one example is
//! always selected ("guarantee at least one data is sampled out").

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct Uniform;

impl Sampler for Uniform {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let vi = valid_indices(valid);
        if vi.is_empty() || budget == 0 {
            return vec![];
        }
        let ratio = budget as f64 / vi.len() as f64;
        let mut out: Vec<usize> =
            vi.iter().copied().filter(|_| rng.bernoulli(ratio)).collect();
        if out.is_empty() {
            out.push(vi[rng.below(vi.len())]);
        }
        out
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realized_count_tracks_ratio() {
        let losses = vec![0.0f32; 1000];
        let valid = vec![1.0f32; 1000];
        let mut rng = Rng::seed_from(2);
        let mut s = Uniform;
        let total: usize = (0..20)
            .map(|_| s.select(&losses, &valid, 250, &mut rng).len())
            .sum();
        let mean = total as f64 / 20.0;
        assert!((200.0..300.0).contains(&mean), "mean count {mean}");
    }

    #[test]
    fn never_empty_for_positive_budget() {
        let losses = vec![0.0f32; 8];
        let valid = vec![1.0f32; 8];
        let mut rng = Rng::seed_from(3);
        let mut s = Uniform;
        for _ in 0..100 {
            assert!(!s.select(&losses, &valid, 1, &mut rng).is_empty());
        }
    }

    #[test]
    fn ignores_loss_values() {
        // same rng stream, different losses → identical selection
        let valid = vec![1.0f32; 32];
        let a = Uniform.select(&vec![0.0; 32], &valid, 8, &mut Rng::seed_from(7));
        let b = Uniform.select(&vec![9.9; 32], &valid, 8, &mut Rng::seed_from(7));
        assert_eq!(a, b);
    }
}
