//! OBFTF — the paper's method (§3.3, Algorithm 1).
//!
//! Per batch: (1) compute the batch mean loss; (2) noise it with
//! `N(mean, std/√b)` exactly as the reference implementation
//! (`np.random.normal(np.mean(loss), np.std(loss)/np.sqrt(N1))`) — the
//! jitter decorrelates consecutive steps' targets; (3) solve the sparse
//! subset approximation problem Eq. 6 for the `b` examples whose mean
//! loss best matches the target.
//!
//! The paper calls OR-tools CBC; we dispatch to our own solver stack
//! ([`SolverKind`]): exact branch-and-bound (default), ε-approximate DP,
//! or the Frank–Wolfe relaxation.

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;
use crate::solver::bnb::BranchBound;
use crate::solver::dp::DpApprox;
use crate::solver::frank_wolfe::FrankWolfe;
use crate::solver::{SubsetProblem, SubsetSolver};

/// Which subset-approximation solver backs OBFTF.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    BranchBound,
    Dp,
    FrankWolfe,
}

/// The OBFTF sampler.
///
/// **Composition degeneracy** (found empirically; DESIGN.md
/// `abl-solver`): Eq. 6 constrains only the subset *mean*, which many
/// subsets satisfy. Driving the solver to exact optimality returns
/// arbitrary optimal compositions — often "b−1 easy examples + one
/// extreme outlier" — whose *gradients* are terrible at small budgets
/// (the paper's batch 4096 / b≈410 hides this; our batch-128 / b≈13
/// regime exposes it). The fix: solve to within `tolerance_frac` of the
/// statistical noise floor `std/√b` instead of to optimality. The B&B's
/// incumbent (a quantile-strided, swap-polished subset) then wins
/// whenever it is statistically indistinguishable from exact, keeping a
/// distribution-matched composition. Set `tolerance_frac = 0` to study
/// the degenerate exact behaviour.
#[derive(Clone, Copy, Debug)]
pub struct Obftf {
    pub solver: SolverKind,
    /// Scale on the target-noise term (1.0 = paper; 0.0 = deterministic
    /// batch mean, used by the ablation benches).
    pub noise_scale: f64,
    /// Solve tolerance as a fraction of `std/√b` (see above).
    pub tolerance_frac: f64,
}

impl Obftf {
    pub fn new(solver: SolverKind) -> Self {
        Obftf { solver, noise_scale: 1.0, tolerance_frac: 0.1 }
    }

    pub fn deterministic(solver: SolverKind) -> Self {
        Obftf { solver, noise_scale: 0.0, tolerance_frac: 0.1 }
    }

    /// Exact-optimality variant (the composition-degenerate one).
    pub fn exact(solver: SolverKind) -> Self {
        Obftf { solver, noise_scale: 1.0, tolerance_frac: 0.0 }
    }

    fn run_solver(&self, p: &SubsetProblem, noise_floor: f64) -> Vec<usize> {
        match self.solver {
            SolverKind::BranchBound => {
                let bnb = BranchBound {
                    tolerance: (self.tolerance_frac * noise_floor).max(1e-12),
                    ..Default::default()
                };
                bnb.solve(p).indices
            }
            SolverKind::Dp => DpApprox::default().solve(p).indices,
            SolverKind::FrankWolfe => FrankWolfe::default().solve(p).indices,
        }
    }
}

impl Sampler for Obftf {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let vi = valid_indices(valid);
        let b = budget.min(vi.len());
        if b == 0 {
            return vec![];
        }
        let vals: Vec<f32> = vi.iter().map(|&i| losses[i]).collect();
        let n = vals.len() as f64;
        let mean = vals.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = vals
            .iter()
            .map(|&v| (v as f64 - mean) * (v as f64 - mean))
            .sum::<f64>()
            / n;
        // Target jitter: the appendix noises the target with
        // `N(mean, std/√N1)` where `N1` is an undefined global in the
        // paper's listing. We read it as the *batch* size — the standard
        // error of the batch-mean estimate itself — which is the
        // statistically coherent interpretation and stays proportionate
        // at small batches (reading it as the subset size makes the
        // jitter dominate the signal at b ≈ 13; see EXPERIMENTS.md).
        let target_jitter = var.sqrt() / n.sqrt();
        // Solve tolerance is measured against the subset mean's own
        // granularity, std/√b.
        let subset_floor = var.sqrt() / (b as f64).sqrt();
        let target = mean + self.noise_scale * target_jitter * rng.normal();

        let p = SubsetProblem::new(&vals, b, target)
            .expect("losses validated finite upstream");
        let local = self.run_solver(&p, subset_floor);
        local.into_iter().map(|q| vi[q]).collect()
    }

    fn name(&self) -> &'static str {
        match self.solver {
            SolverKind::BranchBound => "obftf",
            SolverKind::Dp => "obftf_dp",
            SolverKind::FrankWolfe => "frank_wolfe",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lognormal_losses(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| (rng.normal() * 0.8).exp() as f32).collect()
    }

    #[test]
    fn selected_mean_tracks_batch_mean() {
        let losses = lognormal_losses(128, 5);
        let valid = vec![1.0f32; 128];
        let batch_mean = losses.iter().sum::<f32>() / 128.0;
        let mut rng = Rng::seed_from(7);
        for kind in [SolverKind::BranchBound, SolverKind::Dp, SolverKind::FrankWolfe] {
            let mut s = Obftf::deterministic(kind);
            let sel = s.select(&losses, &valid, 32, &mut rng);
            assert_eq!(sel.len(), 32, "{kind:?}");
            let m = sel.iter().map(|&i| losses[i]).sum::<f32>() / 32.0;
            assert!(
                (m - batch_mean).abs() < 0.02,
                "{kind:?}: selected mean {m} vs batch mean {batch_mean}"
            );
        }
    }

    #[test]
    fn robust_to_outliers_unlike_max_prob() {
        // one catastrophic outlier: OBFTF must not select it (its value
        // alone would blow the subset mean far past the batch mean)
        let mut losses = vec![1.0f32; 64];
        losses[10] = 10_000.0;
        let valid = vec![1.0f32; 64];
        let mut rng = Rng::seed_from(9);
        let mut s = Obftf::deterministic(SolverKind::BranchBound);
        let sel = s.select(&losses, &valid, 8, &mut rng);
        assert!(!sel.contains(&10), "OBFTF selected the outlier");
    }

    #[test]
    fn noise_makes_selection_vary_across_steps() {
        let losses = lognormal_losses(64, 21);
        let valid = vec![1.0f32; 64];
        let mut rng = Rng::seed_from(3);
        let mut s = Obftf::new(SolverKind::BranchBound);
        let a = s.select(&losses, &valid, 16, &mut rng);
        let b = s.select(&losses, &valid, 16, &mut rng);
        assert_ne!(a, b, "noised targets should vary the selection");
    }

    #[test]
    fn deterministic_mode_is_stable() {
        let losses = lognormal_losses(64, 22);
        let valid = vec![1.0f32; 64];
        let mut s = Obftf::deterministic(SolverKind::BranchBound);
        let a = s.select(&losses, &valid, 16, &mut Rng::seed_from(1));
        let b = s.select(&losses, &valid, 16, &mut Rng::seed_from(99));
        assert_eq!(a, b);
    }

    #[test]
    fn respects_validity_mask() {
        let losses = lognormal_losses(32, 23);
        let mut valid = vec![1.0f32; 32];
        for v in valid.iter_mut().skip(16) {
            *v = 0.0;
        }
        let mut rng = Rng::seed_from(4);
        let mut s = Obftf::new(SolverKind::Dp);
        let sel = s.select(&losses, &valid, 8, &mut rng);
        assert!(sel.iter().all(|&i| i < 16));
    }
}
