//! Min-k loss selection (Shah, Wu & Sanghavi 2020; paper baseline
//! `minK`): keep the `b` examples with the *lowest* loss.
//!
//! Robust to outliers (they never get selected) but slow to converge —
//! the instability band the paper shows in Fig 1/2.

use super::{valid_indices, Sampler};
use crate::data::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct MinK;

impl Sampler for MinK {
    fn select(
        &mut self,
        losses: &[f32],
        valid: &[f32],
        budget: usize,
        _rng: &mut Rng,
    ) -> Vec<usize> {
        debug_assert_eq!(losses.len(), valid.len());
        let mut vi = valid_indices(valid);
        let b = budget.min(vi.len());
        if b == 0 {
            return vec![];
        }
        vi.sort_by(|&a, &c| losses[a].partial_cmp(&losses[c]).unwrap());
        vi.truncate(b);
        vi
    }

    fn name(&self) -> &'static str {
        "mink"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_lowest_losses() {
        let losses = vec![5.0, 1.0, 3.0, 0.5, 4.0];
        let valid = vec![1.0f32; 5];
        let mut rng = Rng::seed_from(0);
        let mut got = MinK.select(&losses, &valid, 2, &mut rng);
        got.sort_unstable();
        assert_eq!(got, vec![1, 3]);
    }

    #[test]
    fn excludes_outliers_entirely() {
        let mut losses = vec![1.0f32; 10];
        losses[7] = 1000.0; // outlier
        let valid = vec![1.0f32; 10];
        let mut rng = Rng::seed_from(0);
        let got = MinK.select(&losses, &valid, 9, &mut rng);
        assert!(!got.contains(&7));
    }

    #[test]
    fn budget_larger_than_valid_rows() {
        let losses = vec![1.0, 2.0, 3.0];
        let valid = vec![1.0, 1.0, 0.0];
        let mut rng = Rng::seed_from(0);
        let got = MinK.select(&losses, &valid, 5, &mut rng);
        assert_eq!(got.len(), 2);
    }
}
