//! Batch selection policies ("which examples earn a backward pass").
//!
//! Every policy implements [`Sampler`]: given the per-example losses
//! recorded from the forward pass (the paper's "constant amount of
//! information per instance"), a validity mask (padding rows are never
//! selectable) and a budget `b`, return the indices that participate in
//! the backward pass.
//!
//! | [`Method`] | paper | semantics |
//! |---|---|---|
//! | `Uniform` | §4 baseline | Bernoulli(ratio) per example |
//! | `SelectiveBackprop` | [38] | keep w.p. `tanh(γ·L)`, budget-calibrated |
//! | `MinK` | [39] | `b` lowest-loss examples |
//! | `MaxProb` | Table 3 baseline | `b` highest-loss examples |
//! | `Obftf` | §3.3 (ours) | sparse subset approx, exact B&B solver |
//! | `ObftfProx` | appendix | strided pick over loss-sorted order |
//! | `ObftfDp` | (ablation) | subset approx via ε-DP solver |
//! | `FrankWolfe` | §3.3 future work | subset approx via FW relaxation |

pub mod max_prob;
pub mod mink;
pub mod obftf;
pub mod obftf_prox;
pub mod selective_backprop;
pub mod uniform;

use std::str::FromStr;

use anyhow::bail;

use crate::data::rng::Rng;

pub use max_prob::MaxProb;
pub use mink::MinK;
pub use obftf::{Obftf, SolverKind};
pub use obftf_prox::ObftfProx;
pub use selective_backprop::SelectiveBackprop;
pub use uniform::Uniform;

/// A batch-selection policy. `&mut self` lets stateful policies (e.g.
/// history-based extensions) evolve across steps.
pub trait Sampler: Send {
    /// Return the selected indices (subset of valid rows, unsorted ok).
    ///
    /// * `losses` — per-example losses, length = compiled batch size;
    /// * `valid`  — 1.0 for real rows, 0.0 for padding;
    /// * `budget` — target number of selected examples (see
    ///   [`budget_for`]); policies may return fewer (never more than
    ///   the number of valid rows).
    fn select(&mut self, losses: &[f32], valid: &[f32], budget: usize, rng: &mut Rng)
        -> Vec<usize>;

    fn name(&self) -> &'static str;
}

/// The configured selection method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Uniform,
    SelectiveBackprop,
    MinK,
    MaxProb,
    Obftf,
    ObftfProx,
    ObftfDp,
    FrankWolfe,
}

impl Method {
    pub const ALL: [Method; 8] = [
        Method::Uniform,
        Method::SelectiveBackprop,
        Method::MinK,
        Method::MaxProb,
        Method::Obftf,
        Method::ObftfProx,
        Method::ObftfDp,
        Method::FrankWolfe,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Uniform => "uniform",
            Method::SelectiveBackprop => "selective_backprop",
            Method::MinK => "mink",
            Method::MaxProb => "max_prob",
            Method::Obftf => "obftf",
            Method::ObftfProx => "obftf_prox",
            Method::ObftfDp => "obftf_dp",
            Method::FrankWolfe => "frank_wolfe",
        }
    }

    /// Instantiate the sampler. `gamma` only affects SelectiveBackprop.
    pub fn build(&self, gamma: f32) -> Box<dyn Sampler> {
        match self {
            Method::Uniform => Box::new(Uniform),
            Method::SelectiveBackprop => Box::new(SelectiveBackprop::new(gamma)),
            Method::MinK => Box::new(MinK),
            Method::MaxProb => Box::new(MaxProb),
            Method::Obftf => Box::new(Obftf::new(SolverKind::BranchBound)),
            Method::ObftfProx => Box::new(ObftfProx),
            Method::ObftfDp => Box::new(Obftf::new(SolverKind::Dp)),
            Method::FrankWolfe => Box::new(Obftf::new(SolverKind::FrankWolfe)),
        }
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        for m in Method::ALL {
            if m.as_str() == s {
                return Ok(m);
            }
        }
        bail!(
            "unknown method {s:?}; expected one of {}",
            Method::ALL.map(|m| m.as_str()).join(" | ")
        )
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Indices of valid (non-padding) rows.
pub fn valid_indices(valid: &[f32]) -> Vec<usize> {
    valid
        .iter()
        .enumerate()
        .filter(|(_, &m)| m > 0.0)
        .map(|(i, _)| i)
        .collect()
}

/// The per-batch budget `b = round(ratio · n_valid)`, clamped to
/// `[1, n_valid]` (the paper guarantees at least one selected example).
pub fn budget_for(ratio: f64, n_valid: usize) -> usize {
    if n_valid == 0 {
        return 0;
    }
    (((ratio * n_valid as f64).round() as usize).max(1)).min(n_valid)
}

/// Order-sensitive FNV-1a fingerprint of a selection. The gathered
/// backward reduces rows in selection order, so two trainers are only
/// bit-identical when their selections match *including order* — this
/// is the compact per-step observable the pipeline-vs-serial
/// equivalence tests compare (recorded as `StepRecord::sel_hash`).
pub fn selection_hash(selected: &[usize]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &i in selected {
        for b in (i as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Convert selected indices into the f32 0/1 mask the `train_step`
/// executable consumes.
pub fn selection_mask(indices: &[usize], n: usize) -> Vec<f32> {
    let mut mask = vec![0.0f32; n];
    for &i in indices {
        debug_assert!(i < n);
        mask[i] = 1.0;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_roundtrip_strings() {
        for m in Method::ALL {
            assert_eq!(Method::from_str(m.as_str()).unwrap(), m);
        }
        assert!(Method::from_str("bogus").is_err());
    }

    #[test]
    fn budget_bounds() {
        assert_eq!(budget_for(0.0, 100), 1); // at least one
        assert_eq!(budget_for(0.25, 128), 32);
        assert_eq!(budget_for(1.0, 128), 128);
        assert_eq!(budget_for(2.0, 10), 10); // clamped
        assert_eq!(budget_for(0.5, 0), 0);
    }

    #[test]
    fn mask_from_indices() {
        let m = selection_mask(&[0, 3], 5);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn selection_hash_is_order_sensitive() {
        assert_eq!(selection_hash(&[1, 2, 3]), selection_hash(&[1, 2, 3]));
        assert_ne!(selection_hash(&[1, 2, 3]), selection_hash(&[3, 2, 1]));
        assert_ne!(selection_hash(&[]), selection_hash(&[0]));
        assert_ne!(selection_hash(&[0, 1]), selection_hash(&[1]));
    }

    #[test]
    fn valid_indices_skips_padding() {
        assert_eq!(valid_indices(&[1.0, 0.0, 1.0]), vec![0, 2]);
    }

    #[test]
    fn all_methods_build_and_select() {
        let losses: Vec<f32> = (0..16).map(|i| i as f32 / 4.0).collect();
        let valid = vec![1.0f32; 16];
        let mut rng = Rng::seed_from(0);
        for m in Method::ALL {
            let mut s = m.build(1.0);
            let sel = s.select(&losses, &valid, 4, &mut rng);
            assert!(!sel.is_empty(), "{m} selected nothing");
            assert!(sel.iter().all(|&i| i < 16));
            let mut u = sel.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), sel.len(), "{m} returned duplicates");
        }
    }

    #[test]
    fn no_method_selects_padding() {
        let losses: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let valid = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::seed_from(1);
        for m in Method::ALL {
            let mut s = m.build(1.0);
            for trial in 0..10 {
                let sel = s.select(&losses, &valid, 3, &mut rng);
                assert!(
                    sel.iter().all(|&i| i < 4),
                    "{m} trial {trial} selected padding row: {sel:?}"
                );
            }
        }
    }
}
