//! Checkpointing: durable parameter snapshots for the continuous-
//! training setting (crash/resume without losing the stream position).
//!
//! Format (little-endian, versioned):
//! ```text
//!   magic  "OBTF"    4 bytes
//!   version u32      (=1)
//!   step    u64
//!   epoch   u64
//!   n_tensors u32
//!   per tensor: name_len u32, name bytes, rank u32, dims u64...,
//!               dtype u8 (0=f32, 1=i32), data bytes
//! ```
//! Writes go to `<path>.tmp` then `rename` — a crash mid-write never
//! corrupts the previous checkpoint.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::tensor::{HostTensor, TensorData};

const MAGIC: &[u8; 4] = b"OBTF";
const VERSION: u32 = 1;

/// A parameter snapshot plus training position.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub epoch: u64,
    /// `(name, tensor)` in manifest parameter order.
    pub params: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    /// Serialize to `path` atomically (tmp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&self.step.to_le_bytes())?;
            f.write_all(&self.epoch.to_le_bytes())?;
            f.write_all(&(self.params.len() as u32).to_le_bytes())?;
            for (name, t) in &self.params {
                f.write_all(&(name.len() as u32).to_le_bytes())?;
                f.write_all(name.as_bytes())?;
                f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
                for &d in &t.shape {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                match &t.data {
                    TensorData::F32(v) => {
                        f.write_all(&[0u8])?;
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                    TensorData::I32(v) => {
                        f.write_all(&[1u8])?;
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                    TensorData::Bf16(_) => {
                        bail!("bf16 tensors are wire-only; checkpoints hold exact f32 params")
                    }
                }
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    /// Load and validate from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not an obftf checkpoint (bad magic)");
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut f)?;
        let epoch = read_u64(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        if n > 10_000 {
            bail!("implausible tensor count {n} (corrupt checkpoint?)");
        }
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("non-utf8 tensor name")?;
            let rank = read_u32(&mut f)? as usize;
            if rank > 16 {
                bail!("implausible rank {rank}");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let count: usize = shape.iter().product();
            if count > 1 << 30 {
                bail!("implausible tensor size {count}");
            }
            let mut dtype = [0u8; 1];
            f.read_exact(&mut dtype)?;
            let tensor = match dtype[0] {
                0 => {
                    let mut v = vec![0f32; count];
                    for x in v.iter_mut() {
                        let mut b = [0u8; 4];
                        f.read_exact(&mut b)?;
                        *x = f32::from_le_bytes(b);
                    }
                    HostTensor { shape, data: TensorData::F32(v) }
                }
                1 => {
                    let mut v = vec![0i32; count];
                    for x in v.iter_mut() {
                        let mut b = [0u8; 4];
                        f.read_exact(&mut b)?;
                        *x = i32::from_le_bytes(b);
                    }
                    HostTensor { shape, data: TensorData::I32(v) }
                }
                d => bail!("unknown dtype tag {d}"),
            };
            params.push((name, tensor));
        }
        Ok(Checkpoint { step, epoch, params })
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Checkpoint {
        Checkpoint {
            step: 123,
            epoch: 4,
            params: vec![
                ("w".into(), HostTensor::f32(vec![2, 2], vec![1.0, -2.5, 3.0, 0.0]).unwrap()),
                ("labels".into(), HostTensor::i32(vec![3], vec![7, -1, 0]).unwrap()),
            ],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::testkit::TempDir::new("ck").unwrap();
        let p = dir.path().join("ck.bin");
        let ck = toy();
        ck.save(&p).unwrap();
        let got = Checkpoint::load(&p).unwrap();
        assert_eq!(got, ck);
    }

    #[test]
    fn atomic_overwrite_keeps_latest() {
        let dir = crate::testkit::TempDir::new("ck").unwrap();
        let p = dir.path().join("ck.bin");
        let mut ck = toy();
        ck.save(&p).unwrap();
        ck.step = 999;
        ck.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().step, 999);
        assert!(!p.with_extension("tmp").exists());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::testkit::TempDir::new("ck").unwrap();
        let p = dir.path().join("junk.bin");
        std::fs::write(&p, b"NOPE0000000000000000").unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("bad magic"));
    }

    #[test]
    fn truncated_file_rejected() {
        let dir = crate::testkit::TempDir::new("ck").unwrap();
        let p = dir.path().join("ck.bin");
        toy().save(&p).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
