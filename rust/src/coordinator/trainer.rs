//! The single-process OBFTF training loop (paper Algorithm 1).
//!
//! Per batch: **forward** every example (line 4–5), **select** the
//! backward subset with the configured policy (line 6–7), **backward**
//! only the selection (line 8). Everything is timed and recorded; the
//! compute accounting lives in [`super::budget`].

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::data::dataset::{Batch, BatchIter, InMemoryDataset};
use crate::data::rng::Rng;
use crate::metrics::{EvalRecord, Recorder, StepRecord};
use crate::runtime::{Flavour, Manifest, Session};
use crate::sampling::{budget_for, selection_mask, Sampler};
use crate::coordinator::budget::BudgetTracker;

/// Final evaluation numbers.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub loss: f64,
    /// Accuracy for classification, MSE for regression.
    pub metric: f64,
}

/// What a training run returns.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub method: String,
    pub sampling_ratio: f64,
    pub epochs: usize,
    pub steps: u64,
    pub final_eval: EvalResult,
    pub evals: Vec<EvalRecord>,
    pub forward_examples: u64,
    pub backward_examples: u64,
    pub realized_ratio: f64,
    pub saved_fraction: f64,
    pub steps_per_sec: f64,
    pub latency_summary: String,
}

impl TrainReport {
    /// JSON rendering for the CLI / logs (no serde offline).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("model", Json::Str(self.model.clone()))
            .set("method", Json::Str(self.method.clone()))
            .set("sampling_ratio", Json::Num(self.sampling_ratio))
            .set("epochs", Json::Num(self.epochs as f64))
            .set("steps", Json::Num(self.steps as f64))
            .set("final_loss", Json::Num(self.final_eval.loss))
            .set("final_metric", Json::Num(self.final_eval.metric))
            .set("forward_examples", Json::Num(self.forward_examples as f64))
            .set("backward_examples", Json::Num(self.backward_examples as f64))
            .set("realized_ratio", Json::Num(self.realized_ratio))
            .set("saved_fraction", Json::Num(self.saved_fraction))
            .set("steps_per_sec", Json::Num(self.steps_per_sec))
            .set("latency", Json::Str(self.latency_summary.clone()))
            .set(
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            let mut o = Json::obj();
                            o.set("step", Json::Num(e.step as f64))
                                .set("epoch", Json::Num(e.epoch as f64))
                                .set("loss", Json::Num(e.loss))
                                .set("metric", Json::Num(e.metric));
                            o
                        })
                        .collect(),
                ),
            );
        j
    }
}

// The dataset builder every trainer variant shares now lives in
// `coordinator::mod` (one construction path for serial, parallel,
// streaming and pipeline); re-exported here for source compatibility.
pub use super::build_datasets;

/// The single-process trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    session: Session,
    sampler: Box<dyn Sampler>,
    train: InMemoryDataset,
    test: InMemoryDataset,
    rng: Rng,
    pub recorder: Recorder,
    pub budget: BudgetTracker,
    /// Per-instance loss cache (`cfg.reuse_losses`): losses recorded
    /// from earlier forwards stand in for re-execution — the paper's
    /// "inference already ran the forward" premise.
    cache: Option<crate::coordinator::loss_cache::LossCache>,
    step: u64,
    epoch: usize,
}

impl Trainer {
    /// Build everything from a config: manifest (synthesized native
    /// when no artifacts are built), session, datasets, sampler — and
    /// initialize parameters.
    pub fn from_config(cfg: &TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
        Self::with_manifest(cfg, &manifest)
    }

    /// Same, with an explicit manifest (tests point this elsewhere).
    pub fn with_manifest(cfg: &TrainConfig, manifest: &Manifest) -> Result<Trainer> {
        cfg.validate()?;
        let flavour: Flavour = manifest.resolve_flavour(&cfg.flavour)?;
        let mut session = Session::new(manifest, &cfg.model, flavour)
            .with_context(|| format!("building session for model {}", cfg.model))?;
        session.init(cfg.seed as i32)?;
        let (train, test) = build_datasets(cfg)?;
        // dataset/model shape compatibility check up front
        if train.x_shape != session.entry().x_shape {
            anyhow::bail!(
                "dataset {} features {:?} incompatible with model {} ({:?})",
                cfg.dataset_name(),
                train.x_shape,
                cfg.model,
                session.entry().x_shape
            );
        }
        let sampler = cfg.method.build(cfg.gamma);
        let rng = super::selection_rng(cfg);
        let cache = if cfg.reuse_losses {
            let max_age = if cfg.loss_max_age > 0 {
                cfg.loss_max_age
            } else {
                // auto: two epochs' worth of steps — a shuffled epoch
                // mixes rows stamped across the whole previous epoch,
                // so a one-epoch window expires mid-epoch; two epochs
                // yields the intended refresh-every-other-pass cadence
                2 * train.len().div_ceil(manifest.batch) as u64
            };
            Some(crate::coordinator::loss_cache::LossCache::new(train.len(), max_age))
        } else {
            None
        };
        Ok(Trainer {
            cfg: cfg.clone(),
            session,
            sampler,
            train,
            test,
            rng,
            recorder: Recorder::new(),
            budget: BudgetTracker::new(),
            cache,
            step: 0,
            epoch: 0,
        })
    }

    /// `(hits, misses)` of the loss cache at batch granularity.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or((0, 0))
    }

    /// Full loss-cache counters (zeros when the cache is disabled).
    pub fn cache_counters(&self) -> crate::coordinator::CacheStats {
        self.cache.as_ref().map(|c| c.counters()).unwrap_or_default()
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    /// One Algorithm-1 iteration on a prepared batch.
    pub fn step_batch(&mut self, batch: &Batch) -> Result<StepRecord> {
        // (1) ten forward: per-example losses — from the cache when the
        // paper's inference-already-forwarded premise applies, else by
        // executing fwd_loss and recording into the cache
        let t0 = Instant::now();
        let cached = self
            .cache
            .as_mut()
            .and_then(|c| c.lookup_batch(&batch.ids, &batch.valid_mask, self.step));
        let losses = match cached {
            Some(l) => l,
            None => {
                let l = self.session.fwd_loss(&batch.x, &batch.y)?;
                if let Some(c) = self.cache.as_mut() {
                    c.record_batch(&batch.ids, &batch.valid_mask, &l, self.step);
                }
                self.budget.record_forward_executed(batch.real);
                l
            }
        };
        let fwd_us = t0.elapsed().as_micros() as u64;

        // (2) selection
        let t1 = Instant::now();
        let b = budget_for(self.cfg.sampling_ratio, batch.real);
        let selected =
            self.sampler
                .select(&losses, &batch.valid_mask, b, &mut self.rng);
        let mask = selection_mask(&selected, batch.batch_size());
        let sel_us = t1.elapsed().as_micros() as u64;

        // (3) one backward on the selection: gathered sub-batch by
        // default (O(b) backward), masked full batch when forced
        let t2 = Instant::now();
        let sel_loss = if self.cfg.masked_backward {
            self.session.train_step(&batch.x, &batch.y, &mask, self.cfg.lr)?
        } else {
            self.session
                .train_step_selected(&batch.x, &batch.y, &selected, self.cfg.lr)?
        };
        let bwd_us = t2.elapsed().as_micros() as u64;

        let batch_loss = super::masked_mean_loss(&losses, &batch.valid_mask);

        self.budget.record_step(batch.real, selected.len());
        let cache_counters = self.cache_counters();
        let rec = StepRecord {
            step: self.step,
            epoch: self.epoch,
            sel_loss,
            batch_loss,
            n_forward: batch.real,
            n_selected: selected.len(),
            fwd_us,
            sel_us,
            bwd_us,
            cache_hits: cache_counters.hits,
            cache_misses: cache_counters.misses,
            cache_stale: cache_counters.stale,
            sel_hash: crate::sampling::selection_hash(&selected),
            workers_alive: 0,
            worker_restarts: 0,
            frames_per_step: 0,
            publish_bytes: 0,
            reshards: 0,
            n_workers: 0,
            publish_us: 0,
            lookup_rtt_us: 0,
        };
        self.recorder.record_step(rec);
        self.step += 1;
        Ok(rec)
    }

    /// One epoch over the training set (shuffled).
    pub fn run_epoch(&mut self) -> Result<()> {
        let mut shuffle_rng = self.rng.split();
        let batch = self.session.batch();
        // collect batches eagerly to release the &self.train borrow
        let batches: Vec<Batch> =
            BatchIter::new(&self.train, batch, Some(&mut shuffle_rng)).collect();
        for b in &batches {
            self.step_batch(b)?;
        }
        self.epoch += 1;
        Ok(())
    }

    /// Full evaluation over the test split.
    pub fn evaluate(&mut self) -> Result<EvalResult> {
        let batches = self.test.batches(self.session.batch());
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        for b in &batches {
            let (l, m, c) = self.session.eval_batch(&b.x, &b.y, &b.valid_mask)?;
            sums.0 += l;
            sums.1 += m;
            sums.2 += c;
        }
        let count = sums.2.max(1.0);
        Ok(EvalResult { loss: sums.0 / count, metric: sums.1 / count })
    }

    fn maybe_checkpoint(&self) -> Result<()> {
        if let Some(path) = &self.cfg.checkpoint {
            self.save_checkpoint(Path::new(path))?;
        }
        Ok(())
    }

    /// Snapshot parameters + position to `path`.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let params = self.session.params_to_host()?;
        let named: Vec<(String, _)> = self
            .session
            .entry()
            .params
            .iter()
            .map(|p| p.name.clone())
            .zip(params)
            .collect();
        Checkpoint { step: self.step, epoch: self.epoch as u64, params: named }.save(path)
    }

    /// Restore parameters + position from `path`.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        let expected: Vec<&str> = self
            .session
            .entry()
            .params
            .iter()
            .map(|p| p.name.as_str())
            .collect();
        let got: Vec<&str> = ck.params.iter().map(|(n, _)| n.as_str()).collect();
        if expected != got {
            anyhow::bail!(
                "checkpoint params {got:?} do not match model {} ({expected:?})",
                self.cfg.model
            );
        }
        let tensors: Vec<_> = ck.params.into_iter().map(|(_, t)| t).collect();
        self.session.load_params(&tensors)?;
        self.step = ck.step;
        self.epoch = ck.epoch as usize;
        Ok(())
    }

    /// Run the configured number of epochs; eval per `eval_every`;
    /// checkpoint per epoch when configured.
    pub fn run(&mut self) -> Result<TrainReport> {
        for e in 0..self.cfg.epochs {
            self.run_epoch()?;
            let is_last = e + 1 == self.cfg.epochs;
            if is_last
                || (self.cfg.eval_every > 0 && (e + 1) % self.cfg.eval_every == 0)
            {
                let ev = self.evaluate()?;
                self.recorder.record_eval(EvalRecord {
                    step: self.step,
                    epoch: self.epoch,
                    loss: ev.loss,
                    metric: ev.metric,
                });
            }
            self.maybe_checkpoint()?;
        }
        self.report()
    }

    /// Assemble the report from recorded state (used by run() and the
    /// streaming/parallel drivers); writes the metrics CSVs when
    /// configured.
    pub fn report(&mut self) -> Result<TrainReport> {
        if let Some(out) = &self.cfg.metrics_out {
            let out = PathBuf::from(out);
            self.recorder.write_steps_csv(&out)?;
            let evals = out.with_extension("evals.csv");
            self.recorder.write_evals_csv(&evals)?;
        }
        let final_eval = match self.recorder.evals.last() {
            Some(e) => EvalResult { loss: e.loss, metric: e.metric },
            None => self.evaluate()?,
        };
        let (fwd, bwd) = self.recorder.totals();
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            method: self.cfg.method.as_str().to_string(),
            sampling_ratio: self.cfg.sampling_ratio,
            epochs: self.epoch,
            steps: self.step,
            final_eval,
            evals: self.recorder.evals.clone(),
            forward_examples: fwd,
            backward_examples: bwd,
            realized_ratio: self.budget.realized_ratio(),
            saved_fraction: self.budget.saved_fraction(),
            steps_per_sec: self.recorder.throughput(),
            latency_summary: self.recorder.latency_summary(),
        })
    }
}
