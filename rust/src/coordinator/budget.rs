//! Compute-budget accounting: the paper's "ten forward, one backward"
//! economics made observable.
//!
//! Every deployed instance gets a forward pass anyway (inference); the
//! scheme's win is the backward passes *not* run. A backward is ~2× a
//! forward for dense nets, so total cost ≈ fwd + 2·bwd (in
//! forward-equivalents) versus 3·fwd for full training.

/// Running totals of forwarded/backwarded examples.
#[derive(Clone, Copy, Debug, Default)]
pub struct BudgetTracker {
    pub forward_examples: u64,
    pub backward_examples: u64,
    /// Forwards the trainer actually *executed* (≤ `forward_examples`
    /// when the loss cache served the rest — the "inference already
    /// paid" discount).
    pub forward_executed: u64,
    /// Forwards executed by the *inference fleet* (the pipeline's
    /// worker pool). These are the paper's "already paid for" passes:
    /// they never count against the training budget, but tracking them
    /// makes the fleet's throughput observable.
    pub inference_forwards: u64,
    pub steps: u64,
}

impl BudgetTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&mut self, forward: usize, backward: usize) {
        self.forward_examples += forward as u64;
        self.backward_examples += backward as u64;
        self.steps += 1;
    }

    pub fn record_forward_executed(&mut self, n: usize) {
        self.forward_executed += n as u64;
    }

    pub fn record_inference_forwards(&mut self, n: u64) {
        self.inference_forwards += n;
    }

    /// Realized sampling ratio (backward / forward).
    pub fn realized_ratio(&self) -> f64 {
        if self.forward_examples == 0 {
            0.0
        } else {
            self.backward_examples as f64 / self.forward_examples as f64
        }
    }

    /// Training cost in forward-equivalents, assuming backward ≈ 2×
    /// forward: `fwd + 2·bwd`.
    pub fn cost_forward_equivalents(&self) -> u64 {
        self.forward_examples + 2 * self.backward_examples
    }

    /// Fraction of full-training cost saved: `1 − (f + 2b) / (3f)`.
    pub fn saved_fraction(&self) -> f64 {
        if self.forward_examples == 0 {
            return 0.0;
        }
        1.0 - self.cost_forward_equivalents() as f64 / (3.0 * self.forward_examples as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_and_savings() {
        let mut b = BudgetTracker::new();
        b.record_step(128, 32);
        b.record_step(128, 32);
        assert_eq!(b.steps, 2);
        assert!((b.realized_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(b.cost_forward_equivalents(), 256 + 128);
        // saved = 1 - (256+128)/(3·256) = 1 - 0.5 = 0.5
        assert!((b.saved_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_tracker_is_zero() {
        let b = BudgetTracker::new();
        assert_eq!(b.realized_ratio(), 0.0);
        assert_eq!(b.saved_fraction(), 0.0);
    }

    #[test]
    fn inference_forwards_never_count_against_training() {
        let mut b = BudgetTracker::new();
        b.record_step(128, 32);
        b.record_inference_forwards(4 * 128);
        assert_eq!(b.inference_forwards, 512);
        // training-side economics unchanged by fleet accounting
        assert_eq!(b.cost_forward_equivalents(), 128 + 64);
        assert!((b.realized_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn full_ratio_saves_nothing() {
        let mut b = BudgetTracker::new();
        b.record_step(100, 100);
        assert!(b.saved_fraction().abs() < 1e-12);
    }
}
