//! Transport abstraction for the staged pipeline's inference fleet.
//!
//! The pipeline leader (selection + training stages) talks to its
//! inference fleet and distributed loss cache exclusively through the
//! [`Transport`] trait:
//!
//! * [`InProcTransport`] — the fleet as scoped-ownership *threads*
//!   sharing one address space: a bounded ticket queue feeds N workers
//!   (each with a private [`Session`]), losses land in one lock-striped
//!   [`ShardedLossCache`], weights sync through a [`ParamStore`]. This
//!   is the PR-3 pipeline unchanged — the degenerate single-process
//!   case of the sharded-ownership protocol.
//! * [`FleetTransport`] — the fleet as *child processes* (`obftf
//!   worker`) speaking the typed frames of [`crate::coordinator::proto`]
//!   over a per-worker [`WorkerEndpoint`]: stdin/stdout pipes, a
//!   Unix-domain socket, or loopback TCP ([`LinkMode`]). Each worker
//!   **owns** the loss-cache shards `id % n_workers == worker_id`: it
//!   records its own scores locally, receives routed rows for ids it
//!   owns when another worker scored them, and serves the leader's
//!   `CacheLookup` fan-outs. The leader holds no loss state at all —
//!   freshness classification runs over merged `CacheView`s, under the
//!   same rules as the in-memory cache (`exact` stamp in sync mode,
//!   `max_age` window otherwise).
//!
//! Every endpoint handshakes: the worker's first frame is a
//! version-checked `Hello`, awaited under the fleet timeout, so a wrong
//! binary or a hung listener fails with a contextual error naming the
//! endpoint. A dedicated reader thread per worker turns link EOF or a
//! decode error into a generation-tagged `Dead` event.
//!
//! Failure policy is *supervised restart* (`restart_limit` relaunches
//! allowed; 0 = strict fail-fast): a dead worker is respawned, its
//! replacement handshakes, receives the current weights, has its
//! loss-cache shard re-warmed from the leader's routed-row journal
//! (every `LossRecords` reply passes the leader, which is the routing
//! hop), and gets its in-flight `ScoreBatch` work re-issued. Deaths
//! beyond the budget — or during shutdown — surface as a contextual
//! error (worker id, endpoint, child exit status, last frame sent)
//! instead of a hang. `worker_restarts` counts the relaunches.
//!
//! `ScoreBatch` routing is shard-owner **affinity** by default: a batch
//! goes to the alive worker owning the most of its ids (ties to the
//! lowest index), which cuts the routed-`LossRecords` share of
//! `frame_bytes_per_step`; `affinity = false` restores round-robin.
//!
//! The worker *count* itself is elastic: the leader can admit a late
//! worker (a `Join` handshake instead of `Hello`) or retire a
//! permanently-dead one (restart budget exhausted, fleet still above
//! the `min_workers` floor — demote instead of abort). Either way the
//! fleet **reshards**: the leader quiesces in-flight scoring, re-keys
//! its routed-row journal to the new shard count, broadcasts an
//! epoch-tagged `Reshard` ownership map, and migrates each shard's rows
//! to its new owner as `ShardTransfer` frames (exact stamps, not
//! counted as recorded rows). Ownership is positional: shard `k` of the
//! map covers `id % members.len() == k` and belongs to `members[k]`. A
//! lookup fan-out spanning the transition classifies as `Retry` under
//! the same epoch guard that covers restarts, so freshness accounting
//! never mixes ownership maps.
//!
//! [`Session`]: crate::runtime::Session

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::endpoint::{EndpointSpawner, LinkMode, WorkerEndpoint};
use crate::coordinator::loss_cache::{
    is_fresh, CacheProbe, CacheStats, LossCache, ShardedLossCache, NEVER,
};
use crate::coordinator::proto::{self, Frame, FramePools, ViewRow, WorkerStats, NO_ID};
use crate::data::dataset::Batch;
use crate::data::tensor::{bf16_to_f32, f32_to_bf16, TensorData};
use crate::data::HostTensor;
use crate::runtime::{Flavour, Manifest, ScorePrecision, Session};

/// Upper bound on how long the leader waits for fleet progress before
/// declaring the pipeline wedged (overridable per-transport via spec).
pub const STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Versioned weight snapshot the training stage publishes and the
/// in-process inference workers sync from. Version = number of applies
/// performed, which is also the staleness stamp written into the loss
/// cache. (In proc mode the same publish crosses the process boundary
/// as a `ParamUpdate` frame instead.)
pub struct ParamStore {
    inner: Mutex<(u64, Arc<Vec<HostTensor>>)>,
}

impl ParamStore {
    pub fn new(initial: Arc<Vec<HostTensor>>) -> Self {
        ParamStore { inner: Mutex::new((0, initial)) }
    }

    pub fn latest(&self) -> (u64, Arc<Vec<HostTensor>>) {
        let g = self.inner.lock().expect("param store lock");
        (g.0, g.1.clone())
    }

    pub fn publish(&self, version: u64, params: Arc<Vec<HostTensor>>) {
        *self.inner.lock().expect("param store lock") = (version, params);
    }
}

/// Wire-path accounting for the fleet transport: frame count, encode
/// time, and a per-frame-type byte split of leader→worker traffic
/// (replies are counted in `frame_bytes` only). Feeds the bench rows
/// and the per-step `frames_per_step` / `publish_bytes` telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Leader→worker frames written (an envelope counts as one).
    pub frames: u64,
    /// Nanoseconds spent encoding frames (not writing them).
    pub encode_ns: u64,
    /// `ParamUpdate` broadcast bytes.
    pub param_bytes: u64,
    /// `ScoreBatch` bytes.
    pub score_bytes: u64,
    /// Standalone routed-`LossRecords` bytes (shutdown flushes,
    /// restart re-warm).
    pub route_bytes: u64,
    /// Standalone `CacheLookup` bytes.
    pub lookup_bytes: u64,
    /// Coalesced `Batch` envelope bytes (routes + lookup per worker).
    pub envelope_bytes: u64,
    /// Everything else (`Shutdown`, …).
    pub other_bytes: u64,
}

/// End-of-run aggregate the leader absorbs at [`Transport::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct FleetSummary {
    /// Final per-worker counters (proc mode: from `WorkerStats` frames).
    pub workers: Vec<WorkerStats>,
    /// Workers alive when shutdown began.
    pub workers_alive: usize,
    /// Workers relaunched mid-run by the supervised-restart policy.
    pub restarts: u64,
    /// Reshard transitions performed mid-run (joins + permanent leaves).
    pub reshards: u64,
    /// Aggregate lookup-granularity cache counters.
    pub cache: CacheStats,
    /// Row-granularity counters per shard (proc mode: shard == worker).
    pub shard_rows: Vec<CacheStats>,
    /// Total real rows forwarded by the fleet (requeues included).
    pub fleet_rows: u64,
    /// Total wire bytes, both directions (in-proc: 0).
    pub frame_bytes: u64,
    /// Leader→worker wire-path accounting (in-proc: all zero).
    pub wire: WireStats,
}

/// The pipeline leader's view of its inference fleet + loss cache.
///
/// `now` is the current parameter version; in sync mode
/// [`Transport::await_losses`] only accepts losses stamped exactly
/// `now` (the bit-identical oracle rule), otherwise the transport's
/// `max_age` window applies and fully-scored-but-stale batches are
/// re-submitted for re-scoring.
pub trait Transport {
    fn n_workers(&self) -> usize;
    /// Broadcast new weights to the fleet (version = staleness stamp).
    fn publish(&mut self, version: u64, weights: &Arc<Vec<HostTensor>>) -> Result<()>;
    /// Enqueue a batch for scoring.
    fn submit(&mut self, batch: &Arc<Batch>) -> Result<()>;
    /// Block until the losses for `batch` satisfy the freshness rule.
    fn await_losses(&mut self, batch: &Arc<Batch>, now: u64) -> Result<Vec<f32>>;
    /// Aggregate lookup-granularity counters so far.
    fn cache_stats(&self) -> CacheStats;
    /// Workers currently alive.
    fn workers_alive(&self) -> usize;
    /// Per-worker scored-batch counts so far.
    fn worker_scored(&self) -> Vec<u64>;
    /// Workers relaunched so far by the supervised-restart policy
    /// (0 for transports that cannot restart).
    fn restarts(&self) -> u64 {
        0
    }
    /// Reshard transitions performed so far — worker joins plus
    /// permanent leaves (0 for transports with a fixed worker count).
    fn reshards(&self) -> u64 {
        0
    }
    /// Entries evicted so far by the bounded loss-cache/journal policy
    /// (0 when unbounded or the transport keeps no such state).
    fn evictions(&self) -> u64 {
        0
    }
    /// Admit one late worker into the fleet (spawn + `Join` handshake +
    /// reshard). Only the multi-process fleet supports this.
    fn admit_worker(&mut self) -> Result<()> {
        bail!("this transport does not support admitting workers mid-run")
    }
    /// Wire traffic so far in bytes (0 for in-process transports).
    fn frame_bytes(&self) -> u64 {
        0
    }
    /// Leader→worker wire-path accounting so far (frames, encode time,
    /// per-frame-type byte split; all zero for in-process transports).
    fn wire_stats(&self) -> WireStats {
        WireStats::default()
    }
    /// Overlapped-leader hook: issue the *next* step's lookup as soon
    /// as the current step's backward starts, so its round trip hides
    /// behind compute the leader was going to do anyway. Freshness is
    /// still classified at use time, inside [`Transport::await_losses`],
    /// under the normal `max_age`/epoch-retry rules — the prefetch only
    /// moves the fan-out, never the decision. Default: no-op (serial
    /// transports, or overlap off).
    fn prefetch(&mut self, _batch: &Arc<Batch>, _now: u64) -> Result<()> {
        Ok(())
    }
    /// Issue-to-merge round-trip time (µs) of the lookup fan-out that
    /// most recently completed a collect. Under prefetch the clock
    /// starts during the previous step's backward, so this reports the
    /// *hidden* latency (0 for transports without a wire).
    fn lookup_rtt_us(&self) -> u64 {
        0
    }
    /// Wall time (µs) of the most recent completed parameter broadcast:
    /// the serial write loop, or — under the overlapped leader — the
    /// slowest writer thread's write of the shared `ParamUpdate` buffer
    /// (0 for transports without a wire).
    fn publish_us(&self) -> u64 {
        0
    }
    /// Graceful shutdown: drain the fleet, join/reap workers, surface
    /// any failure that raced the leader's last check.
    fn shutdown(&mut self) -> Result<FleetSummary>;
}

// ---------------------------------------------------------------------------
// In-process transport (threads + shared sharded cache)
// ---------------------------------------------------------------------------

/// A unit of inference work: score `batch` and record the losses.
struct Ticket {
    batch: Arc<Batch>,
}

type SharedTickets = Arc<Mutex<mpsc::Receiver<Ticket>>>;

/// Construction parameters for [`InProcTransport::spawn`].
pub struct InProcSpec {
    pub manifest: Manifest,
    pub model: String,
    pub flavour: Flavour,
    pub workers: usize,
    pub capacity: usize,
    pub max_age: u64,
    pub shards: usize,
    /// Loss-cache entry bound (0 = unbounded): oldest-stamp-first
    /// eviction keeps the live entry count under this across a long
    /// stream of distinct ids. Async-only (sync mode rejects it).
    pub max_entries: u64,
    pub sync: bool,
    /// Ticket-queue bound (lookahead depth + workers + slack).
    pub queue_cap: usize,
    pub stall: Duration,
    /// Scoring-forward precision for the fleet's `fwd_loss` calls
    /// (training never sees it — the fleet only scores).
    pub score_precision: ScorePrecision,
    /// Param-broadcast precision. bf16 round-trips the published
    /// snapshot through the wire rounding even in-process, so the
    /// pipeline's scoring semantics are transport-invariant.
    pub param_precision: ScorePrecision,
    /// Overlapped-leader mode: [`Transport::prefetch`] runs the step's
    /// counting lookup early (against prefetch-time cache state, the
    /// shared-memory analogue of the socket fleet's prefetched views)
    /// and parks the classification for `await_losses`. Async-only.
    pub overlap: bool,
}

/// The PR-3 thread fleet behind the [`Transport`] trait.
pub struct InProcTransport {
    cache: Arc<ShardedLossCache>,
    params: Arc<ParamStore>,
    tickets: Option<mpsc::SyncSender<Ticket>>,
    err: Arc<Mutex<Option<String>>>,
    scored_batches: Arc<Vec<AtomicU64>>,
    scored_rows: Arc<Vec<AtomicU64>>,
    handles: Vec<JoinHandle<()>>,
    sync: bool,
    stall: Duration,
    param_precision: ScorePrecision,
    overlap: bool,
    /// Parked prefetch result: `(now, counted lookup outcome)`. The
    /// counting `lookup_batch` already ran at prefetch time, so the
    /// await consumes this instead of counting again.
    prefetched: Option<(u64, Option<Vec<f32>>)>,
}

impl InProcTransport {
    /// Spawn the worker threads; each builds its [`Session`] on its own
    /// thread (backends may hold non-`Send` handles).
    ///
    /// [`Session`]: crate::runtime::Session
    pub fn spawn(spec: InProcSpec) -> Result<InProcTransport> {
        let cache = Arc::new(ShardedLossCache::with_max_entries(
            spec.capacity,
            spec.max_age,
            spec.shards,
            spec.max_entries,
        ));
        let params = Arc::new(ParamStore::new(Arc::new(Vec::new())));
        let err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let scored_batches: Arc<Vec<AtomicU64>> =
            Arc::new((0..spec.workers).map(|_| AtomicU64::new(0)).collect());
        let scored_rows: Arc<Vec<AtomicU64>> =
            Arc::new((0..spec.workers).map(|_| AtomicU64::new(0)).collect());
        let (ticket_tx, ticket_rx) = mpsc::sync_channel::<Ticket>(spec.queue_cap);
        let ticket_rx: SharedTickets = Arc::new(Mutex::new(ticket_rx));
        let mut handles = Vec::with_capacity(spec.workers);
        for w in 0..spec.workers {
            let ctx = WorkerCtx {
                manifest: spec.manifest.clone(),
                model: spec.model.clone(),
                flavour: spec.flavour,
                score_precision: spec.score_precision,
                index: w,
                tickets: ticket_rx.clone(),
                cache: cache.clone(),
                params: params.clone(),
                scored_batches: scored_batches.clone(),
                scored_rows: scored_rows.clone(),
                err: err.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("obftf-infer-{w}"))
                    .spawn(move || inference_worker(ctx))
                    .context("spawn inference worker")?,
            );
        }
        Ok(InProcTransport {
            cache,
            params,
            tickets: Some(ticket_tx),
            err,
            scored_batches,
            scored_rows,
            handles,
            sync: spec.sync,
            stall: spec.stall,
            param_precision: spec.param_precision,
            overlap: spec.overlap,
            prefetched: None,
        })
    }

    /// Live shard counters (the trait only exposes them via summary).
    pub fn shard_stats(&self, shard: usize) -> CacheStats {
        self.cache.shard_stats(shard)
    }

    fn check_err(&self) -> Result<()> {
        if let Some(e) = self.err.lock().expect("err slot").take() {
            bail!("pipeline inference stage failed: {e}");
        }
        Ok(())
    }

    fn check_stall(&self, now: u64, since: Instant) -> Result<()> {
        if since.elapsed() > self.stall {
            bail!(
                "pipeline stalled: step {now} waited {:?} for losses (cache stats {:?})",
                self.stall,
                self.cache.stats()
            );
        }
        Ok(())
    }

    /// Non-blocking ticket send with worker-death detection (a plain
    /// blocking send could deadlock against a dead fleet).
    fn send_ticket(&self, mut ticket: Ticket) -> Result<()> {
        let Some(tickets) = self.tickets.as_ref() else {
            bail!("pipeline inference stage already shut down");
        };
        loop {
            match tickets.try_send(ticket) {
                Ok(()) => return Ok(()),
                Err(mpsc::TrySendError::Full(back)) => {
                    self.check_err()?;
                    ticket = back;
                    std::thread::sleep(Duration::from_micros(50));
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.check_err()?;
                    bail!("pipeline inference stage terminated unexpectedly");
                }
            }
        }
    }

    /// Non-counting poll until the batch classifies fresh: requeue a
    /// fully-scored-but-stale batch once per staleness watermark so a
    /// worker re-scores it with current weights. (The counting lookup
    /// has already happened — at await entry, or at prefetch time.)
    fn probe_loop(&mut self, batch: &Arc<Batch>, now: u64, t0: Instant) -> Result<Vec<f32>> {
        let mut requeued_for: Option<u64> = None;
        loop {
            self.check_err()?;
            match self.cache.probe_batch(&batch.ids, &batch.valid_mask, now) {
                CacheProbe::Fresh(l) => return Ok(l),
                CacheProbe::Stale { min_stamp } => {
                    if requeued_for != Some(min_stamp) {
                        self.send_ticket(Ticket { batch: batch.clone() })?;
                        requeued_for = Some(min_stamp);
                    }
                }
                CacheProbe::Incomplete => {}
            }
            self.check_stall(now, t0)?;
            std::thread::sleep(Duration::from_micros(30));
        }
    }

    fn summary(&self, workers_alive: usize) -> FleetSummary {
        let workers = (0..self.scored_batches.len())
            .map(|w| WorkerStats {
                worker: w as u32,
                scored_batches: self.scored_batches[w].load(Ordering::Relaxed),
                scored_rows: self.scored_rows[w].load(Ordering::Relaxed),
                recorded_rows: self.scored_rows[w].load(Ordering::Relaxed),
                lookups: 0,
            })
            .collect();
        FleetSummary {
            workers,
            workers_alive,
            restarts: 0,
            reshards: 0,
            cache: self.cache.stats(),
            shard_rows: (0..self.cache.n_shards()).map(|k| self.cache.shard_stats(k)).collect(),
            fleet_rows: self.fleet_rows_now(),
            frame_bytes: 0,
            wire: WireStats::default(),
        }
    }

    fn fleet_rows_now(&self) -> u64 {
        self.scored_rows.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

impl Transport for InProcTransport {
    fn n_workers(&self) -> usize {
        self.scored_batches.len()
    }

    fn publish(&mut self, version: u64, weights: &Arc<Vec<HostTensor>>) -> Result<()> {
        let snapshot = match self.param_precision {
            ScorePrecision::F32 => weights.clone(),
            // mirror the wire contract: the fleet scores against the
            // bf16-rounded snapshot exactly as a socket worker would
            // expand it on receipt
            ScorePrecision::Bf16 => Arc::new(
                weights
                    .iter()
                    .map(|t| match &t.data {
                        TensorData::F32(v) => HostTensor {
                            shape: t.shape.clone(),
                            data: TensorData::F32(
                                v.iter().map(|&x| bf16_to_f32(f32_to_bf16(x))).collect(),
                            ),
                        },
                        _ => t.clone(),
                    })
                    .collect(),
            ),
        };
        self.params.publish(version, snapshot);
        Ok(())
    }

    fn submit(&mut self, batch: &Arc<Batch>) -> Result<()> {
        self.send_ticket(Ticket { batch: batch.clone() })
    }

    /// The selection stage's handoff.
    ///
    /// Async mode: first a *counting* lookup (the hit/miss statistic
    /// answers "were the losses ready when selection wanted them?"),
    /// then non-counting polls; fully-scored-but-stale batches are
    /// re-enqueued once per staleness watermark so a worker re-scores
    /// them with current weights.
    ///
    /// Sync mode: poll the exact-stamp probe — only losses computed
    /// under the *current* parameter version (stamp == now) are
    /// accepted, which is what makes the oracle mode bit-identical to
    /// the serial trainer.
    fn await_losses(&mut self, batch: &Arc<Batch>, now: u64) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        if self.sync {
            loop {
                self.check_err()?;
                if let Some(l) = self.cache.probe_stamped(&batch.ids, &batch.valid_mask, now) {
                    return Ok(l);
                }
                self.check_stall(now, t0)?;
                std::thread::sleep(Duration::from_micros(30));
            }
        }
        // overlap mode: a parked prefetch already ran this step's
        // counting lookup (against prefetch-time cache state, mirroring
        // the socket fleet's prefetched views) — a parked hit returns
        // directly, a parked miss skips straight to the probe loop
        if let Some((pnow, parked)) = self.prefetched.take() {
            if pnow == now {
                if let Some(l) = parked {
                    return Ok(l);
                }
                return self.probe_loop(batch, now, t0);
            }
        }
        if let Some(l) = self.cache.lookup_batch(&batch.ids, &batch.valid_mask, now) {
            return Ok(l);
        }
        self.probe_loop(batch, now, t0)
    }

    /// Shared-memory prefetch analogue: run the step's counting lookup
    /// now, while the leader's backward still has the previous step in
    /// flight, and park the outcome for `await_losses`. One counted
    /// lookup per step either way — the overlap knob moves *when* it
    /// runs, never how often.
    fn prefetch(&mut self, batch: &Arc<Batch>, now: u64) -> Result<()> {
        if !self.overlap || self.sync {
            return Ok(());
        }
        self.check_err()?;
        let parked = self.cache.lookup_batch(&batch.ids, &batch.valid_mask, now);
        self.prefetched = Some((now, parked));
        Ok(())
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Worker threads that have not exited. A healthy worker lives
    /// until the ticket queue closes; one that hit an error (recorded
    /// in the err slot) exits early and stops counting here.
    fn workers_alive(&self) -> usize {
        self.handles.iter().filter(|h| !h.is_finished()).count()
    }

    fn worker_scored(&self) -> Vec<u64> {
        self.scored_batches.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    fn evictions(&self) -> u64 {
        self.cache.evictions()
    }

    fn shutdown(&mut self) -> Result<FleetSummary> {
        let alive_at_entry = self.workers_alive();
        // close the ticket queue so workers drain and exit, then join
        self.tickets.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // a worker may have failed after the leader's last check (e.g.
        // on a leftover requeued ticket) — surface it rather than
        // reporting a silently-degraded run
        if let Some(e) = self.err.lock().expect("err slot").take() {
            bail!("pipeline stage failed during shutdown: {e}");
        }
        Ok(self.summary(alive_at_entry))
    }
}

impl Drop for InProcTransport {
    fn drop(&mut self) {
        self.tickets.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Everything an in-process inference worker owns (built before its
/// thread starts; the `Session` itself is constructed *inside* the
/// thread because backends may hold non-`Send` handles).
struct WorkerCtx {
    manifest: Manifest,
    model: String,
    flavour: Flavour,
    score_precision: ScorePrecision,
    index: usize,
    tickets: SharedTickets,
    cache: Arc<ShardedLossCache>,
    params: Arc<ParamStore>,
    scored_batches: Arc<Vec<AtomicU64>>,
    scored_rows: Arc<Vec<AtomicU64>>,
    err: Arc<Mutex<Option<String>>>,
}

fn record_failure(err: &Mutex<Option<String>>, stage: &str, e: anyhow::Error) {
    let mut slot = err.lock().expect("err slot");
    if slot.is_none() {
        *slot = Some(format!("{stage}: {e:#}"));
    }
}

/// In-process inference worker: drain tickets, sync weights from the
/// [`ParamStore`], run `fwd_loss`, record into the sharded cache with
/// the parameter version as the staleness stamp.
fn inference_worker(ctx: WorkerCtx) {
    let mut session = match Session::new(&ctx.manifest, &ctx.model, ctx.flavour) {
        Ok(s) => s,
        Err(e) => return record_failure(&ctx.err, "inference worker (session build)", e),
    };
    session.set_score_precision(ctx.score_precision);
    let mut loaded_version = u64::MAX;
    loop {
        let msg = ctx.tickets.lock().expect("ticket queue").recv();
        let Ok(Ticket { batch }) = msg else {
            return; // leader closed the queue: clean shutdown
        };
        let (version, p) = ctx.params.latest();
        if version != loaded_version {
            if let Err(e) = session.load_params(&p) {
                return record_failure(&ctx.err, "inference worker (weight sync)", e);
            }
            loaded_version = version;
        }
        match session.fwd_loss(&batch.x, &batch.y) {
            Ok(losses) => {
                ctx.cache
                    .record_batch(&batch.ids, &batch.valid_mask, &losses, loaded_version);
                ctx.scored_batches[ctx.index].fetch_add(1, Ordering::Relaxed);
                ctx.scored_rows[ctx.index].fetch_add(batch.real as u64, Ordering::Relaxed);
            }
            Err(e) => return record_failure(&ctx.err, "inference worker (fwd_loss)", e),
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-process fleet transport (child workers over pipes or sockets)
// ---------------------------------------------------------------------------

/// Construction parameters for [`FleetTransport::spawn`].
pub struct FleetSpec {
    pub model: String,
    pub flavour: Flavour,
    pub workers: usize,
    pub capacity: usize,
    pub max_age: u64,
    pub sync: bool,
    /// Scoring-forward precision the children run (`--score-precision`).
    pub score_precision: ScorePrecision,
    /// Param-broadcast precision: bf16 RNE-rounds the published
    /// snapshot once into a half-size `ParamUpdate`; workers detect the
    /// wire dtype and expand to f32 on receipt (no worker flag).
    pub param_precision: ScorePrecision,
    /// Worker binary; `None` resolves `$OBFTF_WORKER_BIN`, then the
    /// current executable (correct when the leader *is* `obftf`).
    pub worker_bin: Option<PathBuf>,
    /// Leader-side recv timeout — also bounds spawn, socket connect and
    /// the Hello handshake (stall + liveness bound).
    pub timeout: Duration,
    /// Test-only fault injection: worker `w` crashes (exit 17, no
    /// handshake) after handling `fail_after[w]` frames. Never
    /// re-injected into supervised-restart replacements.
    pub fail_after: Vec<Option<u64>>,
    /// How frames travel: stdio pipes, Unix socket, or loopback TCP.
    pub link: LinkMode,
    /// Shard-owner affinity routing for `ScoreBatch` (false =
    /// round-robin).
    pub affinity: bool,
    /// Supervised restarts allowed across the fleet before a worker
    /// death becomes fatal (0 = strict fail-fast).
    pub restart_limit: u32,
    /// Fleet-size floor for the elastic policy: a worker death beyond
    /// the restart budget retires the worker (permanent leave +
    /// reshard) instead of aborting, as long as the fleet stays at or
    /// above this count. At the floor, such a death is fatal.
    pub min_workers: usize,
    /// Leader-journal entry bound (0 = unbounded): oldest-stamp-first
    /// eviction keeps the routed-row journal under this across a long
    /// stream of distinct ids. Async-only (sync mode rejects it).
    pub max_entries: u64,
    /// Overlapped-leader mode: per-endpoint writer threads fan the
    /// param broadcast out over every link concurrently, and
    /// [`Transport::prefetch`] issues the next step's lookup during the
    /// current backward. Async-only (sync mode rejects it at resolve).
    pub overlap: bool,
}

/// Test-only fault injection via the environment:
/// `OBFTF_PROC_FAIL_AFTER="<worker>:<frames>"` makes that worker crash
/// after handling that many frames. Returns an empty vector (no
/// faults) when unset or malformed, so production paths cost nothing.
pub fn fail_after_from_env(workers: usize) -> Vec<Option<u64>> {
    let Ok(v) = std::env::var("OBFTF_PROC_FAIL_AFTER") else {
        return Vec::new();
    };
    let mut out = vec![None; workers];
    if let Some((w, k)) = v.split_once(':') {
        if let (Ok(w), Ok(k)) = (w.trim().parse::<usize>(), k.trim().parse::<u64>()) {
            if w < workers {
                out[w] = Some(k);
            }
        }
    }
    out
}

impl FleetSpec {
    fn resolve_bin(&self) -> Result<PathBuf> {
        if let Some(p) = &self.worker_bin {
            return Ok(p.clone());
        }
        if let Ok(p) = std::env::var("OBFTF_WORKER_BIN") {
            return Ok(PathBuf::from(p));
        }
        std::env::current_exe().context("locating worker binary (current_exe)")
    }
}

/// Fleet events are generation-tagged so a dead incarnation's trailing
/// frames or EOF cannot be attributed to its restarted successor.
enum Event {
    Frame(usize, u64, Frame),
    Dead(usize, u64, String),
}

/// One worker's live state: its endpoint (process + write half), the
/// reader thread draining its read half, and handshake/liveness flags.
struct Slot {
    ep: WorkerEndpoint,
    reader: Option<JoinHandle<()>>,
    alive: bool,
    /// Version-checked `Hello` received from this incarnation.
    hello: bool,
    last_sent: &'static str,
}

/// Bound on each writer thread's outbox (overlap mode). Deep enough
/// that a steady-state step (params + routes/lookup envelope) never
/// blocks; shallow enough that a wedged link exerts backpressure
/// instead of buffering unboundedly.
const OUTBOX_CAP: usize = 64;

/// One pre-encoded frame queued to a writer thread. The param
/// broadcast shares a single encoded buffer across the whole fleet via
/// `Arc`; every other frame carries its own copy.
struct WriteJob {
    buf: JobBuf,
    name: &'static str,
}

enum JobBuf {
    Shared(Arc<Vec<u8>>),
    Owned(Vec<u8>),
}

impl WriteJob {
    fn bytes(&self) -> &[u8] {
        match &self.buf {
            JobBuf::Shared(b) => b,
            JobBuf::Owned(b) => b,
        }
    }
}

/// One worker's dedicated writer thread (overlap mode): a bounded
/// outbox drained FIFO onto the endpoint's write half, so the param
/// broadcast — and every other leader→worker frame — goes out over all
/// links concurrently instead of one socket at a time. Per-connection
/// frame order is exactly the enqueue order, which is exactly the
/// order the serial path would have written.
struct Writer {
    tx: mpsc::SyncSender<WriteJob>,
    handle: JoinHandle<()>,
    /// write_all nanoseconds of the most recent `ParamUpdate` this
    /// writer completed (the fleet's publish_us = slowest writer).
    publish_ns: Arc<AtomicU64>,
}

impl Writer {
    /// Close the outbox and join the thread. Jobs still queued for a
    /// dead incarnation are dropped by the drain-and-discard loop —
    /// the outbox analogue of dropping a dead reader's stale events.
    fn join(self) {
        let Writer { tx, handle, .. } = self;
        drop(tx);
        let _ = handle.join();
    }
}

/// Writer-thread body: drain the outbox onto the write half. A write
/// error surfaces as a generation-tagged [`Event::Dead`] — the same
/// path a reader-side EOF takes — after which the thread keeps
/// draining and *discarding* jobs, so the leader can never block on a
/// dead worker's outbox. The endpoint write halves are unbuffered
/// (raw pipe / socket clones), so no flush step is needed here.
fn writer_loop(
    mut out: Box<dyn Write + Send>,
    rx: mpsc::Receiver<WriteJob>,
    w: usize,
    generation: u64,
    tx: mpsc::Sender<Event>,
    publish_ns: Arc<AtomicU64>,
) {
    let mut dead = false;
    while let Ok(job) = rx.recv() {
        if dead {
            continue;
        }
        let t0 = Instant::now();
        match out.write_all(job.bytes()) {
            Ok(()) => {
                if job.name == "ParamUpdate" {
                    publish_ns.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            }
            Err(e) => {
                dead = true;
                let _ = tx.send(Event::Dead(
                    w,
                    generation,
                    format!("write of {} frame failed: {e}", job.name),
                ));
            }
        }
    }
    // rx disconnected: the leader dropped the outbox (restart, retire
    // or shutdown); dropping `out` closes the stream's write half
}

/// An issued-but-uncollected `CacheLookup` fan-out (overlap mode):
/// step s+1's lookup goes out as soon as step s's backward starts, its
/// views park in `pending_views` as they arrive, and the merge +
/// freshness classification run at use time under use-time rules.
struct Prefetch {
    req: u64,
    now: u64,
    /// `restart_epoch` at issue: a bump since voids the fan-out (the
    /// replacement worker never saw the request / the ownership map
    /// changed), exactly like the mid-collect epoch guard.
    epoch: u64,
    issued: Instant,
}

/// The multi-process fleet: `obftf worker` children (pipes or sockets)
/// with distributed loss-cache shard ownership (`id % n_workers`) and
/// supervised restart.
pub struct FleetTransport {
    spawner: EndpointSpawner,
    slots: Vec<Slot>,
    events: mpsc::Receiver<Event>,
    /// Kept alive so restarted workers' reader threads can attach; the
    /// event channel never disconnects while the transport lives.
    event_tx: mpsc::Sender<Event>,
    sync: bool,
    max_age: u64,
    timeout: Duration,
    affinity: bool,
    restart_limit: u32,
    /// Fleet-size floor: retire-don't-abort only applies above it.
    min_workers: usize,
    /// Slot ids currently in the ownership map, ascending. Shard `k`
    /// covers `id % active.len() == k` and belongs to `active[k]`; a
    /// join appends a slot, a permanent leave removes one, and either
    /// transition reshards.
    active: Vec<usize>,
    /// Supervised restarts performed so far.
    restarts: u64,
    /// Reshard transitions performed so far (joins + permanent
    /// leaves); doubles as the wire `Reshard` epoch.
    reshard_count: u64,
    /// Bumped on every restart *and* reshard; an in-flight
    /// `CacheLookup` collect aborts (and re-issues) when it observes a
    /// bump, since the old fan-out can no longer be answered (replaced
    /// worker) or classified (changed ownership map).
    restart_epoch: u64,
    /// Per-shard journal of every routed/recorded row the leader has
    /// seen (`id → (loss, stamp)`, newest stamp wins) — the re-warm
    /// source for a restarted owner's shard and the migration source
    /// for a reshard. Indexed by shard *position* `0..active.len()`,
    /// re-keyed on every reshard.
    journal: Vec<HashMap<u64, (f32, u64)>>,
    /// Journal entry bound (0 = unbounded) with oldest-stamp-first
    /// eviction; `journal_entries` is the live count across shards.
    max_entries: u64,
    journal_entries: u64,
    /// Journal entries evicted so far by the bound.
    evictions: u64,
    /// In-flight `ScoreBatch` work: `seq → (worker, batch)`, retired by
    /// the matching `LossRecords` reply, re-issued on restart.
    outstanding: BTreeMap<u64, (usize, Arc<Batch>)>,
    /// Last published `ParamUpdate`, pre-encoded once per publish and
    /// broadcast to every worker from this one buffer (empty = never
    /// published); also the restart republish source.
    last_params: Vec<u8>,
    /// Param-broadcast precision (`encode_param_update_into` dtype).
    param_precision: ScorePrecision,
    /// Reusable frame-encode scratch — the steady-state write path
    /// allocates nothing once this is warm.
    enc_buf: Vec<u8>,
    /// Reusable wire-id scratch for `lookup_once`.
    lookup_ids: Vec<u64>,
    /// Reusable per-row merge scratch for `lookup_once` (the PR-8
    /// "leader merge vectors" residual: warm lookups allocate only the
    /// returned losses).
    per_row: Vec<Option<(f32, u64)>>,
    /// Reusable per-shard stats scratch for `lookup_once`.
    per_shard: Vec<CacheStats>,
    /// Decode-side payload pools shared with the reader threads: they
    /// decode frames out of the pools (under a short lock, never held
    /// across a blocking read) and the leader recycles consumed payload
    /// vectors back, so warm steady-state decodes allocate nothing.
    pools: Arc<Mutex<FramePools>>,
    /// Routed `LossRecords` deferred per owner; they coalesce into the
    /// next selection-time envelope instead of going out as one write
    /// per scorer per owner.
    pending_routes: Vec<Vec<Route>>,
    /// Recycled `Route` buffers (ids/losses capacity stays warm).
    route_pool: Vec<Route>,
    /// Leader→worker wire accounting.
    wire: WireStats,
    next_seq: u64,
    next_req: u64,
    cur_req: u64,
    pending_views: Vec<Option<Vec<ViewRow>>>,
    agg: CacheStats,
    shard_rows: Vec<CacheStats>,
    scored: Vec<u64>,
    fleet_rows: u64,
    bytes_out: u64,
    bytes_in: Arc<AtomicU64>,
    final_stats: Vec<Option<WorkerStats>>,
    shutting_down: bool,
    /// Set whenever a `LossRecords` frame lands (new rows recorded /
    /// routed) — tells `await_losses` a re-lookup can make progress
    /// without waiting for another event. Routing itself produces no
    /// reply frame, so without this the leader could block on an event
    /// that never comes after the routed rows already satisfied it.
    progress: bool,
    /// Overlapped-leader mode: writer threads + lookup prefetch.
    overlap: bool,
    /// Per-slot writer threads (overlap mode only; `None` per slot
    /// otherwise). Torn down and respawned with the slot, exactly like
    /// the reader threads.
    writers: Vec<Option<Writer>>,
    /// Overlap-mode twin of `last_params`: the broadcast buffer shared
    /// by `Arc` across every writer thread, reclaimed for reuse at the
    /// next publish once the last writer has dropped its handle.
    last_params_shared: Option<Arc<Vec<u8>>>,
    /// The in-flight prefetched lookup, if any (overlap mode).
    prefetched: Option<Prefetch>,
    /// Wall time of the most recent serial-path param broadcast.
    last_publish_ns: u64,
    /// Issue-to-merge RTT of the most recent completed lookup collect.
    last_lookup_rtt_ns: u64,
}

/// One deferred routed-rows write (scorer → shard owner), pooled in
/// `route_pool` so steady-state routing reuses warm buffers.
#[derive(Default)]
struct Route {
    worker: u32,
    stamp: u64,
    ids: Vec<u64>,
    losses: Vec<f32>,
}

enum RowClass {
    Fresh(Vec<f32>),
    Stale { min_stamp: u64 },
    Incomplete,
    /// A restart invalidated the in-flight lookup; re-issue immediately
    /// (nothing was counted).
    Retry,
}

impl FleetTransport {
    /// Spawn `workers` child processes, their reader threads, and await
    /// every endpoint's version-checked `Hello` handshake.
    pub fn spawn(spec: FleetSpec) -> Result<FleetTransport> {
        anyhow::ensure!(spec.workers > 0, "fleet transport needs at least one worker");
        anyhow::ensure!(
            spec.min_workers >= 1 && spec.min_workers <= spec.workers,
            "fleet floor min_workers = {} must be in 1..={}",
            spec.min_workers,
            spec.workers
        );
        let bin = spec.resolve_bin()?;
        let spawner = EndpointSpawner {
            bin,
            model: spec.model.clone(),
            flavour: spec.flavour.as_str().to_string(),
            workers: spec.workers,
            capacity: spec.capacity,
            max_age: spec.max_age,
            score_precision: spec.score_precision.as_str().to_string(),
            link: spec.link,
            timeout: spec.timeout,
        };
        let (event_tx, events) = mpsc::channel::<Event>();
        let mut t = FleetTransport {
            spawner,
            slots: Vec::with_capacity(spec.workers),
            events,
            event_tx,
            sync: spec.sync,
            max_age: spec.max_age,
            timeout: spec.timeout,
            affinity: spec.affinity,
            restart_limit: spec.restart_limit,
            min_workers: spec.min_workers,
            active: (0..spec.workers).collect(),
            restarts: 0,
            reshard_count: 0,
            restart_epoch: 0,
            journal: (0..spec.workers).map(|_| HashMap::new()).collect(),
            max_entries: spec.max_entries,
            journal_entries: 0,
            evictions: 0,
            outstanding: BTreeMap::new(),
            last_params: Vec::new(),
            param_precision: spec.param_precision,
            enc_buf: Vec::new(),
            lookup_ids: Vec::new(),
            per_row: Vec::new(),
            per_shard: Vec::new(),
            pools: Arc::new(Mutex::new(FramePools::new())),
            pending_routes: (0..spec.workers).map(|_| Vec::new()).collect(),
            route_pool: Vec::new(),
            wire: WireStats::default(),
            next_seq: 0,
            next_req: 0,
            cur_req: 0,
            pending_views: vec![None; spec.workers],
            agg: CacheStats::default(),
            shard_rows: vec![CacheStats::default(); spec.workers],
            scored: vec![0; spec.workers],
            fleet_rows: 0,
            bytes_out: 0,
            bytes_in: Arc::new(AtomicU64::new(0)),
            final_stats: vec![None; spec.workers],
            shutting_down: false,
            progress: false,
            overlap: spec.overlap,
            writers: Vec::with_capacity(spec.workers),
            last_params_shared: None,
            prefetched: None,
            last_publish_ns: 0,
            last_lookup_rtt_ns: 0,
        };
        for w in 0..spec.workers {
            let fail = spec.fail_after.get(w).copied().flatten();
            let (slot, writer) = t.spawn_slot(w, 0, fail, false)?;
            t.slots.push(slot);
            t.writers.push(writer);
        }
        for w in 0..spec.workers {
            t.await_hello(w)?;
        }
        Ok(t)
    }

    /// Spawn one worker incarnation: endpoint (process + link) plus the
    /// reader thread that turns its frames into generation-tagged
    /// events — and, in overlap mode, the writer thread that owns the
    /// endpoint's write half. `join` spawns a late worker that
    /// announces `Join` instead of `Hello` and owns nothing until the
    /// first `Reshard`.
    fn spawn_slot(
        &self,
        w: usize,
        generation: u64,
        fail_after: Option<u64>,
        join: bool,
    ) -> Result<(Slot, Option<Writer>)> {
        let (mut ep, stream) = self.spawner.spawn(w, generation, fail_after, join)?;
        let tx = self.event_tx.clone();
        let counter = self.bytes_in.clone();
        let pools = self.pools.clone();
        let reader = std::thread::Builder::new()
            .name(format!("obftf-fleet-rx-{w}-g{generation}"))
            .spawn(move || {
                let mut r = BufReader::new(stream);
                // reused body buffer: framing allocates nothing once
                // warm. The body is read *before* taking the pools lock
                // so a blocked read never stalls the other readers or
                // the leader's recycling.
                let mut body = Vec::new();
                loop {
                    match proto::read_frame_body(&mut r, &mut body) {
                        Ok(Some(len)) => {
                            let decoded = {
                                let mut pools = pools.lock().expect("frame pools");
                                Frame::decode_pooled(&body, &mut pools)
                            };
                            match decoded {
                                Ok(frame) => {
                                    counter.fetch_add(4 + len as u64, Ordering::Relaxed);
                                    if tx.send(Event::Frame(w, generation, frame)).is_err() {
                                        return;
                                    }
                                }
                                Err(e) => {
                                    let _ = tx.send(Event::Dead(
                                        w,
                                        generation,
                                        format!("bad frame from worker: {e:#}"),
                                    ));
                                    return;
                                }
                            }
                        }
                        Ok(None) => {
                            let _ = tx.send(Event::Dead(
                                w,
                                generation,
                                "link closed (worker exited)".into(),
                            ));
                            return;
                        }
                        Err(e) => {
                            let _ = tx.send(Event::Dead(
                                w,
                                generation,
                                format!("bad frame from worker: {e:#}"),
                            ));
                            return;
                        }
                    }
                }
            })
            .context("spawn fleet reader thread")?;
        let writer = if self.overlap {
            let out = ep
                .take_writer()
                .context("endpoint write half already taken (overlap writer)")?;
            let (jtx, jrx) = mpsc::sync_channel::<WriteJob>(OUTBOX_CAP);
            let etx = self.event_tx.clone();
            let publish_ns = Arc::new(AtomicU64::new(0));
            let pns = publish_ns.clone();
            let handle = std::thread::Builder::new()
                .name(format!("obftf-fleet-tx-{w}-g{generation}"))
                .spawn(move || writer_loop(out, jrx, w, generation, etx, pns))
                .context("spawn fleet writer thread")?;
            Some(Writer { tx: jtx, handle, publish_ns })
        } else {
            None
        };
        Ok((Slot { ep, reader: Some(reader), alive: true, hello: false, last_sent: "none" }, writer))
    }

    /// Block (bounded by the fleet timeout) until worker `w`'s current
    /// incarnation has handshaken. Other workers' events are handled
    /// along the way, including their deaths (supervised recursively).
    fn await_hello(&mut self, w: usize) -> Result<()> {
        let deadline = Instant::now() + self.timeout;
        while !self.slots[w].hello {
            let what = format!("Hello handshake from {}", self.slots[w].ep.describe);
            self.recv_deadline(deadline, &what)?;
        }
        Ok(())
    }

    /// Supervised-restart policy for a dead worker: within the restart
    /// budget, respawn → handshake → republish weights → (post-reshard)
    /// re-announce the ownership map → re-warm the owned shard from the
    /// journal → re-issue in-flight batches. Beyond the budget the
    /// worker is *retired* (permanent leave + reshard) while the fleet
    /// stays above the `min_workers` floor; at the floor, or during
    /// shutdown, the death is fatal.
    fn supervise(&mut self, w: usize, reason: &str) -> Result<()> {
        if self.shutting_down {
            return Err(self.dead_error(w, reason));
        }
        if self.restarts >= u64::from(self.restart_limit) {
            if self.active.len() > self.min_workers && self.active.contains(&w) {
                return self.retire(w, reason);
            }
            return Err(self.dead_error(w, reason));
        }
        self.restarts += 1;
        self.restart_epoch += 1;
        eprintln!(
            "obftf fleet: {} died ({reason}); supervised restart {} of {}",
            self.slots[w].ep.describe, self.restarts, self.restart_limit
        );
        let generation = self.slots[w].ep.generation + 1;
        // reap the dead incarnation; its reader exits on EOF, its
        // writer (overlap mode) drains-and-discards then exits on
        // outbox close, and any trailing events either already queued
        // carry the old generation
        self.slots[w].alive = false;
        if let Some(wr) = self.writers[w].take() {
            wr.join();
        }
        self.slots[w].ep.reap();
        if let Some(h) = self.slots[w].reader.take() {
            let _ = h.join();
        }
        // never re-inject --fail-after into a replacement
        let (slot, writer) = self.spawn_slot(w, generation, None, false)?;
        self.slots[w] = slot;
        self.writers[w] = writer;
        self.await_hello(w)?;
        self.write_params(w)?;
        // a replacement announces with the *spawn-time* default map
        // (worker_id of n_workers); after any reshard that map is
        // stale, so re-announce the current one before the re-warm
        if self.reshard_count > 0 {
            let members: Vec<u64> = self.active.iter().map(|&a| a as u64).collect();
            let mut buf = std::mem::take(&mut self.enc_buf);
            proto::encode_reshard_into(self.reshard_count, &members, &mut buf);
            let res = self.write_raw(w, &buf, "Reshard");
            self.enc_buf = buf;
            res?;
        }
        // routes still deferred for this owner are already journaled —
        // drop them so the re-warm below doesn't get stale duplicates
        while let Some(r) = self.pending_routes[w].pop() {
            self.recycle_route(r);
        }
        // re-warm the shard in (stamp, id) order: stamp-ascending so
        // the newest stamp wins exactly as it did the first time, and
        // id-ascending within a stamp so the replayed frame sequence is
        // identical run-to-run (a HashMap iteration here would not be)
        if let Some(k) = self.active.iter().position(|&a| a == w) {
            let mut entries: Vec<(u64, u64, f32)> =
                self.journal[k].iter().map(|(&id, &(loss, stamp))| (stamp, id, loss)).collect();
            entries.sort_unstable_by_key(|&(stamp, id, _)| (stamp, id));
            let mut ids: Vec<u64> = Vec::new();
            let mut losses: Vec<f32> = Vec::new();
            let mut i = 0;
            while i < entries.len() {
                let stamp = entries[i].0;
                ids.clear();
                losses.clear();
                while i < entries.len() && entries[i].0 == stamp {
                    ids.push(entries[i].1);
                    losses.push(entries[i].2);
                    i += 1;
                }
                let mut buf = std::mem::take(&mut self.enc_buf);
                proto::encode_loss_records_into(u64::MAX, w as u32, stamp, &ids, &losses, &mut buf);
                let res = self.write_raw(w, &buf, "LossRecords");
                self.enc_buf = buf;
                res?;
            }
        }
        // re-issue the dead incarnation's in-flight scoring work
        let replay: Vec<(u64, Arc<Batch>)> = self
            .outstanding
            .iter()
            .filter(|(_, (owner, _))| *owner == w)
            .map(|(&seq, (_, b))| (seq, b.clone()))
            .collect();
        for (seq, batch) in replay {
            self.write(w, &Frame::ScoreBatch { seq, batch: (*batch).clone() })?;
        }
        self.progress = true;
        Ok(())
    }

    /// Permanent leave: the restart budget is spent, so instead of
    /// aborting, drop worker `w` from the ownership map. Its in-flight
    /// scoring work is carried aside (original seqs), the survivors are
    /// quiesced, ownership reshards over the shrunk fleet, and the
    /// carried work re-submits under the new map.
    fn retire(&mut self, w: usize, reason: &str) -> Result<()> {
        eprintln!(
            "obftf fleet: {} died ({reason}); restart budget spent — retiring it \
             (fleet {} → {}, floor {})",
            self.slots[w].ep.describe,
            self.active.len(),
            self.active.len() - 1,
            self.min_workers
        );
        self.slots[w].alive = false;
        if let Some(wr) = self.writers[w].take() {
            wr.join();
        }
        self.slots[w].ep.reap();
        if let Some(h) = self.slots[w].reader.take() {
            let _ = h.join();
        }
        // its deferred routes died with its shard state; the journal
        // re-key in do_reshard migrates the rows themselves
        while let Some(r) = self.pending_routes[w].pop() {
            self.recycle_route(r);
        }
        // carry its in-flight work under the original seqs, past the
        // quiesce (which can no longer wait on the dead worker)
        let carried: Vec<(u64, Arc<Batch>)> = self
            .outstanding
            .iter()
            .filter(|(_, (owner, _))| *owner == w)
            .map(|(&seq, (_, b))| (seq, b.clone()))
            .collect();
        for (seq, _) in &carried {
            self.outstanding.remove(seq);
        }
        self.drain_outstanding()?;
        let next: Vec<usize> = self.active.iter().copied().filter(|&a| a != w).collect();
        self.do_reshard(next)?;
        for (seq, batch) in carried {
            let scorer = self.pick_scorer(&batch);
            self.outstanding.insert(seq, (scorer, batch.clone()));
            self.write(scorer, &Frame::ScoreBatch { seq, batch: (*batch).clone() })?;
        }
        self.progress = true;
        Ok(())
    }

    /// Quiesce: block (bounded by the fleet timeout) until every
    /// in-flight `ScoreBatch` has been answered. The reshard
    /// prerequisite — a reply scored under the old ownership map must
    /// be journaled and routed under that same map, so no score may
    /// span the transition.
    fn drain_outstanding(&mut self) -> Result<()> {
        let deadline = Instant::now() + self.timeout;
        while !self.outstanding.is_empty() {
            self.recv_deadline(deadline, "in-flight scores before reshard")?;
        }
        Ok(())
    }

    /// Recompute ownership over `new_active`: re-key the journal to the
    /// new shard count, drop deferred routes (the full-shard transfer
    /// below subsumes them), broadcast the epoch-tagged `Reshard` map,
    /// and migrate every shard's rows to its owner as `(stamp, id)`-
    /// sorted `ShardTransfer` frames (exact stamps, deterministic
    /// order, not counted as recorded rows). Caller has quiesced.
    fn do_reshard(&mut self, new_active: Vec<usize>) -> Result<()> {
        debug_assert!(self.outstanding.is_empty(), "reshard requires a quiesced fleet");
        self.reshard_count += 1;
        // the epoch bump doubles as the lookup guard: a fan-out
        // spanning this transition classifies as Retry
        self.restart_epoch += 1;
        let new_n = new_active.len() as u64;
        let old = std::mem::take(&mut self.journal);
        let mut journal: Vec<HashMap<u64, (f32, u64)>> =
            (0..new_active.len()).map(|_| HashMap::new()).collect();
        for shard in old {
            for (id, row) in shard {
                journal[(id % new_n) as usize].insert(id, row);
            }
        }
        self.journal = journal;
        for owner in 0..self.pending_routes.len() {
            while let Some(r) = self.pending_routes[owner].pop() {
                self.recycle_route(r);
            }
        }
        self.active = new_active;
        let epoch = self.reshard_count;
        let members: Vec<u64> = self.active.iter().map(|&a| a as u64).collect();
        let mut ids: Vec<u64> = Vec::new();
        let mut losses: Vec<f32> = Vec::new();
        let mut stamps: Vec<u64> = Vec::new();
        for k in 0..self.active.len() {
            let w = self.active[k];
            let mut buf = std::mem::take(&mut self.enc_buf);
            proto::encode_reshard_into(epoch, &members, &mut buf);
            let res = self.write_raw(w, &buf, "Reshard");
            self.enc_buf = buf;
            res?;
            let mut entries: Vec<(u64, u64, f32)> =
                self.journal[k].iter().map(|(&id, &(loss, stamp))| (stamp, id, loss)).collect();
            entries.sort_unstable_by_key(|&(stamp, id, _)| (stamp, id));
            for chunk in entries.chunks(65536) {
                ids.clear();
                losses.clear();
                stamps.clear();
                for &(stamp, id, loss) in chunk {
                    ids.push(id);
                    losses.push(loss);
                    stamps.push(stamp);
                }
                let mut buf = std::mem::take(&mut self.enc_buf);
                proto::encode_shard_transfer_into(
                    epoch, w as u32, &ids, &losses, &stamps, &mut buf,
                );
                let res = self.write_raw(w, &buf, "ShardTransfer");
                self.enc_buf = buf;
                res?;
            }
        }
        self.progress = true;
        Ok(())
    }

    /// Admit one late worker: spawn it on the next slot id with a
    /// `Join` announcement, handshake, publish the current weights,
    /// quiesce in-flight scoring, then reshard ownership over the
    /// grown fleet (which transfers the joiner its shard).
    fn admit(&mut self) -> Result<()> {
        anyhow::ensure!(!self.shutting_down, "cannot admit a worker during shutdown");
        let w = self.slots.len();
        self.spawner.workers = w + 1;
        let (slot, writer) = self.spawn_slot(w, 0, None, true)?;
        self.slots.push(slot);
        self.writers.push(writer);
        self.scored.push(0);
        self.shard_rows.push(CacheStats::default());
        self.pending_views.push(None);
        self.pending_routes.push(Vec::new());
        self.final_stats.push(None);
        self.await_hello(w)?;
        self.write_params(w)?;
        self.drain_outstanding()?;
        let mut next = self.active.clone();
        next.push(w);
        next.sort_unstable();
        self.do_reshard(next)
    }

    /// Enforce the journal bound: when the live entry count exceeds
    /// `max_entries`, evict the oldest `(stamp, id)` entries down to
    /// the bound minus 1/8 slack (so the full scan amortizes), bumping
    /// `evictions`. Deterministic: the eviction order is a total order
    /// over entries, independent of hash iteration.
    fn evict_journal(&mut self) {
        if self.max_entries == 0 || self.journal_entries <= self.max_entries {
            return;
        }
        let slack = (self.max_entries / 8).max(1).min(self.max_entries - 1);
        let target = self.max_entries - slack;
        let excess = self.journal_entries - target;
        let mut entries: Vec<(u64, u64, usize)> = Vec::with_capacity(self.journal_entries as usize);
        for (k, shard) in self.journal.iter().enumerate() {
            for (&id, &(_, stamp)) in shard {
                entries.push((stamp, id, k));
            }
        }
        entries.sort_unstable();
        for &(_, id, k) in entries.iter().take(excess as usize) {
            self.journal[k].remove(&id);
        }
        self.journal_entries -= excess;
        self.evictions += excess;
    }

    /// Return a dropped (stale-generation / retired-sender) frame's
    /// payload vectors to the shared decode pools.
    fn recycle_frame(&mut self, frame: Frame) {
        self.pools.lock().expect("frame pools").recycle(frame);
    }

    /// Contextual error for a dead/failed worker: id, endpoint, child
    /// exit status, the last frame the leader sent it.
    fn dead_error(&mut self, w: usize, reason: &str) -> anyhow::Error {
        self.slots[w].alive = false;
        let status = self.slots[w].ep.status_string();
        let desc = self.slots[w].ep.describe.clone();
        let last = self.slots[w].last_sent;
        anyhow!(
            "pipeline worker {w} died mid-pipeline: {reason} \
             (endpoint: {desc}; child status: {status}; \
             last frame sent to worker {w}: {last})"
        )
    }

    /// Attribute one written frame to the per-type byte split.
    fn account_write(&mut self, name: &'static str, len: u64) {
        self.bytes_out += len;
        self.wire.frames += 1;
        match name {
            "ParamUpdate" => self.wire.param_bytes += len,
            "ScoreBatch" => self.wire.score_bytes += len,
            "LossRecords" => self.wire.route_bytes += len,
            "CacheLookup" => self.wire.lookup_bytes += len,
            "Batch" => self.wire.envelope_bytes += len,
            _ => self.wire.other_bytes += len,
        }
    }

    /// Overlap mode: queue one pre-encoded frame on worker `w`'s
    /// outbox. Blocks only when the bounded outbox is full
    /// (backpressure). Accounting and `last_sent` update at enqueue —
    /// the frame leaves the leader's schedule here; a write that later
    /// fails comes back as a generation-tagged `Dead` event.
    fn enqueue(&mut self, w: usize, job: WriteJob) -> Result<()> {
        let name = job.name;
        let len = job.bytes().len() as u64;
        let sent = match &self.writers[w] {
            Some(wr) => wr.tx.send(job).is_ok(),
            None => false,
        };
        if sent {
            self.account_write(name, len);
            self.slots[w].last_sent = name;
            Ok(())
        } else {
            // the writer thread is gone (panicked) or was never
            // spawned: same policy as a failed serial write
            let reason = format!("write of {name} frame failed: writer outbox closed");
            self.supervise(w, &reason)
        }
    }

    fn write_raw(&mut self, w: usize, bytes: &[u8], name: &'static str) -> Result<()> {
        if !self.slots[w].alive {
            return Err(self.dead_error(w, "refusing to write to dead worker"));
        }
        if self.overlap {
            // copied into an owned job; the Arc-shared fast path is
            // publish-only (see `write_params`)
            return self.enqueue(w, WriteJob { buf: JobBuf::Owned(bytes.to_vec()), name });
        }
        match self.slots[w].ep.write_all(bytes) {
            Ok(()) => {
                self.account_write(name, bytes.len() as u64);
                self.slots[w].last_sent = name;
                Ok(())
            }
            Err(e) => {
                // the write found the corpse before the reader thread
                // did — same policy: supervise within budget. The lost
                // frame is covered by the restart sequence (ParamUpdate
                // republish, journal re-warm, outstanding replay) or,
                // for CacheLookup, by the epoch-bump retry.
                let reason = format!("write of {name} frame failed: {e}");
                self.supervise(w, &reason)
            }
        }
    }

    fn write(&mut self, w: usize, frame: &Frame) -> Result<()> {
        // encode into the pooled scratch (taken, not borrowed: a write
        // failure re-enters through supervise, which writes frames of
        // its own and then simply warms up a fresh buffer)
        let mut buf = std::mem::take(&mut self.enc_buf);
        let t0 = Instant::now();
        frame.encode_into(&mut buf);
        self.wire.encode_ns += t0.elapsed().as_nanos() as u64;
        let res = self.write_raw(w, &buf, frame.name());
        self.enc_buf = buf;
        res
    }

    /// Broadcast the pre-encoded `ParamUpdate` snapshot to worker `w`
    /// straight from the shared buffer — no per-worker copy. No-op
    /// before the first publish. (Body duplicates `write_raw` because
    /// the buffer lives on `self`; the disjoint field borrows keep it
    /// clone-free.)
    fn write_params(&mut self, w: usize) -> Result<()> {
        if self.overlap {
            // share the one pre-encoded broadcast buffer by Arc; the
            // slot's writer thread pushes it concurrently with every
            // other slot's (and with the leader's next hot-loop work)
            let Some(shared) = self.last_params_shared.clone() else {
                return Ok(()); // never published
            };
            if !self.slots[w].alive {
                return Err(self.dead_error(w, "refusing to write to dead worker"));
            }
            return self
                .enqueue(w, WriteJob { buf: JobBuf::Shared(shared), name: "ParamUpdate" });
        }
        if self.last_params.is_empty() {
            return Ok(());
        }
        if !self.slots[w].alive {
            return Err(self.dead_error(w, "refusing to write to dead worker"));
        }
        match self.slots[w].ep.write_all(&self.last_params) {
            Ok(()) => {
                self.account_write("ParamUpdate", self.last_params.len() as u64);
                self.slots[w].last_sent = "ParamUpdate";
                Ok(())
            }
            Err(e) => {
                let reason = format!("write of ParamUpdate frame failed: {e}");
                self.supervise(w, &reason)
            }
        }
    }

    /// Return a spent route to the pool with its buffers kept warm.
    fn recycle_route(&mut self, mut r: Route) {
        r.ids.clear();
        r.losses.clear();
        self.route_pool.push(r);
    }

    /// Write every still-deferred route as a standalone `LossRecords`
    /// frame — the shutdown path, where no further lookup envelope will
    /// carry them and worker-side `recorded_rows` accounting must
    /// complete before the stats handshake. Dead owners' routes are
    /// dropped (their shard state died with them).
    fn flush_routes(&mut self) -> Result<()> {
        for owner in 0..self.slots.len() {
            let mut routes = std::mem::take(&mut self.pending_routes[owner]);
            let mut res = Ok(());
            for route in routes.drain(..) {
                if res.is_ok() && self.slots[owner].alive {
                    let mut buf = std::mem::take(&mut self.enc_buf);
                    proto::encode_loss_records_into(
                        u64::MAX,
                        route.worker,
                        route.stamp,
                        &route.ids,
                        &route.losses,
                        &mut buf,
                    );
                    res = self.write_raw(owner, &buf, "LossRecords");
                    self.enc_buf = buf;
                }
                self.recycle_route(route);
            }
            self.pending_routes[owner] = routes;
            res?;
        }
        Ok(())
    }

    fn handle_event(&mut self, ev: Event) -> Result<()> {
        match ev {
            Event::Frame(w, gen, frame) => {
                if gen != self.slots[w].ep.generation || !self.slots[w].alive {
                    // trailing frame from a dead incarnation or a
                    // retired worker: drop it, keep its payload buffers
                    self.recycle_frame(frame);
                    return Ok(());
                }
                self.handle_frame(w, frame)
            }
            Event::Dead(w, gen, reason) => {
                if gen != self.slots[w].ep.generation {
                    return Ok(()); // the predecessor's EOF, already handled
                }
                if !self.slots[w].alive {
                    return Ok(()); // retired worker's queued EOF, already handled
                }
                if self.shutting_down && self.final_stats[w].is_some() {
                    // normal EOF after the stats handshake
                    self.slots[w].alive = false;
                    Ok(())
                } else {
                    self.supervise(w, &reason)
                }
            }
        }
    }

    fn handle_frame(&mut self, w: usize, frame: Frame) -> Result<()> {
        match frame {
            Frame::Hello { proto: version, worker } | Frame::Join { proto: version, worker } => {
                if version != proto::PROTO_VERSION {
                    return Err(self.dead_error(
                        w,
                        &format!(
                            "protocol version mismatch: worker speaks v{version}, \
                             leader speaks v{}",
                            proto::PROTO_VERSION
                        ),
                    ));
                }
                if worker as usize != w {
                    return Err(self
                        .dead_error(w, &format!("handshake id mismatch: announced {worker}")));
                }
                self.slots[w].hello = true;
                Ok(())
            }
            Frame::LossRecords { seq, stamp, ids, losses, .. } => {
                self.scored[w] += 1;
                self.fleet_rows += ids.len() as u64;
                self.progress = true;
                if seq != u64::MAX {
                    self.outstanding.remove(&seq);
                }
                // journal every row under its shard (newest stamp wins)
                // so a restarted owner can be re-warmed and a reshard
                // can migrate the rows
                let n = self.active.len() as u64;
                for (&id, &l) in ids.iter().zip(&losses) {
                    match self.journal[(id % n) as usize].entry(id) {
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert((l, stamp));
                            self.journal_entries += 1;
                        }
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            if stamp >= e.get().1 {
                                *e.get_mut() = (l, stamp);
                            }
                        }
                    }
                }
                self.evict_journal();
                if self.shutting_down {
                    // late score reply: absorb, don't route
                    let mut pools = self.pools.lock().expect("frame pools");
                    pools.recycle_u64s(ids);
                    pools.recycle_f32s(losses);
                    return Ok(());
                }
                // defer foreign-row routing: each owner's routes coalesce
                // into its next selection-time lookup envelope (one write
                // per owner per step instead of one per scorer per owner);
                // arrival order is preserved, so newest-stamp-wins cache
                // semantics are unchanged. A crash before the flush is
                // covered by the journal insert above.
                for k in 0..self.active.len() {
                    let owner = self.active[k];
                    if owner == w {
                        continue; // scorer recorded its own rows locally
                    }
                    let mut route = self.route_pool.pop().unwrap_or_default();
                    route.worker = w as u32;
                    route.stamp = stamp;
                    for (&id, &l) in ids.iter().zip(&losses) {
                        if (id % n) as usize == k {
                            route.ids.push(id);
                            route.losses.push(l);
                        }
                    }
                    if route.ids.is_empty() {
                        self.recycle_route(route);
                    } else {
                        self.pending_routes[owner].push(route);
                    }
                }
                let mut pools = self.pools.lock().expect("frame pools");
                pools.recycle_u64s(ids);
                pools.recycle_f32s(losses);
                Ok(())
            }
            Frame::CacheView { req, worker, rows } => {
                let worker = worker as usize;
                if req == self.cur_req && worker < self.pending_views.len() {
                    if let Some(old) = self.pending_views[worker].replace(rows) {
                        self.pools.lock().expect("frame pools").recycle_views(old);
                    }
                } else {
                    self.pools.lock().expect("frame pools").recycle_views(rows);
                }
                Ok(())
            }
            Frame::WorkerStats(s) => {
                let idx = s.worker as usize;
                if idx < self.final_stats.len() {
                    self.final_stats[idx] = Some(s);
                }
                Ok(())
            }
            other => Err(self.dead_error(w, &format!("protocol violation: sent {}", other.name()))),
        }
    }

    fn drain_events(&mut self) -> Result<()> {
        loop {
            match self.events.try_recv() {
                Ok(ev) => self.handle_event(ev)?,
                Err(mpsc::TryRecvError::Empty) => return Ok(()),
                Err(mpsc::TryRecvError::Disconnected) => {
                    bail!("all pipeline workers terminated (event channel closed)")
                }
            }
        }
    }

    fn recv_deadline(&mut self, deadline: Instant, what: &str) -> Result<()> {
        let remain = deadline.saturating_duration_since(Instant::now());
        if remain.is_zero() {
            bail!(
                "pipeline timed out after {:?} waiting for {what} \
                 (workers alive: {}/{})",
                self.timeout,
                self.slots.iter().filter(|s| s.alive).count(),
                self.slots.len()
            );
        }
        match self.events.recv_timeout(remain) {
            Ok(ev) => self.handle_event(ev),
            Err(mpsc::RecvTimeoutError::Timeout) => bail!(
                "pipeline timed out after {:?} waiting for {what} \
                 (workers alive: {}/{})",
                self.timeout,
                self.slots.iter().filter(|s| s.alive).count(),
                self.slots.len()
            ),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                bail!("all pipeline workers terminated while waiting for {what}")
            }
        }
    }

    /// Send-phase of a lookup fan-out: allocate a request id, recycle
    /// parked views, and write (or, in overlap mode, enqueue) every
    /// owner's `CacheLookup` — with its deferred routes coalesced into
    /// one envelope, exactly as before. Returns `Ok(false)` when a
    /// supervised restart or reshard fired mid-send: the fan-out is
    /// void and nothing was recorded or counted.
    fn issue_lookup(&mut self, batch: &Batch, now: u64) -> Result<bool> {
        let epoch0 = self.restart_epoch;
        self.next_req += 1;
        let req = self.next_req;
        self.cur_req = req;
        // pooled wire-id scratch (taken so the fan-out below can borrow
        // self mutably; restored on every exit path — `collect_lookup`
        // re-takes it for the merge)
        let mut wire_ids = std::mem::take(&mut self.lookup_ids);
        wire_ids.clear();
        wire_ids.extend(
            batch
                .ids
                .iter()
                .zip(&batch.valid_mask)
                .map(|(&id, &m)| if m > 0.0 && id != usize::MAX { id as u64 } else { NO_ID }),
        );
        {
            let mut pools = self.pools.lock().expect("frame pools");
            for v in self.pending_views.iter_mut() {
                if let Some(rows) = v.take() {
                    pools.recycle_views(rows);
                }
            }
        }
        for k in 0..self.active.len() {
            let w = self.active[k];
            // coalesce this owner's deferred routes with the lookup into
            // one envelope frame (routes first, so the lookup answers
            // over the freshly-routed rows); no routes → a plain lookup
            let mut buf = std::mem::take(&mut self.enc_buf);
            let mut routes = std::mem::take(&mut self.pending_routes[w]);
            let t0 = Instant::now();
            let name = if routes.is_empty() {
                proto::encode_cache_lookup_into(req, now, self.sync, &wire_ids, &mut buf);
                "CacheLookup"
            } else {
                let mut enc = proto::EnvelopeEncoder::begin(&mut buf);
                for r in &routes {
                    enc.member_loss_records(u64::MAX, r.worker, r.stamp, &r.ids, &r.losses);
                }
                enc.member_cache_lookup(req, now, self.sync, &wire_ids);
                enc.finish();
                "Batch"
            };
            self.wire.encode_ns += t0.elapsed().as_nanos() as u64;
            for r in routes.drain(..) {
                self.recycle_route(r);
            }
            self.pending_routes[w] = routes; // keep the Vec's capacity
            let res = self.write_raw(w, &buf, name);
            self.enc_buf = buf;
            if let Err(e) = res {
                self.lookup_ids = wire_ids;
                return Err(e);
            }
            if self.restart_epoch != epoch0 {
                self.lookup_ids = wire_ids;
                return Ok(false);
            }
        }
        self.lookup_ids = wire_ids;
        Ok(true)
    }

    /// Collect-phase: wait for the current fan-out's outstanding views,
    /// then merge and classify under *use-time* freshness rules.
    /// `epoch0` is the epoch the fan-out was issued under — a bump
    /// mid-collect voids it ([`RowClass::Retry`]); `issued` is when the
    /// fan-out left, so the recorded RTT spans issue-to-merge even when
    /// the issue happened during the previous step's backward.
    fn collect_lookup(
        &mut self,
        now: u64,
        count: bool,
        epoch0: u64,
        issued: Instant,
    ) -> Result<RowClass> {
        let deadline = Instant::now() + self.timeout;
        loop {
            let missing_view =
                self.active.iter().any(|&w| self.pending_views[w].is_none());
            if !missing_view {
                break;
            }
            self.recv_deadline(deadline, "cache views")?;
            if self.restart_epoch != epoch0 {
                return Ok(RowClass::Retry);
            }
        }
        self.last_lookup_rtt_ns = issued.elapsed().as_nanos() as u64;
        // merge views into the reused per-row scratch — a warm lookup
        // allocates only the returned losses
        let wire_ids = std::mem::take(&mut self.lookup_ids);
        let rows = wire_ids.len();
        let n = self.active.len();
        self.per_row.clear();
        self.per_row.resize(rows, None);
        for view in self.pending_views.iter().flatten() {
            for r in view {
                if (r.pos as usize) < rows {
                    self.per_row[r.pos as usize] = Some((r.loss, r.stamp));
                }
            }
        }
        let mut out = vec![0.0f32; rows];
        let mut missing = 0usize;
        let mut stale = 0usize;
        let mut min_stamp = NEVER;
        self.per_shard.clear();
        self.per_shard.resize(self.slots.len(), CacheStats::default());
        for (pos, &wid) in wire_ids.iter().enumerate() {
            if wid == NO_ID {
                continue;
            }
            let owner = self.active[(wid % n as u64) as usize];
            let (loss, stamp) = self.per_row[pos].unwrap_or((0.0, NEVER));
            let fresh = if self.sync {
                stamp == now
            } else {
                is_fresh(stamp, now, self.max_age)
            };
            if stamp == NEVER {
                missing += 1;
                self.per_shard[owner].misses += 1;
            } else if fresh {
                out[pos] = loss;
                min_stamp = min_stamp.min(stamp);
                self.per_shard[owner].hits += 1;
            } else {
                stale += 1;
                min_stamp = min_stamp.min(stamp);
                self.per_shard[owner].misses += 1;
                self.per_shard[owner].stale += 1;
            }
        }
        if count {
            for (agg, s) in self.shard_rows.iter_mut().zip(&self.per_shard) {
                agg.hits += s.hits;
                agg.misses += s.misses;
                agg.stale += s.stale;
            }
            if missing == 0 && stale == 0 {
                self.agg.hits += 1;
            } else {
                self.agg.misses += 1;
                if missing == 0 {
                    self.agg.stale += 1;
                }
            }
        }
        self.lookup_ids = wire_ids;
        Ok(if missing > 0 {
            RowClass::Incomplete
        } else if stale > 0 {
            RowClass::Stale { min_stamp }
        } else {
            RowClass::Fresh(out)
        })
    }

    /// One `CacheLookup` fan-out + merged-view freshness classification
    /// (the distributed analogue of `ShardedLossCache::scan`).
    ///
    /// A matching prefetched fan-out is consumed instead of issuing a
    /// new one: its views may already be parked, the rest are collected
    /// here, and classification (and hit/miss counting) runs at *use*
    /// time — the prefetch moved the wire round trip, not the decision.
    ///
    /// If a supervised restart fires mid-collect (the respawned worker
    /// never saw this request), the lookup aborts with
    /// [`RowClass::Retry`] so the caller re-issues it against the new
    /// incarnation instead of waiting out the timeout. A prefetch the
    /// same way voided is simply discarded — the fresh fan-out below
    /// recycles its parked views.
    fn lookup_once(&mut self, batch: &Batch, now: u64, count: bool) -> Result<RowClass> {
        if let Some(p) = self.prefetched.take() {
            if p.now == now && p.req == self.cur_req && p.epoch == self.restart_epoch {
                return self.collect_lookup(now, count, p.epoch, p.issued);
            }
        }
        let issued = Instant::now();
        if !self.issue_lookup(batch, now)? {
            return Ok(RowClass::Retry);
        }
        let epoch0 = self.restart_epoch;
        self.collect_lookup(now, count, epoch0, issued)
    }

    /// Pick the scorer for a batch. With affinity routing (the
    /// default), that is the shard owner of the most batch ids —
    /// its rows are recorded locally instead of routed, cutting
    /// `LossRecords` re-send traffic. Ties go to the lowest worker
    /// index; batches with no valid ids fall back to round-robin.
    fn pick_scorer(&self, batch: &Batch) -> usize {
        let n = self.active.len();
        let rr = self.active[(self.next_seq % n as u64) as usize];
        if !self.affinity || n == 1 {
            return rr;
        }
        let mut counts = vec![0u64; n];
        for (&id, &m) in batch.ids.iter().zip(&batch.valid_mask) {
            if m > 0.0 && id != usize::MAX {
                counts[(id as u64 % n as u64) as usize] += 1;
            }
        }
        let mut best = rr;
        let mut best_count = 0u64;
        for (k, &c) in counts.iter().enumerate() {
            if c > best_count {
                best = self.active[k];
                best_count = c;
            }
        }
        best
    }

    fn submit_inner(&mut self, batch: &Arc<Batch>) -> Result<()> {
        let w = self.pick_scorer(batch);
        let seq = self.next_seq;
        self.next_seq += 1;
        // track before writing: if the write triggers a supervised
        // restart, the replay loop must already see this batch
        self.outstanding.insert(seq, (w, batch.clone()));
        self.write(w, &Frame::ScoreBatch { seq, batch: (**batch).clone() })
    }

    fn reap(&mut self) {
        self.shutting_down = true;
        // writers first: closing the outbox drops the write half, so a
        // still-healthy worker sees EOF and exits before the kill
        for wr in self.writers.iter_mut().filter_map(Option::take) {
            wr.join();
        }
        for s in &mut self.slots {
            s.ep.reap();
            if let Some(h) = s.reader.take() {
                let _ = h.join();
            }
            s.alive = false;
        }
    }
}

impl Transport for FleetTransport {
    fn n_workers(&self) -> usize {
        self.active.len()
    }

    fn publish(&mut self, version: u64, weights: &Arc<Vec<HostTensor>>) -> Result<()> {
        if self.overlap {
            // overlapped fan-out: encode once, share the buffer by
            // Arc, and let every slot's writer thread push it in
            // parallel. The previous broadcast's buffer is reclaimed
            // (try_unwrap) once the last writer finished with it, so
            // the steady state still reuses one warm buffer.
            let mut buf = self
                .last_params_shared
                .take()
                .and_then(|a| Arc::try_unwrap(a).ok())
                .unwrap_or_default();
            let t0 = Instant::now();
            proto::encode_param_update_into(
                version,
                weights.as_slice(),
                self.param_precision,
                &mut buf,
            );
            self.wire.encode_ns += t0.elapsed().as_nanos() as u64;
            // stash before the enqueue loop so a restart fired by a
            // closed outbox already republishes this snapshot
            self.last_params_shared = Some(Arc::new(buf));
            for w in 0..self.slots.len() {
                if self.slots[w].alive {
                    self.write_params(w)?;
                }
            }
            return Ok(());
        }
        // runs once per training step: encode straight from the
        // borrowed snapshot into the reused broadcast buffer (bf16
        // param precision halves it here, once, for every worker)
        let mut buf = std::mem::take(&mut self.last_params);
        let t0 = Instant::now();
        proto::encode_param_update_into(
            version,
            weights.as_slice(),
            self.param_precision,
            &mut buf,
        );
        self.wire.encode_ns += t0.elapsed().as_nanos() as u64;
        // stash before the write loop so a restart fired *by* one of
        // these writes already republishes this snapshot; retired
        // workers are skipped (they left the fleet permanently)
        self.last_params = buf;
        let t1 = Instant::now();
        for w in 0..self.slots.len() {
            if self.slots[w].alive {
                self.write_params(w)?;
            }
        }
        self.last_publish_ns = t1.elapsed().as_nanos() as u64;
        Ok(())
    }

    fn submit(&mut self, batch: &Arc<Batch>) -> Result<()> {
        self.drain_events()?;
        self.submit_inner(batch)
    }

    /// Distributed analogue of the in-process wait: drain fleet events
    /// (routing loss records to shard owners as they arrive), fan out
    /// `CacheLookup`s, classify merged views, requeue stale batches
    /// (async mode), all under the recv timeout.
    fn await_losses(&mut self, batch: &Arc<Batch>, now: u64) -> Result<Vec<f32>> {
        let deadline = Instant::now() + self.timeout;
        // sync/exact mode never counts: matches the thread oracle, whose
        // probe_stamped polls are non-counting
        let mut counted = self.sync;
        let mut requeued_for: Option<u64> = None;
        loop {
            self.drain_events()?;
            self.progress = false;
            match self.lookup_once(batch, now, !counted)? {
                RowClass::Fresh(l) => return Ok(l),
                RowClass::Stale { min_stamp } => {
                    if !self.sync && requeued_for != Some(min_stamp) {
                        self.submit_inner(batch)?;
                        requeued_for = Some(min_stamp);
                    }
                    counted = true;
                }
                RowClass::Incomplete => {
                    counted = true;
                }
                RowClass::Retry => {
                    // a supervised restart aborted the lookup before it
                    // classified (or counted) anything — re-issue it
                    // against the new incarnation; `progress` is set by
                    // the restart, so the loop retries immediately
                }
            }
            // a LossRecords handled during the lookup's own collect means
            // rows were routed after some owners had already answered —
            // re-lookup immediately; otherwise block for fleet progress
            if !self.progress {
                self.recv_deadline(deadline, "loss records")?;
            }
        }
    }

    fn cache_stats(&self) -> CacheStats {
        self.agg
    }

    fn workers_alive(&self) -> usize {
        self.slots.iter().filter(|s| s.alive).count()
    }

    fn worker_scored(&self) -> Vec<u64> {
        self.scored.clone()
    }

    fn restarts(&self) -> u64 {
        self.restarts
    }

    fn reshards(&self) -> u64 {
        self.reshard_count
    }

    fn evictions(&self) -> u64 {
        self.evictions
    }

    fn admit_worker(&mut self) -> Result<()> {
        self.drain_events()?;
        self.admit()
    }

    fn frame_bytes(&self) -> u64 {
        self.bytes_out + self.bytes_in.load(Ordering::Relaxed)
    }

    fn wire_stats(&self) -> WireStats {
        self.wire
    }

    /// Issue step `now`'s lookup fan-out immediately (overlap mode) so
    /// its round trip runs under the leader's current backward. The
    /// views park in `pending_views` as reader threads deliver them;
    /// `await_losses` collects and classifies at use time.
    fn prefetch(&mut self, batch: &Arc<Batch>, now: u64) -> Result<()> {
        if !self.overlap || self.sync {
            return Ok(());
        }
        self.drain_events()?;
        let issued = Instant::now();
        if self.issue_lookup(batch, now)? {
            self.prefetched =
                Some(Prefetch { req: self.cur_req, now, epoch: self.restart_epoch, issued });
        }
        Ok(())
    }

    fn lookup_rtt_us(&self) -> u64 {
        self.last_lookup_rtt_ns / 1000
    }

    fn publish_us(&self) -> u64 {
        if self.overlap {
            // slowest writer's most recent completed ParamUpdate write;
            // read off the critical path, never waited on
            let mut ns = 0;
            for wr in self.writers.iter().flatten() {
                ns = ns.max(wr.publish_ns.load(Ordering::Relaxed));
            }
            ns / 1000
        } else {
            self.last_publish_ns / 1000
        }
    }

    fn shutdown(&mut self) -> Result<FleetSummary> {
        // flush still-deferred routed rows first (no further lookup
        // envelope will carry them, and worker-side recorded_rows
        // accounting must settle before the stats handshake)
        let mut first_err: Option<anyhow::Error> = self.flush_routes().err();
        self.shutting_down = true;
        let alive_at_entry = self.workers_alive();
        let n = self.slots.len();
        for w in 0..n {
            if self.slots[w].alive {
                if let Err(e) = self.write(w, &Frame::Shutdown) {
                    first_err.get_or_insert(e);
                }
            }
        }
        let deadline = Instant::now() + self.timeout;
        while first_err.is_none()
            && (0..n).any(|w| self.slots[w].alive && self.final_stats[w].is_none())
        {
            if let Err(e) = self.recv_deadline(deadline, "worker stats") {
                first_err = Some(e);
            }
        }
        self.reap();
        if let Some(e) = first_err {
            return Err(e);
        }
        let workers = (0..n)
            .map(|w| {
                self.final_stats[w].unwrap_or(WorkerStats {
                    worker: w as u32,
                    scored_batches: self.scored[w],
                    ..Default::default()
                })
            })
            .collect();
        Ok(FleetSummary {
            workers,
            workers_alive: alive_at_entry,
            restarts: self.restarts,
            reshards: self.reshard_count,
            cache: self.agg,
            shard_rows: self.shard_rows.clone(),
            fleet_rows: self.fleet_rows,
            frame_bytes: self.frame_bytes(),
            wire: self.wire,
        })
    }
}

impl Drop for FleetTransport {
    fn drop(&mut self) {
        self.reap();
    }
}

// ---------------------------------------------------------------------------
// Worker side (the `obftf worker` subcommand body)
// ---------------------------------------------------------------------------

/// Child-side configuration (parsed from the worker subcommand flags).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub worker_id: usize,
    pub n_workers: usize,
    pub model: String,
    pub flavour: String,
    /// Loss-cache capacity (training-set size).
    pub capacity: usize,
    /// Stored for symmetry/diagnostics; freshness is classified
    /// leader-side from the stamps in `CacheView`s.
    pub max_age: u64,
    /// Scoring-forward precision: "f32" | "bf16".
    pub score_precision: String,
    /// Test-only: crash (exit 17, no handshake) after this many frames.
    pub fail_after: Option<u64>,
    /// Late worker admitted into a running fleet: announce `Join`
    /// instead of `Hello` and own nothing until the first `Reshard`
    /// assigns a shard.
    pub join: bool,
}

/// Whether the worker loop continues after a frame or exits.
enum Flow {
    Continue,
    Done,
}

/// The worker protocol state plus its steady-state scratch buffers:
/// every per-frame list (wire ids, losses, owned rows, view rows) and
/// the encoded reply reuse warm buffers, so a steady-state step
/// performs zero wire-path heap allocations on the worker side.
struct WorkerLoop {
    session: Session,
    cache: LossCache,
    stats: WorkerStats,
    version: u64,
    /// This worker's shard *position* in the current ownership map
    /// (initially the worker id; repositioned by `Reshard`).
    shard_ix: u64,
    /// Shard count of the current map (0 for a joiner that has not
    /// received its first `Reshard` yet — it owns nothing).
    n_shards: u64,
    ids: Vec<u64>,
    vals: Vec<f32>,
    own_ids: Vec<usize>,
    own_vals: Vec<f32>,
    own_valid: Vec<f32>,
    view_rows: Vec<ViewRow>,
    reply: Vec<u8>,
}

impl WorkerLoop {
    /// Positional shard ownership under the current map. A joiner owns
    /// nothing until its first `Reshard` (`n_shards == 0`).
    fn owns(&self, id: u64) -> bool {
        self.n_shards > 0 && id % self.n_shards == self.shard_ix
    }

    /// Handle one frame by reference: the caller owns the frame and
    /// recycles its payload vectors into its [`FramePools`] afterwards,
    /// so a warm steady-state step allocates nothing on the wire path.
    fn handle(&mut self, frame: &Frame, output: &mut impl Write) -> Result<Flow> {
        match frame {
            Frame::ParamUpdate { version: v, weights } => {
                // a bf16 broadcast is detected from the wire dtype and
                // expanded to f32 on receipt — no worker-side flag
                if weights.iter().any(|t| matches!(t.data, TensorData::Bf16(_))) {
                    let expanded: Vec<HostTensor> =
                        weights.iter().map(|t| t.expand_to_f32()).collect();
                    self.session.load_params(&expanded).context("worker weight sync")?;
                } else {
                    self.session.load_params(weights).context("worker weight sync")?;
                }
                self.version = *v;
                Ok(Flow::Continue)
            }
            Frame::ScoreBatch { seq, batch } => {
                anyhow::ensure!(self.version != NEVER, "ScoreBatch before any ParamUpdate");
                let losses =
                    self.session.fwd_loss(&batch.x, &batch.y).context("worker fwd_loss")?;
                self.ids.clear();
                self.vals.clear();
                self.own_ids.clear();
                self.own_vals.clear();
                for ((&id, &m), &l) in batch.ids.iter().zip(&batch.valid_mask).zip(&losses) {
                    if m <= 0.0 || id == usize::MAX {
                        continue;
                    }
                    self.ids.push(id as u64);
                    self.vals.push(l);
                    if self.owns(id as u64) {
                        self.own_ids.push(id);
                        self.own_vals.push(l);
                    }
                }
                self.own_valid.clear();
                self.own_valid.resize(self.own_ids.len(), 1.0);
                self.cache.record_batch(
                    &self.own_ids,
                    &self.own_valid,
                    &self.own_vals,
                    self.version,
                );
                self.stats.scored_batches += 1;
                self.stats.scored_rows += self.ids.len() as u64;
                self.stats.recorded_rows += self.own_ids.len() as u64;
                proto::encode_loss_records_into(
                    *seq,
                    self.stats.worker,
                    self.version,
                    &self.ids,
                    &self.vals,
                    &mut self.reply,
                );
                output.write_all(&self.reply).context("writing LossRecords frame")?;
                Ok(Flow::Continue)
            }
            Frame::LossRecords { stamp, ids, losses, .. } => {
                // rows routed from another scorer; record the owned ones
                self.own_ids.clear();
                self.own_vals.clear();
                for (&id, &l) in ids.iter().zip(losses) {
                    if self.owns(id) {
                        self.own_ids.push(id as usize);
                        self.own_vals.push(l);
                    }
                }
                self.own_valid.clear();
                self.own_valid.resize(self.own_ids.len(), 1.0);
                self.cache.record_batch(&self.own_ids, &self.own_valid, &self.own_vals, *stamp);
                self.stats.recorded_rows += self.own_ids.len() as u64;
                Ok(Flow::Continue)
            }
            Frame::CacheLookup { req, ids, .. } => {
                self.view_rows.clear();
                for (pos, &wid) in ids.iter().enumerate() {
                    if wid == NO_ID || !self.owns(wid) {
                        continue;
                    }
                    let (loss, stamp) = self.cache.entry(wid as usize).unwrap_or((0.0, NEVER));
                    self.view_rows.push(ViewRow { pos: pos as u32, loss, stamp });
                }
                self.stats.lookups += 1;
                proto::encode_cache_view_into(
                    *req,
                    self.stats.worker,
                    &self.view_rows,
                    &mut self.reply,
                );
                output.write_all(&self.reply).context("writing CacheView frame")?;
                Ok(Flow::Continue)
            }
            Frame::Reshard { members, .. } => {
                // reposition under the new ownership map, then drop
                // rows this worker no longer owns (gained rows arrive
                // as ShardTransfer frames right behind this one)
                let me = u64::from(self.stats.worker);
                let Some(k) = members.iter().position(|&m| m == me) else {
                    bail!(
                        "worker {}: Reshard map {:?} omits this worker",
                        self.stats.worker,
                        members
                    );
                };
                self.shard_ix = k as u64;
                self.n_shards = members.len() as u64;
                let (ix, n) = (self.shard_ix, self.n_shards);
                self.cache.retain_owned(|id| id as u64 % n == ix);
                Ok(Flow::Continue)
            }
            Frame::ShardTransfer { ids, losses, stamps, .. } => {
                // migrated rows keep their original stamps: exact
                // restore, not counted as recorded rows (nothing new
                // was scored or routed)
                for ((&id, &l), &s) in ids.iter().zip(losses).zip(stamps) {
                    if self.owns(id) {
                        self.cache.restore(id as usize, l, s);
                    }
                }
                Ok(Flow::Continue)
            }
            Frame::Shutdown => {
                proto::write_frame(output, &Frame::WorkerStats(self.stats))?;
                output.flush().context("flushing WorkerStats")?;
                Ok(Flow::Done)
            }
            Frame::Batch(members) => {
                // coalesced envelope: handle members in order (decode
                // already rejected nesting), so routed rows land before
                // the lookup that rides with them
                for m in members {
                    if let Flow::Done = self.handle(m, output)? {
                        return Ok(Flow::Done);
                    }
                }
                Ok(Flow::Continue)
            }
            other => bail!(
                "worker {}: unexpected {} frame from leader",
                self.stats.worker,
                other.name()
            ),
        }
    }
}

/// The worker protocol loop: read frames from `input`, write replies to
/// `output`. Owns the loss-cache shards `id % n_workers == worker_id`:
/// records its own scores and routed rows there, serves `CacheLookup`s
/// over them. Returns on `Shutdown` (after the `WorkerStats` handshake)
/// or on clean EOF.
///
/// Runs over any byte stream, so tests drive it hermetically with
/// in-memory buffers; `obftf worker` runs it over stdin/stdout.
pub fn run_worker(cfg: &WorkerConfig, mut input: impl Read, mut output: impl Write) -> Result<()> {
    anyhow::ensure!(cfg.n_workers > 0, "worker fleet size must be ≥ 1");
    anyhow::ensure!(
        cfg.worker_id < cfg.n_workers,
        "worker id {} out of range for {} workers",
        cfg.worker_id,
        cfg.n_workers
    );
    // announce first, before the (possibly slow) session build, so the
    // leader's version-checked handshake completes promptly; a late
    // worker announces Join instead of Hello
    let announce = if cfg.join {
        Frame::Join { proto: proto::PROTO_VERSION, worker: cfg.worker_id as u32 }
    } else {
        Frame::Hello { proto: proto::PROTO_VERSION, worker: cfg.worker_id as u32 }
    };
    proto::write_frame(&mut output, &announce)?;
    output.flush().context("flushing handshake announcement")?;
    let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
    let flavour = manifest.resolve_flavour(&cfg.flavour)?;
    let mut session = Session::new(&manifest, &cfg.model, flavour)
        .with_context(|| format!("worker {}: building session for {}", cfg.worker_id, cfg.model))?;
    let precision = ScorePrecision::parse(cfg.score_precision.trim())
        .with_context(|| format!("worker {}: --score-precision", cfg.worker_id))?;
    session.set_score_precision(precision);
    let mut wl = WorkerLoop {
        session,
        cache: LossCache::new(cfg.capacity, 0),
        stats: WorkerStats { worker: cfg.worker_id as u32, ..Default::default() },
        version: NEVER,
        shard_ix: cfg.worker_id as u64,
        n_shards: if cfg.join { 0 } else { cfg.n_workers as u64 },
        ids: Vec::new(),
        vals: Vec::new(),
        own_ids: Vec::new(),
        own_vals: Vec::new(),
        own_valid: Vec::new(),
        view_rows: Vec::new(),
        reply: Vec::new(),
    };
    let mut frames_handled = 0u64;
    let mut body = Vec::new();
    let mut pools = FramePools::new();
    loop {
        let Some((frame, _)) = proto::read_frame_pooled(&mut input, &mut body, &mut pools)?
        else {
            return Ok(()); // leader closed the pipe: clean shutdown
        };
        if cfg.fail_after.is_some_and(|k| frames_handled >= k) {
            // simulated mid-pipeline crash for the kill-a-worker
            // regression test: no Shutdown handshake, no stats
            std::process::exit(17);
        }
        frames_handled += 1;
        let flow = wl.handle(&frame, &mut output)?;
        // one flush per *top-level* frame: a coalesced envelope's
        // replies (routed-row acks, the view) leave in a single
        // syscall instead of one flush per member reply. Shutdown's
        // stats handshake keeps its own flush inside `handle`, since
        // it must reach the leader even mid-envelope.
        output.flush().context("flushing replies")?;
        pools.recycle(frame);
        if let Flow::Done = flow {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::InMemoryDataset;
    use crate::data::Rng;

    fn worker_cfg(worker_id: usize, n_workers: usize, capacity: usize) -> WorkerConfig {
        WorkerConfig {
            worker_id,
            n_workers,
            model: "linreg".into(),
            flavour: "native".into(),
            capacity,
            max_age: 0,
            score_precision: "f32".into(),
            fail_after: None,
            join: false,
        }
    }

    /// Build a linreg-shaped batch over `capacity` synthetic examples.
    fn linreg_fixture() -> (Manifest, Session, Batch, usize) {
        let manifest = Manifest::load_or_native(&crate::artifacts_dir()).expect("manifest");
        let batch_size = manifest.batch;
        let capacity = batch_size * 2;
        let mut rng = Rng::seed_from(11);
        let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
        let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
        let ds = InMemoryDataset::new(vec![1], xs, crate::data::Targets::F32(ys)).unwrap();
        let ids: Vec<usize> = (0..batch_size).collect();
        let batch = ds.gather_batch(&ids, batch_size).unwrap();
        let mut session = Session::new(&manifest, "linreg", Flavour::Native).unwrap();
        session.init(3).unwrap();
        (manifest, session, batch, capacity)
    }

    fn run_script(cfg: &WorkerConfig, frames: &[Frame]) -> Vec<Frame> {
        let mut input = Vec::new();
        for f in frames {
            input.extend_from_slice(&f.encode());
        }
        let mut output = Vec::new();
        run_worker(cfg, &mut input.as_slice(), &mut output).expect("worker runs");
        let mut replies = Vec::new();
        let mut cur = std::io::Cursor::new(output);
        while let Some((f, _)) = proto::read_frame(&mut cur).expect("reply decodes") {
            replies.push(f);
        }
        replies
    }

    #[test]
    fn worker_scores_records_owned_and_serves_lookups() {
        let (_, mut session, batch, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();
        let cfg = worker_cfg(1, 2, capacity);
        let lookup_ids: Vec<u64> = batch.ids.iter().map(|&i| i as u64).collect();
        let script = [
            Frame::ParamUpdate { version: 5, weights },
            Frame::ScoreBatch { seq: 7, batch: batch.clone() },
            Frame::CacheLookup { req: 1, now: 5, exact: true, ids: lookup_ids },
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        assert_eq!(replies.len(), 4, "Hello + LossRecords + CacheView + WorkerStats");
        let Frame::Hello { proto: version, worker } = &replies[0] else {
            panic!("expected Hello first, got {}", replies[0].name());
        };
        assert_eq!((*version, *worker), (proto::PROTO_VERSION, 1));
        let Frame::LossRecords { seq, worker, stamp, ids, losses } = &replies[1] else {
            panic!("expected LossRecords, got {}", replies[1].name());
        };
        assert_eq!((*seq, *worker, *stamp), (7, 1, 5));
        assert_eq!(ids.len(), batch.real);
        for ((&id, &got), &want) in ids.iter().zip(losses).zip(&expect) {
            assert_eq!(got.to_bits(), want.to_bits(), "loss for id {id}");
        }
        let Frame::CacheView { req, worker, rows } = &replies[2] else {
            panic!("expected CacheView, got {}", replies[2].name());
        };
        assert_eq!((*req, *worker), (1, 1));
        // worker 1 of 2 owns the odd ids, all recorded at stamp 5
        let odd = batch.ids.iter().filter(|&&i| i % 2 == 1).count();
        assert_eq!(rows.len(), odd);
        for r in rows {
            assert_eq!(batch.ids[r.pos as usize] % 2, 1);
            assert_eq!(r.stamp, 5);
            assert_eq!(r.loss.to_bits(), expect[r.pos as usize].to_bits());
        }
        let Frame::WorkerStats(s) = &replies[3] else {
            panic!("expected WorkerStats, got {}", replies[3].name());
        };
        assert_eq!(s.scored_batches, 1);
        assert_eq!(s.scored_rows, batch.real as u64);
        assert_eq!(s.recorded_rows, odd as u64);
        assert_eq!(s.lookups, 1);
    }

    #[test]
    fn worker_records_routed_rows_and_reports_never_for_unknown() {
        let (_, session, batch, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let cfg = worker_cfg(0, 2, capacity);
        // route two rows owned by worker 0 (even ids) at stamp 9
        let script = [
            Frame::ParamUpdate { version: 0, weights },
            Frame::LossRecords {
                seq: u64::MAX,
                worker: 1,
                stamp: 9,
                ids: vec![0, 2, 3],
                losses: vec![0.25, 0.5, 99.0],
            },
            Frame::CacheLookup { req: 4, now: 9, exact: false, ids: vec![0, 2, 3, 4, NO_ID] },
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        assert!(matches!(replies[0], Frame::Hello { .. }), "Hello announces first");
        let Frame::CacheView { rows, .. } = &replies[1] else {
            panic!("expected CacheView, got {}", replies[1].name());
        };
        // owned requested rows: positions 0 (id 0), 1 (id 2), 3 (id 4);
        // id 3 belongs to worker 1, NO_ID is skipped
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[0].pos, rows[0].stamp), (0, 9));
        assert_eq!(rows[0].loss, 0.25);
        assert_eq!((rows[1].pos, rows[1].stamp), (1, 9));
        assert_eq!(rows[1].loss, 0.5);
        // id 4 was never recorded
        assert_eq!((rows[2].pos, rows[2].stamp), (3, NEVER));
        let Frame::WorkerStats(s) = &replies[2] else { panic!("expected stats") };
        assert_eq!(s.recorded_rows, 2, "only the owned routed rows");
        assert_eq!(s.scored_batches, 0);
    }

    #[test]
    fn worker_handles_coalesced_envelope() {
        let (_, session, batch, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let cfg = worker_cfg(0, 2, capacity);
        // one coalesced envelope: routed rows ride ahead of the lookup,
        // so the view already covers them
        let script = [
            Frame::ParamUpdate { version: 2, weights },
            Frame::Batch(vec![
                Frame::LossRecords {
                    seq: u64::MAX,
                    worker: 1,
                    stamp: 6,
                    ids: vec![0, 2, 5],
                    losses: vec![0.125, 0.75, 42.0],
                },
                Frame::CacheLookup { req: 9, now: 6, exact: false, ids: vec![0, 2, 4] },
            ]),
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        assert_eq!(replies.len(), 3, "Hello + CacheView + WorkerStats");
        let Frame::CacheView { req, worker, rows } = &replies[1] else {
            panic!("expected CacheView, got {}", replies[1].name());
        };
        assert_eq!((*req, *worker), (9, 0));
        // the routes in the same envelope landed before the lookup ran
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[0].pos, rows[0].stamp), (0, 6));
        assert_eq!(rows[0].loss, 0.125);
        assert_eq!((rows[1].pos, rows[1].stamp), (1, 6));
        assert_eq!(rows[1].loss, 0.75);
        assert_eq!((rows[2].pos, rows[2].stamp), (2, NEVER));
        let Frame::WorkerStats(s) = &replies[2] else { panic!("expected stats") };
        assert_eq!(s.recorded_rows, 2, "ids 0 and 2 are owned; 5 belongs to worker 1");
        assert_eq!(s.lookups, 1);
    }

    /// The worker flushes once per *top-level* frame (reply
    /// coalescing), which is only sound if a burst's replies still
    /// leave in request order. Pin that order across a mixed burst:
    /// two scores, a bare lookup, and a coalesced envelope whose
    /// member replies share one flush.
    #[test]
    fn worker_burst_replies_stay_in_request_order() {
        let (_, session, batch, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let cfg = worker_cfg(0, 1, capacity);
        let ids: Vec<u64> = batch.ids.iter().map(|&i| i as u64).collect();
        let script = [
            Frame::ParamUpdate { version: 1, weights },
            Frame::ScoreBatch { seq: 1, batch: batch.clone() },
            Frame::ScoreBatch { seq: 2, batch: batch.clone() },
            Frame::CacheLookup { req: 3, now: 1, exact: false, ids: ids.clone() },
            Frame::Batch(vec![
                // routed records are silent; only the lookup replies
                Frame::LossRecords {
                    seq: u64::MAX,
                    worker: 0,
                    stamp: 1,
                    ids: vec![0],
                    losses: vec![0.5],
                },
                Frame::CacheLookup { req: 4, now: 1, exact: false, ids },
            ]),
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        let got: Vec<String> = replies
            .iter()
            .map(|f| match f {
                Frame::Hello { .. } => "Hello".into(),
                Frame::LossRecords { seq, .. } => format!("LossRecords#{seq}"),
                Frame::CacheView { req, .. } => format!("CacheView#{req}"),
                Frame::WorkerStats(_) => "WorkerStats".into(),
                other => other.name().into(),
            })
            .collect();
        assert_eq!(
            got,
            [
                "Hello",
                "LossRecords#1",
                "LossRecords#2",
                "CacheView#3",
                "CacheView#4",
                "WorkerStats",
            ]
            .map(String::from),
            "replies must keep request order with one flush per burst frame"
        );
    }

    #[test]
    fn worker_expands_bf16_param_broadcast() {
        let (manifest, session, batch, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        // the expected losses come from a local session loaded with the
        // elementwise bf16-rounded weights
        let rounded: Vec<HostTensor> = weights
            .iter()
            .map(|t| match &t.data {
                TensorData::F32(v) => HostTensor {
                    shape: t.shape.clone(),
                    data: TensorData::F32(
                        v.iter().map(|&x| bf16_to_f32(f32_to_bf16(x))).collect(),
                    ),
                },
                _ => t.clone(),
            })
            .collect();
        let mut check = Session::new(&manifest, "linreg", Flavour::Native).unwrap();
        check.load_params(&rounded).unwrap();
        let expect = check.fwd_loss(&batch.x, &batch.y).unwrap();
        // ship the broadcast in its bf16 wire form (half-size payload)
        let enc = proto::encode_param_update(4, &weights, ScorePrecision::Bf16);
        let f32_enc = proto::encode_param_update(4, &weights, ScorePrecision::F32);
        assert!(enc.len() < f32_enc.len(), "bf16 broadcast must shrink the frame");
        let (update, _) = proto::read_frame(&mut enc.as_slice()).unwrap().expect("decodes");
        let cfg = worker_cfg(0, 1, capacity);
        let script = [update, Frame::ScoreBatch { seq: 1, batch: batch.clone() }, Frame::Shutdown];
        let replies = run_script(&cfg, &script);
        let Frame::LossRecords { stamp, losses, .. } = &replies[1] else {
            panic!("expected LossRecords, got {}", replies[1].name());
        };
        assert_eq!(*stamp, 4);
        for (i, (&got, &want)) in losses.iter().zip(&expect).enumerate() {
            assert_eq!(got.to_bits(), want.to_bits(), "loss {i}");
        }
    }

    #[test]
    fn worker_reshard_repositions_ownership_and_restores_transfers() {
        let (_, session, _, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        // worker 0 of 2 owns the even ids; after the fleet shrinks to
        // [0] it owns everything, and the migrated odd rows arrive as a
        // ShardTransfer with their original stamps
        let cfg = worker_cfg(0, 2, capacity);
        let script = [
            Frame::ParamUpdate { version: 7, weights },
            Frame::LossRecords {
                seq: u64::MAX,
                worker: 1,
                stamp: 6,
                ids: vec![0, 2],
                losses: vec![0.25, 0.5],
            },
            Frame::Reshard { epoch: 1, members: vec![0] },
            Frame::ShardTransfer {
                epoch: 1,
                worker: 0,
                ids: vec![1, 3],
                losses: vec![1.5, 2.5],
                stamps: vec![4, 5],
            },
            Frame::CacheLookup { req: 2, now: 7, exact: false, ids: vec![0, 1, 2, 3, 4] },
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        assert_eq!(replies.len(), 3, "Hello + CacheView + WorkerStats");
        let Frame::CacheView { rows, .. } = &replies[1] else {
            panic!("expected CacheView, got {}", replies[1].name());
        };
        // sole owner now: every requested id answers, migrated rows
        // keep their original stamps, id 4 was never seen anywhere
        assert_eq!(rows.len(), 5);
        assert_eq!((rows[0].pos, rows[0].loss, rows[0].stamp), (0, 0.25, 6));
        assert_eq!((rows[1].pos, rows[1].loss, rows[1].stamp), (1, 1.5, 4));
        assert_eq!((rows[2].pos, rows[2].loss, rows[2].stamp), (2, 0.5, 6));
        assert_eq!((rows[3].pos, rows[3].loss, rows[3].stamp), (3, 2.5, 5));
        assert_eq!((rows[4].pos, rows[4].stamp), (4, NEVER));
        let Frame::WorkerStats(s) = &replies[2] else { panic!("expected stats") };
        assert_eq!(s.recorded_rows, 2, "routed rows count; ShardTransfer restores do not");
    }

    #[test]
    fn worker_reshard_drops_rows_it_no_longer_owns() {
        let (_, session, _, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        // worker 0 of 1 owns everything; after the map grows to [0, 1]
        // it keeps only the even ids
        let cfg = worker_cfg(0, 1, capacity);
        let script = [
            Frame::ParamUpdate { version: 3, weights },
            Frame::LossRecords {
                seq: u64::MAX,
                worker: 0,
                stamp: 3,
                ids: vec![0, 1, 2, 3],
                losses: vec![0.1, 0.2, 0.3, 0.4],
            },
            Frame::Reshard { epoch: 1, members: vec![0, 1] },
            Frame::CacheLookup { req: 5, now: 3, exact: true, ids: vec![0, 1, 2, 3] },
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        let Frame::CacheView { rows, .. } = &replies[1] else {
            panic!("expected CacheView, got {}", replies[1].name());
        };
        // only the still-owned (even) positions answer, and the handed-
        // off odd rows were invalidated, not just filtered
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].pos, rows[0].stamp), (0, 3));
        assert_eq!((rows[1].pos, rows[1].stamp), (2, 3));
    }

    #[test]
    fn joining_worker_announces_join_and_owns_nothing_until_reshard() {
        let (_, session, _, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let mut cfg = worker_cfg(2, 3, capacity);
        cfg.join = true;
        let script = [
            Frame::ParamUpdate { version: 1, weights },
            // before its first Reshard the joiner owns nothing
            Frame::CacheLookup { req: 1, now: 1, exact: false, ids: vec![0, 1, 2, 5] },
            Frame::Reshard { epoch: 2, members: vec![0, 1, 2] },
            Frame::ShardTransfer {
                epoch: 2,
                worker: 2,
                ids: vec![2, 5],
                losses: vec![0.5, 1.0],
                stamps: vec![0, 1],
            },
            Frame::CacheLookup { req: 2, now: 1, exact: false, ids: vec![0, 1, 2, 5] },
            Frame::Shutdown,
        ];
        let replies = run_script(&cfg, &script);
        let Frame::Join { proto: version, worker } = &replies[0] else {
            panic!("expected Join announcement, got {}", replies[0].name());
        };
        assert_eq!((*version, *worker), (proto::PROTO_VERSION, 2));
        let Frame::CacheView { rows, .. } = &replies[1] else { panic!("expected CacheView") };
        assert!(rows.is_empty(), "joiner owns nothing before its first Reshard");
        let Frame::CacheView { rows, .. } = &replies[2] else { panic!("expected CacheView") };
        // shard position 2 of 3: ids 2 and 5, restored with exact stamps
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].pos, rows[0].loss, rows[0].stamp), (2, 0.5, 0));
        assert_eq!((rows[1].pos, rows[1].loss, rows[1].stamp), (3, 1.0, 1));
    }

    #[test]
    fn worker_rejects_reshard_map_that_omits_it() {
        let (_, session, _, capacity) = linreg_fixture();
        let weights = session.snapshot().unwrap();
        let mut input = Vec::new();
        input.extend_from_slice(&Frame::ParamUpdate { version: 1, weights }.encode());
        input.extend_from_slice(&Frame::Reshard { epoch: 1, members: vec![1, 2] }.encode());
        let mut out = Vec::new();
        let err = run_worker(&worker_cfg(0, 3, capacity), &mut input.as_slice(), &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("omits this worker"), "err: {err}");
    }

    #[test]
    fn worker_rejects_score_before_params_and_bad_ids() {
        let (_, _, batch, capacity) = linreg_fixture();
        let mut input = Frame::ScoreBatch { seq: 0, batch }.encode();
        let mut out = Vec::new();
        let err = run_worker(&worker_cfg(0, 1, capacity), &mut input.as_slice(), &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("ParamUpdate"), "err: {err}");
        // out-of-range worker id rejected up front
        input.clear();
        let err = run_worker(&worker_cfg(3, 2, capacity), &mut input.as_slice(), &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "err: {err}");
    }

    #[test]
    fn worker_clean_eof_is_ok() {
        let (_, _, _, capacity) = linreg_fixture();
        let mut out = Vec::new();
        run_worker(&worker_cfg(0, 1, capacity), std::io::empty(), &mut out).unwrap();
        // only the Hello announcement crossed the wire
        let mut cur = std::io::Cursor::new(out);
        let (first, _) = proto::read_frame(&mut cur).unwrap().expect("Hello present");
        assert!(matches!(first, Frame::Hello { proto: v, worker: 0 } if v == proto::PROTO_VERSION));
        assert!(proto::read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn param_store_publish_and_latest() {
        let t0 = Arc::new(vec![HostTensor::scalar_f32(1.0)]);
        let store = ParamStore::new(t0.clone());
        let (v, p) = store.latest();
        assert_eq!(v, 0);
        assert!(Arc::ptr_eq(&p, &t0));
        let t1 = Arc::new(vec![HostTensor::scalar_f32(2.0)]);
        store.publish(3, t1.clone());
        let (v, p) = store.latest();
        assert_eq!(v, 3);
        assert!(Arc::ptr_eq(&p, &t1));
    }
}
