//! Staged continuous-training pipeline — the paper's deployed-system
//! architecture as concurrently-running stages.
//!
//! The premise of the paper is that a production inference fleet is
//! *already* running forward passes; training should merely record the
//! per-instance losses those passes produce and spend its own compute
//! on backward passes. The serial drivers interleave all of that on one
//! thread; this module decouples it:
//!
//! ```text
//!   producer ──batches──▶ ticket queue ──▶ inference stage
//!   (Prefetcher)               ▲            (N scoped workers, each
//!        │                     │ re-score    with its own Session,
//!        │ (Arc<Batch>)        │ on stale    params synced from the
//!        ▼                     │             ParamStore)
//!   selection stage ◀── ShardedLossCache ◀── record_batch(stamp =
//!   (leader: sampler            (lock-striped,     param version)
//!    over cached losses)         concurrent writers)
//!        │ selected
//!        ▼
//!   training stage (leader: backward + apply only)
//!        │ publish params (version = step+1)     │ snapshot at the
//!        ▼                                       ▼ eval cadence
//!   ParamStore ──────────────▶ async-eval stage (cloned Session,
//!                              scores off the hot path)
//! ```
//!
//! **Synchronous oracle mode** (`pipeline_sync` / `OBFTF_PIPELINE_SYNC`):
//! tickets are issued one step at a time and the selection stage waits
//! for the inference stage before selecting, so every loss is computed
//! with the current weights — the pipeline is then bit-identical to the
//! serial [`StreamingTrainer`] / [`Trainer`] path (pinned by
//! `rust/tests/pipeline_equivalence.rs`). **Async mode** runs the
//! stages concurrently: the inference fleet scores up to
//! `pipeline_depth` batches ahead under possibly-stale weights, bounded
//! by `loss_max_age` (0 = auto: two epochs' worth of steps, the serial
//! trainer's window; fully-scored-but-stale batches are re-enqueued for
//! re-scoring with current weights).
//!
//! Environment overrides (CI and benches): `OBFTF_PIPELINE_WORKERS`,
//! `OBFTF_PIPELINE_DEPTH`, `OBFTF_PIPELINE_SHARDS`,
//! `OBFTF_PIPELINE_SYNC` — see README "Pipeline architecture".
//!
//! [`StreamingTrainer`]: crate::coordinator::StreamingTrainer
//! [`Trainer`]: crate::coordinator::Trainer

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::budget::BudgetTracker;
use crate::coordinator::loss_cache::{CacheProbe, CacheStats, ShardedLossCache};
use crate::coordinator::service::StatusBoard;
use crate::coordinator::trainer::{EvalResult, TrainReport};
use crate::data::dataset::Batch;
use crate::data::rng::Rng;
use crate::data::stream::Prefetcher;
use crate::data::HostTensor;
use crate::metrics::{EvalRecord, Recorder, StepRecord};
use crate::runtime::{Flavour, Manifest, Session};
use crate::sampling::{budget_for, selection_hash, selection_mask, Sampler};

/// Upper bound on how long the selection stage waits for the inference
/// fleet before declaring the pipeline wedged.
const STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// A unit of inference work: score `batch` and record the losses.
struct Ticket {
    batch: Arc<Batch>,
}

/// A unit of eval work: score the test split under `params`.
struct EvalJob {
    step: u64,
    params: Arc<Vec<HostTensor>>,
}

type SharedTickets = Arc<Mutex<mpsc::Receiver<Ticket>>>;

/// Versioned weight snapshot the training stage publishes and the
/// inference workers sync from. Version = number of applies performed,
/// which is also the staleness stamp written into the loss cache.
struct ParamStore {
    inner: Mutex<(u64, Arc<Vec<HostTensor>>)>,
}

impl ParamStore {
    fn new(initial: Arc<Vec<HostTensor>>) -> Self {
        ParamStore { inner: Mutex::new((0, initial)) }
    }

    fn latest(&self) -> (u64, Arc<Vec<HostTensor>>) {
        let g = self.inner.lock().expect("param store lock");
        (g.0, g.1.clone())
    }

    fn publish(&self, version: u64, params: Arc<Vec<HostTensor>>) {
        *self.inner.lock().expect("param store lock") = (version, params);
    }
}

/// Resolved pipeline shape (config overlaid with `OBFTF_PIPELINE_*`).
#[derive(Clone, Copy, Debug)]
pub struct PipelineKnobs {
    /// Inference-fleet worker threads.
    pub workers: usize,
    /// Batches the fleet may score ahead of the training stage (async
    /// mode; sync mode pins this to 0).
    pub depth: usize,
    /// Loss-cache lock stripes.
    pub shards: usize,
    /// Synchronous handoffs — the bit-identical oracle mode.
    pub sync: bool,
    /// Max accepted loss age in parameter versions. `loss_max_age = 0`
    /// resolves to the same auto window the serial trainer uses (two
    /// epochs' worth of steps), so the knob means the same thing in
    /// both drivers.
    pub max_age: u64,
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn env_bool(key: &str) -> Option<bool> {
    std::env::var(key)
        .ok()
        .map(|v| matches!(v.trim(), "1" | "true" | "yes" | "on"))
}

impl PipelineKnobs {
    /// Config values overlaid with the `OBFTF_PIPELINE_*` environment
    /// (the env wins — CI and benches sweep worker counts that way).
    /// `train_len`/`batch` size the auto defaults: the auto `max_age`
    /// is two epochs' worth of steps, exactly like the serial trainer's
    /// `reuse_losses` auto window.
    pub fn resolve(cfg: &TrainConfig, train_len: usize, batch: usize) -> PipelineKnobs {
        let workers = env_usize("OBFTF_PIPELINE_WORKERS")
            .unwrap_or(cfg.pipeline_workers)
            .max(1);
        let depth = env_usize("OBFTF_PIPELINE_DEPTH")
            .unwrap_or(cfg.pipeline_depth)
            .max(1);
        let shards_cfg = env_usize("OBFTF_PIPELINE_SHARDS").unwrap_or(cfg.cache_shards);
        let shards = if shards_cfg == 0 {
            (workers * 2).clamp(4, 16)
        } else {
            shards_cfg
        };
        let sync = env_bool("OBFTF_PIPELINE_SYNC").unwrap_or(cfg.pipeline_sync);
        let max_age = if cfg.loss_max_age > 0 {
            cfg.loss_max_age
        } else {
            2 * train_len.div_ceil(batch.max(1)) as u64
        };
        PipelineKnobs { workers, depth, shards, sync, max_age }
    }
}

/// The staged continuous-training driver (see module docs).
pub struct PipelineTrainer {
    pub cfg: TrainConfig,
    session: Session,
    sampler: Box<dyn Sampler>,
    rng: Rng,
    prefetcher: Prefetcher,
    test_batches: Arc<Vec<Batch>>,
    cache: Arc<ShardedLossCache>,
    pub recorder: Recorder,
    pub budget: BudgetTracker,
    knobs: PipelineKnobs,
    steps: usize,
    eval_every_steps: usize,
    eval_stall_ns: u64,
    step: u64,
}

impl PipelineTrainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<PipelineTrainer> {
        let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
        Self::with_manifest(cfg, &manifest)
    }

    pub fn with_manifest(cfg: &TrainConfig, manifest: &Manifest) -> Result<PipelineTrainer> {
        cfg.validate()?;
        anyhow::ensure!(cfg.stream_steps > 0, "stream_steps must be > 0 for pipeline mode");
        let flavour: Flavour = manifest.resolve_flavour(&cfg.flavour)?;
        let mut session = Session::new(manifest, &cfg.model, flavour)
            .with_context(|| format!("building session for model {}", cfg.model))?;
        session.init(cfg.seed as i32)?;
        let (train, test) = crate::coordinator::build_datasets(cfg)?;
        if train.x_shape != session.entry().x_shape {
            anyhow::bail!(
                "dataset {} features {:?} incompatible with model {} ({:?})",
                cfg.dataset_name(),
                train.x_shape,
                cfg.model,
                session.entry().x_shape
            );
        }
        let sampler = cfg.method.build(cfg.gamma);
        let rng = crate::coordinator::selection_rng(cfg);
        let mut knobs = PipelineKnobs::resolve(cfg, train.len(), manifest.batch);
        let cache = Arc::new(ShardedLossCache::new(train.len(), knobs.max_age, knobs.shards));
        // the cache clamps its stripe count to the capacity; keep the
        // published knobs in agreement so 0..knobs.shards is always a
        // valid shard_stats range
        knobs.shards = cache.n_shards();
        let test_batches = Arc::new(test.batches(manifest.batch));
        let source = crate::coordinator::stream_source(cfg, train);
        let prefetcher = Prefetcher::spawn(
            source,
            manifest.batch,
            cfg.prefetch_depth.max(knobs.depth + 2),
        );
        let eval_every_steps = if cfg.eval_every > 0 {
            (cfg.stream_steps / cfg.eval_every.max(1)).max(1)
        } else {
            0
        };
        Ok(PipelineTrainer {
            cfg: cfg.clone(),
            session,
            sampler,
            rng,
            prefetcher,
            test_batches,
            cache,
            recorder: Recorder::new(),
            budget: BudgetTracker::new(),
            knobs,
            steps: cfg.stream_steps,
            eval_every_steps,
            eval_stall_ns: 0,
            step: 0,
        })
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    pub fn knobs(&self) -> PipelineKnobs {
        self.knobs
    }

    /// Aggregate loss-cache counters (lookup granularity: one hit or
    /// miss per step, counted the moment the selection stage first asks).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Per-shard row-granularity cache counters.
    pub fn shard_stats(&self, shard: usize) -> CacheStats {
        self.cache.shard_stats(shard)
    }

    /// Milliseconds the training stage spent blocked handing snapshots
    /// to the async-eval stage (nonzero = evals arrive faster than the
    /// eval session can score them).
    pub fn eval_stall_ms(&self) -> u64 {
        self.eval_stall_ns / 1_000_000
    }

    /// Producer-side stall time (ns) of the batch stream.
    pub fn producer_blocked_ns(&self) -> u64 {
        self.prefetcher.stats.blocked_ns.load(Ordering::Relaxed)
    }

    /// Run `stream_steps` batches through the staged pipeline.
    pub fn run(&mut self) -> Result<TrainReport> {
        let board = StatusBoard::new();
        self.run_with_board(&board)
    }

    /// Run, publishing per-step state (including cache and eval-stall
    /// counters) to `board`.
    pub fn run_with_board(&mut self, board: &StatusBoard) -> Result<TrainReport> {
        let t0 = Instant::now();
        let manifest = self.session.manifest().clone();
        let model = self.cfg.model.clone();
        let flavour = self.session.flavour();
        let cache = self.cache.clone();
        let params = Arc::new(ParamStore::new(Arc::new(self.session.snapshot()?)));
        let err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let fleet_rows = Arc::new(AtomicU64::new(0));
        let eval_out: Arc<Mutex<Vec<EvalRecord>>> = Arc::new(Mutex::new(Vec::new()));
        let ticket_cap = self.knobs.depth + self.knobs.workers + 2;
        let (ticket_tx, ticket_rx) = mpsc::sync_channel::<Ticket>(ticket_cap);
        let ticket_rx: SharedTickets = Arc::new(Mutex::new(ticket_rx));
        let (eval_tx, eval_rx) = mpsc::sync_channel::<EvalJob>(1);
        let test_batches = self.test_batches.clone();

        let run_result = std::thread::scope(|scope| -> Result<()> {
            for w in 0..self.knobs.workers {
                let ctx = WorkerCtx {
                    manifest: manifest.clone(),
                    model: model.clone(),
                    flavour,
                    tickets: ticket_rx.clone(),
                    cache: cache.clone(),
                    params: params.clone(),
                    fleet_rows: fleet_rows.clone(),
                    err: err.clone(),
                };
                std::thread::Builder::new()
                    .name(format!("obftf-infer-{w}"))
                    .spawn_scoped(scope, move || inference_worker(ctx))
                    .context("spawn inference worker")?;
            }
            let ectx = EvalCtx {
                manifest: manifest.clone(),
                model: model.clone(),
                flavour,
                jobs: eval_rx,
                batches: test_batches,
                out: eval_out.clone(),
                err: err.clone(),
            };
            std::thread::Builder::new()
                .name("obftf-eval".into())
                .spawn_scoped(scope, move || eval_worker(ectx))
                .context("spawn eval worker")?;
            let r = self.leader(board, &ticket_tx, &eval_tx, &params, &err, t0);
            // close the stage queues so workers and the eval stage exit
            // before the scope joins them
            drop(ticket_tx);
            drop(eval_tx);
            r
        });
        run_result?;
        // a stage may have failed after the leader's last check (e.g.
        // the eval stage on the final snapshot, or a worker on a
        // leftover requeued ticket) — surface it rather than reporting
        // a silently-degraded run
        if let Some(e) = err.lock().expect("err slot").take() {
            anyhow::bail!("pipeline stage failed during shutdown: {e}");
        }

        self.budget
            .record_inference_forwards(fleet_rows.load(Ordering::Relaxed));
        let mut evals: Vec<EvalRecord> = std::mem::take(&mut *eval_out.lock().expect("eval out"));
        evals.sort_by_key(|e| e.step);
        for e in evals {
            self.recorder.record_eval(e);
        }
        self.report()
    }

    /// Selection + training stages (the leader loop). Issues inference
    /// tickets up to the lookahead horizon, waits on the cache handoff,
    /// selects, runs the backward, publishes weights.
    fn leader(
        &mut self,
        board: &StatusBoard,
        tickets: &mpsc::SyncSender<Ticket>,
        evals: &mpsc::SyncSender<EvalJob>,
        params: &ParamStore,
        err: &Mutex<Option<String>>,
        t0: Instant,
    ) -> Result<()> {
        let steps = self.steps as u64;
        let depth = if self.knobs.sync { 0 } else { self.knobs.depth as u64 };
        let mut pending: VecDeque<Arc<Batch>> = VecDeque::new();
        let mut next_issue: u64 = 0;
        for s in 0..steps {
            // top up the fleet's lookahead window
            let horizon = (s + depth).min(steps - 1);
            while next_issue <= horizon {
                let batch = Arc::new(self.prefetcher.next());
                send_ticket(tickets, Ticket { batch: batch.clone() }, err)?;
                pending.push_back(batch);
                next_issue += 1;
            }
            let batch = pending.pop_front().expect("ticket issued for this step");

            // ---- stage handoff: wait for the inference fleet ----
            let t_wait = Instant::now();
            let losses = await_losses(&self.cache, &batch, s, self.knobs.sync, tickets, err)?;
            let fwd_us = t_wait.elapsed().as_micros() as u64;

            // ---- selection stage (never touches the engine) ----
            let t1 = Instant::now();
            let b = budget_for(self.cfg.sampling_ratio, batch.real);
            let selected = self
                .sampler
                .select(&losses, &batch.valid_mask, b, &mut self.rng);
            let sel_us = t1.elapsed().as_micros() as u64;

            // ---- training stage: backward + apply only ----
            let t2 = Instant::now();
            let sel_loss = if self.cfg.masked_backward {
                let mask = selection_mask(&selected, batch.batch_size());
                self.session.train_step(&batch.x, &batch.y, &mask, self.cfg.lr)?
            } else {
                self.session
                    .train_step_selected(&batch.x, &batch.y, &selected, self.cfg.lr)?
            };
            let bwd_us = t2.elapsed().as_micros() as u64;

            let new_params = Arc::new(self.session.snapshot()?);
            params.publish(s + 1, new_params.clone());

            let batch_loss = {
                let mut sum = 0.0f64;
                let mut cnt = 0.0f64;
                for (l, m) in losses.iter().zip(&batch.valid_mask) {
                    sum += (*l as f64) * (*m as f64);
                    cnt += *m as f64;
                }
                (sum / cnt.max(1.0)) as f32
            };

            self.budget.record_step(batch.real, selected.len());
            let cache_stats = self.cache.stats();
            let rec = StepRecord {
                step: self.step,
                epoch: 0,
                sel_loss,
                batch_loss,
                n_forward: batch.real,
                n_selected: selected.len(),
                fwd_us,
                sel_us,
                bwd_us,
                cache_hits: cache_stats.hits,
                cache_misses: cache_stats.misses,
                cache_stale: cache_stats.stale,
                sel_hash: selection_hash(&selected),
            };
            self.recorder.record_step(rec);
            self.step += 1;

            // ---- async eval stage ----
            if self.eval_every_steps > 0 && ((s + 1) as usize) % self.eval_every_steps == 0 {
                let t3 = Instant::now();
                if evals
                    .send(EvalJob { step: self.step, params: new_params })
                    .is_err()
                {
                    if let Some(e) = err.lock().expect("err slot").take() {
                        anyhow::bail!("pipeline eval stage failed: {e}");
                    }
                    anyhow::bail!("pipeline eval stage terminated unexpectedly");
                }
                self.eval_stall_ns += t3.elapsed().as_nanos() as u64;
            }

            let blocked_ms = self.producer_blocked_ns() / 1_000_000;
            let ratio = self.budget.realized_ratio();
            let eval_stall_ms = self.eval_stall_ms();
            board.update(|st| {
                st.step = rec.step + 1;
                st.sel_loss = rec.sel_loss;
                st.batch_loss = rec.batch_loss;
                st.realized_ratio = ratio;
                st.steps_per_sec = (s + 1) as f64 / t0.elapsed().as_secs_f64();
                st.producer_blocked_ms = blocked_ms;
                st.cache_hits = cache_stats.hits;
                st.cache_misses = cache_stats.misses;
                st.cache_stale = cache_stats.stale;
                st.eval_stall_ms = eval_stall_ms;
            });
        }
        Ok(())
    }

    /// Leader-side synchronous evaluation (used only when the run
    /// recorded no async evals).
    fn evaluate(&mut self) -> Result<EvalResult> {
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        let batches = self.test_batches.clone();
        for b in batches.iter() {
            let (l, m, c) = self.session.eval_batch(&b.x, &b.y, &b.valid_mask)?;
            sums.0 += l;
            sums.1 += m;
            sums.2 += c;
        }
        let count = sums.2.max(1.0);
        Ok(EvalResult { loss: sums.0 / count, metric: sums.1 / count })
    }

    fn report(&mut self) -> Result<TrainReport> {
        let final_eval = match self.recorder.evals.last() {
            Some(e) => EvalResult { loss: e.loss, metric: e.metric },
            None => self.evaluate()?,
        };
        let (fwd, bwd) = self.recorder.totals();
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            method: self.cfg.method.as_str().to_string(),
            sampling_ratio: self.cfg.sampling_ratio,
            epochs: 0,
            steps: self.step,
            final_eval,
            evals: self.recorder.evals.clone(),
            forward_examples: fwd,
            backward_examples: bwd,
            realized_ratio: self.budget.realized_ratio(),
            saved_fraction: self.budget.saved_fraction(),
            steps_per_sec: self.recorder.throughput(),
            latency_summary: self.recorder.latency_summary(),
        })
    }
}

/// Everything an inference worker owns (built before its thread starts;
/// the `Session` itself is constructed *inside* the thread because
/// backends may hold non-`Send` handles).
struct WorkerCtx {
    manifest: Manifest,
    model: String,
    flavour: Flavour,
    tickets: SharedTickets,
    cache: Arc<ShardedLossCache>,
    params: Arc<ParamStore>,
    fleet_rows: Arc<AtomicU64>,
    err: Arc<Mutex<Option<String>>>,
}

struct EvalCtx {
    manifest: Manifest,
    model: String,
    flavour: Flavour,
    jobs: mpsc::Receiver<EvalJob>,
    batches: Arc<Vec<Batch>>,
    out: Arc<Mutex<Vec<EvalRecord>>>,
    err: Arc<Mutex<Option<String>>>,
}

fn record_failure(err: &Mutex<Option<String>>, stage: &str, e: anyhow::Error) {
    let mut slot = err.lock().expect("err slot");
    if slot.is_none() {
        *slot = Some(format!("{stage}: {e:#}"));
    }
}

/// Inference-stage worker: drain tickets, sync weights from the
/// [`ParamStore`], run `fwd_loss`, record into the sharded cache with
/// the parameter version as the staleness stamp.
fn inference_worker(ctx: WorkerCtx) {
    let mut session = match Session::new(&ctx.manifest, &ctx.model, ctx.flavour) {
        Ok(s) => s,
        Err(e) => return record_failure(&ctx.err, "inference worker (session build)", e),
    };
    let mut loaded_version = u64::MAX;
    loop {
        let msg = ctx.tickets.lock().expect("ticket queue").recv();
        let Ok(Ticket { batch }) = msg else {
            return; // leader closed the queue: clean shutdown
        };
        let (version, p) = ctx.params.latest();
        if version != loaded_version {
            if let Err(e) = session.load_params(&p) {
                return record_failure(&ctx.err, "inference worker (weight sync)", e);
            }
            loaded_version = version;
        }
        match session.fwd_loss(&batch.x, &batch.y) {
            Ok(losses) => {
                ctx.cache
                    .record_batch(&batch.ids, &batch.valid_mask, &losses, loaded_version);
                ctx.fleet_rows.fetch_add(batch.real as u64, Ordering::Relaxed);
            }
            Err(e) => return record_failure(&ctx.err, "inference worker (fwd_loss)", e),
        }
    }
}

/// Async-eval stage: score weight snapshots over the test split on a
/// cloned session, entirely off the training hot path.
fn eval_worker(ctx: EvalCtx) {
    let mut session = match Session::new(&ctx.manifest, &ctx.model, ctx.flavour) {
        Ok(s) => s,
        Err(e) => return record_failure(&ctx.err, "eval stage (session build)", e),
    };
    while let Ok(job) = ctx.jobs.recv() {
        if let Err(e) = session.load_params(&job.params) {
            return record_failure(&ctx.err, "eval stage (weight sync)", e);
        }
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        for b in ctx.batches.iter() {
            match session.eval_batch(&b.x, &b.y, &b.valid_mask) {
                Ok((l, m, c)) => {
                    sums.0 += l;
                    sums.1 += m;
                    sums.2 += c;
                }
                Err(e) => return record_failure(&ctx.err, "eval stage (eval_batch)", e),
            }
        }
        let count = sums.2.max(1.0);
        ctx.out.lock().expect("eval out").push(EvalRecord {
            step: job.step,
            epoch: 0,
            loss: sums.0 / count,
            metric: sums.1 / count,
        });
    }
}

/// Non-blocking ticket send with worker-death detection (a plain
/// blocking send could deadlock against a dead fleet).
fn send_ticket(
    tickets: &mpsc::SyncSender<Ticket>,
    mut ticket: Ticket,
    err: &Mutex<Option<String>>,
) -> Result<()> {
    loop {
        match tickets.try_send(ticket) {
            Ok(()) => return Ok(()),
            Err(mpsc::TrySendError::Full(back)) => {
                if let Some(e) = err.lock().expect("err slot").take() {
                    anyhow::bail!("pipeline inference stage failed: {e}");
                }
                ticket = back;
                std::thread::sleep(Duration::from_micros(50));
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                if let Some(e) = err.lock().expect("err slot").take() {
                    anyhow::bail!("pipeline inference stage failed: {e}");
                }
                anyhow::bail!("pipeline inference stage terminated unexpectedly");
            }
        }
    }
}

/// The selection stage's handoff.
///
/// Async mode: first a *counting* lookup (the hit/miss statistic
/// answers "were the losses ready when selection wanted them?"), then
/// non-counting polls; fully-scored-but-stale batches are re-enqueued
/// once per staleness watermark so a worker re-scores them with
/// current weights.
///
/// Sync mode: poll the exact-stamp probe — only losses computed under
/// the *current* parameter version (stamp == step) are accepted, which
/// is what makes the oracle mode bit-identical to the serial trainer.
fn await_losses(
    cache: &ShardedLossCache,
    batch: &Arc<Batch>,
    now: u64,
    sync: bool,
    tickets: &mpsc::SyncSender<Ticket>,
    err: &Mutex<Option<String>>,
) -> Result<Vec<f32>> {
    let t0 = Instant::now();
    if sync {
        loop {
            if let Some(e) = err.lock().expect("err slot").take() {
                anyhow::bail!("pipeline inference stage failed: {e}");
            }
            if let Some(l) = cache.probe_stamped(&batch.ids, &batch.valid_mask, now) {
                return Ok(l);
            }
            check_stall(cache, now, t0)?;
            std::thread::sleep(Duration::from_micros(30));
        }
    }
    if let Some(l) = cache.lookup_batch(&batch.ids, &batch.valid_mask, now) {
        return Ok(l);
    }
    let mut requeued_for: Option<u64> = None;
    loop {
        if let Some(e) = err.lock().expect("err slot").take() {
            anyhow::bail!("pipeline inference stage failed: {e}");
        }
        match cache.probe_batch(&batch.ids, &batch.valid_mask, now) {
            CacheProbe::Fresh(l) => return Ok(l),
            CacheProbe::Stale { min_stamp } => {
                if requeued_for != Some(min_stamp) {
                    send_ticket(tickets, Ticket { batch: batch.clone() }, err)?;
                    requeued_for = Some(min_stamp);
                }
            }
            CacheProbe::Incomplete => {}
        }
        check_stall(cache, now, t0)?;
        std::thread::sleep(Duration::from_micros(30));
    }
}

fn check_stall(cache: &ShardedLossCache, now: u64, since: Instant) -> Result<()> {
    if since.elapsed() > STALL_TIMEOUT {
        anyhow::bail!(
            "pipeline stalled: step {now} waited {STALL_TIMEOUT:?} for losses \
             (cache stats {:?})",
            cache.stats()
        );
    }
    Ok(())
}
