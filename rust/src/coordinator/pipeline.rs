//! Staged continuous-training pipeline — the paper's deployed-system
//! architecture as concurrently-running stages.
//!
//! The premise of the paper is that a production inference fleet is
//! *already* running forward passes; training should merely record the
//! per-instance losses those passes produce and spend its own compute
//! on backward passes. The serial drivers interleave all of that on one
//! thread; this module decouples it:
//!
//! ```text
//!   producer ──batches──▶ Transport::submit ──▶ inference fleet
//!   (Prefetcher)               ▲                (N workers — threads
//!        │                     │ re-score        *or* `obftf worker`
//!        │ (Arc<Batch>)        │ on stale        child processes —
//!        ▼                     │                 each with a private
//!   selection stage ◀── Transport::await_losses  Session, weights from
//!   (leader: sampler            (sharded loss    Transport::publish)
//!    over cached losses)         cache: striped
//!        │ selected              or worker-owned shards)
//!        ▼
//!   training stage (leader: backward + apply only)
//!        │ Transport::publish (version = step+1) │ snapshot at the
//!        ▼                                       ▼ eval cadence
//!   fleet weights ────────────▶ async-eval stage (cloned Session,
//!                               scores off the hot path)
//! ```
//!
//! Every stage handoff goes through the [`Transport`] trait
//! (`coordinator::ipc`): [`InProcTransport`] keeps the PR-3 thread
//! fleet and lock-striped cache; [`FleetTransport`] promotes the fleet
//! to child processes speaking typed frames (`coordinator::proto`) —
//! over stdio pipes, Unix-domain sockets or loopback TCP — with
//! distributed loss-cache shard ownership (`id % n_workers`),
//! shard-owner affinity routing, supervised worker restart, and
//! elastic membership: `pipeline_join` admits late workers mid-run and
//! `pipeline_min_workers` lets a worker whose restart budget is spent
//! be retired instead of aborting the run, each transition a reshard
//! (see README "Socket fleet — elastic resharding").
//!
//! **Synchronous oracle mode** (`pipeline_sync` / `OBFTF_PIPELINE_SYNC`):
//! tickets are issued one step at a time and the selection stage waits
//! for the inference stage before selecting, so every loss is computed
//! with the current weights — the pipeline is then bit-identical to the
//! serial [`StreamingTrainer`] / [`Trainer`] path in *both* transports
//! (pinned by `rust/tests/pipeline_equivalence.rs`; the wire codec is
//! bit-exact for f32). **Async mode** runs the stages concurrently: the
//! inference fleet scores up to `pipeline_depth` batches ahead under
//! possibly-stale weights, bounded by `loss_max_age` (0 = auto: two
//! epochs' worth of steps; fully-scored-but-stale batches are
//! re-enqueued for re-scoring with current weights).
//!
//! **Overlapped leader** (`pipeline_overlap` / `OBFTF_PIPELINE_OVERLAP`,
//! async-only): three latency hidings stacked on async mode. The next
//! step's `CacheLookup` fan-out is issued the moment this step's
//! backward starts ([`Transport::prefetch`]; the parked answer is
//! re-judged for freshness at use time under the usual
//! `loss_max_age`/restart-epoch rules, so an early reply can only cost
//! a re-issue, never staleness). The param broadcast leaves over
//! per-endpoint writer threads concurrently instead of a serial write
//! loop. And the step epilogue — masked-mean `batch_loss` reduction,
//! `StepRecord`, status-board publish — moves to a recorder stage fed
//! over a bounded channel. Sync mode rejects the knob at resolve time:
//! its guarantee *is* the serialised schedule.
//!
//! Every knob (worker count, depth, shards, sync, transport kind,
//! affinity, restart budget, timeouts) resolves through
//! [`PipelineOptions`] with CLI > env > config > default precedence —
//! see `config::options` for the table, and README "Pipeline
//! architecture" / "Multi-process fleet" / "Socket fleet".
//!
//! [`StreamingTrainer`]: crate::coordinator::StreamingTrainer
//! [`Trainer`]: crate::coordinator::Trainer
//! [`Transport`]: crate::coordinator::ipc::Transport
//! [`InProcTransport`]: crate::coordinator::ipc::InProcTransport
//! [`FleetTransport`]: crate::coordinator::ipc::FleetTransport

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{PipelineOptions, TrainConfig, TransportKind};
use crate::coordinator::budget::BudgetTracker;
use crate::coordinator::endpoint::LinkMode;
use crate::coordinator::ipc::{
    FleetSpec, FleetSummary, FleetTransport, InProcSpec, InProcTransport, Transport, WireStats,
    STALL_TIMEOUT,
};
use crate::coordinator::loss_cache::CacheStats;
use crate::coordinator::service::StatusBoard;
use crate::coordinator::trainer::{EvalResult, TrainReport};
use crate::data::dataset::Batch;
use crate::data::rng::Rng;
use crate::data::stream::Prefetcher;
use crate::data::HostTensor;
use crate::metrics::{EvalRecord, Recorder, StepRecord};
use crate::runtime::{Flavour, Manifest, Session};
use crate::sampling::{budget_for, selection_hash, selection_mask, Sampler};

/// A unit of eval work: score the test split under `params`.
struct EvalJob {
    step: u64,
    params: Arc<Vec<HostTensor>>,
}

/// Everything a step's epilogue needs: the record skeleton
/// (`batch_loss` still unset), the raw losses to reduce, and the
/// status-board fields sampled on the leader. Under the overlapped
/// leader this crosses a bounded channel to the recorder stage;
/// otherwise the leader finishes it inline, exactly where the work
/// used to run.
struct StepEpilogue {
    rec: StepRecord,
    losses: Vec<f32>,
    batch: Arc<Batch>,
    worker_scored: Vec<u64>,
    realized_ratio: f64,
    steps_per_sec: f64,
    producer_blocked_ms: u64,
    eval_stall_ms: u64,
    evictions: u64,
}

impl StepEpilogue {
    /// Finish the step off the hot path: reduce the masked batch loss
    /// (same helper — and therefore bitwise the same value — as the
    /// serial trainers) and publish the completed record to the status
    /// board. Returns the record; the caller owns recording order.
    fn finish(self, board: &StatusBoard) -> StepRecord {
        let StepEpilogue {
            mut rec,
            losses,
            batch,
            worker_scored,
            realized_ratio,
            steps_per_sec,
            producer_blocked_ms,
            eval_stall_ms,
            evictions,
        } = self;
        rec.batch_loss = super::masked_mean_loss(&losses, &batch.valid_mask);
        board.update(|st| {
            st.step = rec.step + 1;
            st.sel_loss = rec.sel_loss;
            st.batch_loss = rec.batch_loss;
            st.realized_ratio = realized_ratio;
            st.steps_per_sec = steps_per_sec;
            st.producer_blocked_ms = producer_blocked_ms;
            st.cache_hits = rec.cache_hits;
            st.cache_misses = rec.cache_misses;
            st.cache_stale = rec.cache_stale;
            st.eval_stall_ms = eval_stall_ms;
            st.workers_alive = rec.workers_alive as u64;
            st.worker_restarts = rec.worker_restarts as u64;
            st.worker_scored = worker_scored;
            st.frames_per_step = rec.frames_per_step;
            st.publish_bytes = rec.publish_bytes;
            st.reshards = rec.reshards;
            st.n_workers = rec.n_workers as u64;
            st.evictions = evictions;
            st.publish_us = rec.publish_us;
            st.lookup_rtt_us = rec.lookup_rtt_us;
        });
        rec
    }
}

/// The staged continuous-training driver (see module docs).
pub struct PipelineTrainer {
    pub cfg: TrainConfig,
    session: Session,
    sampler: Box<dyn Sampler>,
    rng: Rng,
    prefetcher: Prefetcher,
    test_batches: Arc<Vec<Batch>>,
    pub recorder: Recorder,
    pub budget: BudgetTracker,
    options: PipelineOptions,
    capacity: usize,
    steps: usize,
    eval_every_steps: usize,
    eval_stall_ns: u64,
    step: u64,
    /// Fleet/cache aggregate, populated when a run completes.
    summary: FleetSummary,
}

impl PipelineTrainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<PipelineTrainer> {
        let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
        Self::with_manifest(cfg, &manifest)
    }

    pub fn with_manifest(cfg: &TrainConfig, manifest: &Manifest) -> Result<PipelineTrainer> {
        cfg.validate()?;
        anyhow::ensure!(cfg.stream_steps > 0, "stream_steps must be > 0 for pipeline mode");
        let flavour: Flavour = manifest.resolve_flavour(&cfg.flavour)?;
        let mut session = Session::new(manifest, &cfg.model, flavour)
            .with_context(|| format!("building session for model {}", cfg.model))?;
        session.init(cfg.seed as i32)?;
        let (train, test) = crate::coordinator::build_datasets(cfg)?;
        if train.x_shape != session.entry().x_shape {
            anyhow::bail!(
                "dataset {} features {:?} incompatible with model {} ({:?})",
                cfg.dataset_name(),
                train.x_shape,
                cfg.model,
                session.entry().x_shape
            );
        }
        let sampler = cfg.method.build(cfg.gamma);
        let rng = crate::coordinator::selection_rng(cfg);
        let mut options = PipelineOptions::resolve(cfg, train.len(), manifest.batch)?;
        let capacity = train.len();
        if !options.transport.is_fleet() {
            // the in-proc cache clamps its stripe count to the capacity;
            // keep the published options in agreement so 0..options.shards
            // is always a valid shard_stats range
            options.shards = options.shards.clamp(1, capacity.max(1));
        }
        let test_batches = Arc::new(test.batches(manifest.batch));
        let source = crate::coordinator::stream_source(cfg, train);
        let prefetcher = Prefetcher::spawn(
            source,
            manifest.batch,
            cfg.prefetch_depth.max(options.depth + 2),
        );
        let eval_every_steps = if cfg.eval_every > 0 {
            (cfg.stream_steps / cfg.eval_every.max(1)).max(1)
        } else {
            0
        };
        Ok(PipelineTrainer {
            cfg: cfg.clone(),
            session,
            sampler,
            rng,
            prefetcher,
            test_batches,
            recorder: Recorder::new(),
            budget: BudgetTracker::new(),
            options,
            capacity,
            steps: cfg.stream_steps,
            eval_every_steps,
            eval_stall_ns: 0,
            step: 0,
            summary: FleetSummary::default(),
        })
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    /// The fully-resolved pipeline shape this trainer runs with
    /// (CLI > env > config > default; see `config::options`).
    pub fn options(&self) -> PipelineOptions {
        self.options
    }

    /// Aggregate loss-cache counters (lookup granularity: one hit or
    /// miss per step, counted the moment the selection stage first
    /// asks). Populated when a run completes.
    pub fn cache_stats(&self) -> CacheStats {
        self.summary.cache
    }

    /// Per-shard row-granularity cache counters (proc mode: shard ==
    /// owning worker). Populated when a run completes.
    pub fn shard_stats(&self, shard: usize) -> CacheStats {
        self.summary.shard_rows.get(shard).copied().unwrap_or_default()
    }

    /// Final per-worker fleet counters (proc mode: from the
    /// `WorkerStats` shutdown handshake).
    pub fn worker_stats(&self) -> &[crate::coordinator::proto::WorkerStats] {
        &self.summary.workers
    }

    /// Total wire bytes the fleet exchanged (0 for the thread fleet).
    pub fn frame_bytes(&self) -> u64 {
        self.summary.frame_bytes
    }

    /// Reshard events (mid-run joins + retirements) across the run
    /// (0 for the thread fleet). Populated when a run completes.
    pub fn reshards(&self) -> u64 {
        self.summary.reshards
    }

    /// Leader-side wire counters: frames sent, encode time and the
    /// per-frame-type byte split (all zero for the thread fleet).
    /// Populated when a run completes.
    pub fn wire_stats(&self) -> WireStats {
        self.summary.wire
    }

    /// Milliseconds the training stage spent blocked handing snapshots
    /// to the async-eval stage (nonzero = evals arrive faster than the
    /// eval session can score them).
    pub fn eval_stall_ms(&self) -> u64 {
        self.eval_stall_ns / 1_000_000
    }

    /// Producer-side stall time (ns) of the batch stream.
    pub fn producer_blocked_ns(&self) -> u64 {
        self.prefetcher.stats.blocked_ns.load(Ordering::Relaxed)
    }

    fn build_transport(&self) -> Result<Box<dyn Transport>> {
        let queue_cap = self.options.depth + self.options.workers + 2;
        let link = match self.options.transport {
            TransportKind::Threads => {
                return Ok(Box::new(InProcTransport::spawn(InProcSpec {
                    manifest: self.session.manifest().clone(),
                    model: self.cfg.model.clone(),
                    flavour: self.session.flavour(),
                    workers: self.options.workers,
                    capacity: self.capacity,
                    max_age: self.options.max_age,
                    shards: self.options.shards,
                    sync: self.options.sync,
                    queue_cap,
                    stall: STALL_TIMEOUT,
                    score_precision: self.options.score_precision,
                    param_precision: self.options.param_precision,
                    max_entries: self.options.cache_max_entries,
                    overlap: self.options.overlap,
                })?));
            }
            TransportKind::Pipes => LinkMode::Pipes,
            TransportKind::UnixSocket => LinkMode::Unix,
            TransportKind::TcpSocket => LinkMode::Tcp,
        };
        Ok(Box::new(FleetTransport::spawn(FleetSpec {
            model: self.cfg.model.clone(),
            flavour: self.session.flavour(),
            workers: self.options.workers,
            capacity: self.capacity,
            max_age: self.options.max_age,
            sync: self.options.sync,
            score_precision: self.options.score_precision,
            param_precision: self.options.param_precision,
            worker_bin: None,
            timeout: self.options.timeout,
            fail_after: crate::coordinator::ipc::fail_after_from_env(self.options.workers),
            link,
            affinity: self.options.affinity,
            restart_limit: self.options.restart_limit,
            min_workers: self.options.min_workers,
            max_entries: self.options.cache_max_entries,
            overlap: self.options.overlap,
        })?))
    }

    /// Run `stream_steps` batches through the staged pipeline.
    pub fn run(&mut self) -> Result<TrainReport> {
        let board = StatusBoard::new();
        self.run_with_board(&board)
    }

    /// Run, publishing per-step state (including cache, eval-stall and
    /// worker-liveness counters) to `board`.
    pub fn run_with_board(&mut self, board: &StatusBoard) -> Result<TrainReport> {
        let t0 = Instant::now();
        let manifest = self.session.manifest().clone();
        let model = self.cfg.model.clone();
        let flavour = self.session.flavour();
        let initial = Arc::new(self.session.snapshot()?);
        let mut fleet = self.build_transport()?;
        fleet.publish(0, &initial)?;

        let (eval_tx, eval_rx) = mpsc::sync_channel::<EvalJob>(1);
        let eval_out: Arc<Mutex<Vec<EvalRecord>>> = Arc::new(Mutex::new(Vec::new()));
        let eval_err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let ectx = EvalCtx {
            manifest,
            model,
            flavour,
            jobs: eval_rx,
            batches: self.test_batches.clone(),
            out: eval_out.clone(),
            err: eval_err.clone(),
        };
        let eval_handle = std::thread::Builder::new()
            .name("obftf-eval".into())
            .spawn(move || eval_worker(ectx))
            .context("spawn eval worker")?;

        // off-critical-path recorder stage (overlapped leader only):
        // the leader hands each step's epilogue — loss reduction,
        // record, status publish — over a bounded channel instead of
        // running it between backward passes. The channel is FIFO and
        // the stage single-threaded, so records accumulate in step
        // order and merge back after the loop. Nothing in the stage is
        // fallible, so unlike eval it needs no error slot.
        let mut rec_stage = None;
        if self.options.overlap {
            let (tx, rx) = mpsc::sync_channel::<StepEpilogue>(self.options.depth + 2);
            let out: Arc<Mutex<Vec<StepRecord>>> = Arc::new(Mutex::new(Vec::new()));
            let tout = out.clone();
            let tboard = board.clone();
            let handle = std::thread::Builder::new()
                .name("obftf-recorder".into())
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        let rec = job.finish(&tboard);
                        tout.lock().expect("recorder out").push(rec);
                    }
                })
                .context("spawn recorder stage")?;
            rec_stage = Some((tx, handle, out));
        }

        let led = self.leader(
            board,
            fleet.as_mut(),
            &eval_tx,
            &eval_err,
            rec_stage.as_ref().map(|(tx, _, _)| tx),
            t0,
        );
        // close the eval queue so the stage drains and exits
        drop(eval_tx);
        let _ = eval_handle.join();
        // drain the recorder stage and merge its records (even on a
        // failed run, so partial telemetry survives)
        if let Some((tx, handle, out)) = rec_stage {
            drop(tx);
            let _ = handle.join();
            for rec in std::mem::take(&mut *out.lock().expect("recorder out")) {
                self.recorder.record_step(rec);
            }
        }
        let shut = fleet.shutdown();
        led?;
        // a stage may have failed after the leader's last check (e.g.
        // the eval stage on the final snapshot) — surface it rather than
        // reporting a silently-degraded run
        if let Some(e) = eval_err.lock().expect("eval err slot").take() {
            anyhow::bail!("pipeline eval stage failed during shutdown: {e}");
        }
        let summary = shut?;
        self.budget.record_inference_forwards(summary.fleet_rows);
        self.summary = summary;

        let mut evals: Vec<EvalRecord> = std::mem::take(&mut *eval_out.lock().expect("eval out"));
        evals.sort_by_key(|e| e.step);
        for e in evals {
            self.recorder.record_eval(e);
        }
        self.report()
    }

    /// Selection + training stages (the leader loop). Issues inference
    /// work up to the lookahead horizon, waits on the transport's cache
    /// handoff, selects, runs the backward, publishes weights. With
    /// `epilogues` set (overlapped leader), the per-step bookkeeping
    /// tail is handed to the recorder stage instead of running here.
    fn leader(
        &mut self,
        board: &StatusBoard,
        fleet: &mut dyn Transport,
        evals: &mpsc::SyncSender<EvalJob>,
        eval_err: &Mutex<Option<String>>,
        epilogues: Option<&mpsc::SyncSender<StepEpilogue>>,
        t0: Instant,
    ) -> Result<()> {
        let steps = self.steps as u64;
        let depth = if self.options.sync { 0 } else { self.options.depth as u64 };
        let mut pending: VecDeque<Arc<Batch>> = VecDeque::new();
        let mut next_issue: u64 = 0;
        // per-step wire telemetry is the delta against the last step's
        // cumulative counters (the initial publish lands in step 0)
        let mut prev_wire = WireStats::default();
        for s in 0..steps {
            // mid-run admission: late workers join at the configured
            // step, before this step's submissions, so new work routes
            // under the post-reshard ownership map
            if let Some((at, count)) = self.options.join {
                if s == at {
                    for _ in 0..count {
                        fleet.admit_worker()?;
                    }
                }
            }
            // top up the fleet's lookahead window
            let horizon = (s + depth).min(steps - 1);
            while next_issue <= horizon {
                let batch = Arc::new(self.prefetcher.next());
                fleet.submit(&batch)?;
                pending.push_back(batch);
                next_issue += 1;
            }
            let batch = pending.pop_front().expect("work submitted for this step");

            // ---- stage handoff: wait for the inference fleet ----
            let t_wait = Instant::now();
            let losses = fleet.await_losses(&batch, s)?;
            let fwd_us = t_wait.elapsed().as_micros() as u64;

            // ---- selection stage (never touches the engine) ----
            let t1 = Instant::now();
            let b = budget_for(self.cfg.sampling_ratio, batch.real);
            let selected = self
                .sampler
                .select(&losses, &batch.valid_mask, b, &mut self.rng);
            let sel_us = t1.elapsed().as_micros() as u64;

            // ---- overlapped lookup prefetch: issue step s+1's
            // fan-out before this step's backward occupies the leader,
            // so the fleet round-trip hides behind it (a no-op unless
            // async overlap is on). Freshness is re-judged at
            // await_losses(s+1) under the usual max_age/restart-epoch
            // rules, so an early answer can only cost a re-issue.
            if let Some(next) = pending.front() {
                fleet.prefetch(next, s + 1)?;
            }

            // ---- training stage: backward + apply only ----
            let t2 = Instant::now();
            let sel_loss = if self.cfg.masked_backward {
                let mask = selection_mask(&selected, batch.batch_size());
                self.session.train_step(&batch.x, &batch.y, &mask, self.cfg.lr)?
            } else {
                self.session
                    .train_step_selected(&batch.x, &batch.y, &selected, self.cfg.lr)?
            };
            let bwd_us = t2.elapsed().as_micros() as u64;

            let new_params = Arc::new(self.session.snapshot()?);
            fleet.publish(s + 1, &new_params)?;

            self.budget.record_step(batch.real, selected.len());
            let cache_stats = fleet.cache_stats();
            let workers_alive = fleet.workers_alive() as u32;
            let worker_restarts = fleet.restarts() as u32;
            let reshards = fleet.reshards();
            let n_workers = fleet.n_workers() as u32;
            let evictions = fleet.evictions();
            let wire = fleet.wire_stats();
            let frames_per_step = wire.frames - prev_wire.frames;
            let publish_bytes = wire.param_bytes - prev_wire.param_bytes;
            prev_wire = wire;
            let rec = StepRecord {
                step: self.step,
                epoch: 0,
                sel_loss,
                // reduced in the epilogue (masked_mean_loss)
                batch_loss: 0.0,
                n_forward: batch.real,
                n_selected: selected.len(),
                fwd_us,
                sel_us,
                bwd_us,
                cache_hits: cache_stats.hits,
                cache_misses: cache_stats.misses,
                cache_stale: cache_stats.stale,
                sel_hash: selection_hash(&selected),
                workers_alive,
                worker_restarts,
                frames_per_step,
                publish_bytes,
                reshards,
                n_workers,
                publish_us: fleet.publish_us(),
                lookup_rtt_us: fleet.lookup_rtt_us(),
            };
            self.step += 1;

            // ---- async eval stage ----
            if self.eval_every_steps > 0 && ((s + 1) as usize) % self.eval_every_steps == 0 {
                let t3 = Instant::now();
                if evals
                    .send(EvalJob { step: self.step, params: new_params })
                    .is_err()
                {
                    if let Some(e) = eval_err.lock().expect("eval err slot").take() {
                        anyhow::bail!("pipeline eval stage failed: {e}");
                    }
                    anyhow::bail!("pipeline eval stage terminated unexpectedly");
                }
                self.eval_stall_ns += t3.elapsed().as_nanos() as u64;
            }

            // ---- step epilogue: loss reduction, record, status ----
            let job = StepEpilogue {
                rec,
                losses,
                batch,
                worker_scored: fleet.worker_scored(),
                realized_ratio: self.budget.realized_ratio(),
                steps_per_sec: (s + 1) as f64 / t0.elapsed().as_secs_f64(),
                producer_blocked_ms: self.producer_blocked_ns() / 1_000_000,
                eval_stall_ms: self.eval_stall_ms(),
                evictions,
            };
            match epilogues {
                // overlapped leader: the recorder stage finishes the
                // step off the critical path; records merge back into
                // `self.recorder` after the loop
                Some(tx) => {
                    if tx.send(job).is_err() {
                        anyhow::bail!("pipeline recorder stage terminated unexpectedly");
                    }
                }
                None => {
                    let rec = job.finish(board);
                    self.recorder.record_step(rec);
                }
            }
        }
        Ok(())
    }

    /// Leader-side synchronous evaluation (used only when the run
    /// recorded no async evals).
    fn evaluate(&mut self) -> Result<EvalResult> {
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        let batches = self.test_batches.clone();
        for b in batches.iter() {
            let (l, m, c) = self.session.eval_batch(&b.x, &b.y, &b.valid_mask)?;
            sums.0 += l;
            sums.1 += m;
            sums.2 += c;
        }
        let count = sums.2.max(1.0);
        Ok(EvalResult { loss: sums.0 / count, metric: sums.1 / count })
    }

    fn report(&mut self) -> Result<TrainReport> {
        let final_eval = match self.recorder.evals.last() {
            Some(e) => EvalResult { loss: e.loss, metric: e.metric },
            None => self.evaluate()?,
        };
        let (fwd, bwd) = self.recorder.totals();
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            method: self.cfg.method.as_str().to_string(),
            sampling_ratio: self.cfg.sampling_ratio,
            epochs: 0,
            steps: self.step,
            final_eval,
            evals: self.recorder.evals.clone(),
            forward_examples: fwd,
            backward_examples: bwd,
            realized_ratio: self.budget.realized_ratio(),
            saved_fraction: self.budget.saved_fraction(),
            steps_per_sec: self.recorder.throughput(),
            latency_summary: self.recorder.latency_summary(),
        })
    }
}

struct EvalCtx {
    manifest: Manifest,
    model: String,
    flavour: Flavour,
    jobs: mpsc::Receiver<EvalJob>,
    batches: Arc<Vec<Batch>>,
    out: Arc<Mutex<Vec<EvalRecord>>>,
    err: Arc<Mutex<Option<String>>>,
}

fn record_failure(err: &Mutex<Option<String>>, stage: &str, e: anyhow::Error) {
    let mut slot = err.lock().expect("err slot");
    if slot.is_none() {
        *slot = Some(format!("{stage}: {e:#}"));
    }
}

/// Async-eval stage: score weight snapshots over the test split on a
/// cloned session, entirely off the training hot path.
fn eval_worker(ctx: EvalCtx) {
    let mut session = match Session::new(&ctx.manifest, &ctx.model, ctx.flavour) {
        Ok(s) => s,
        Err(e) => return record_failure(&ctx.err, "eval stage (session build)", e),
    };
    while let Ok(job) = ctx.jobs.recv() {
        if let Err(e) = session.load_params(&job.params) {
            return record_failure(&ctx.err, "eval stage (weight sync)", e);
        }
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        for b in ctx.batches.iter() {
            match session.eval_batch(&b.x, &b.y, &b.valid_mask) {
                Ok((l, m, c)) => {
                    sums.0 += l;
                    sums.1 += m;
                    sums.2 += c;
                }
                Err(e) => return record_failure(&ctx.err, "eval stage (eval_batch)", e),
            }
        }
        let count = sums.2.max(1.0);
        ctx.out.lock().expect("eval out").push(EvalRecord {
            step: job.step,
            epoch: 0,
            loss: sums.0 / count,
            metric: sums.1 / count,
        });
    }
}
