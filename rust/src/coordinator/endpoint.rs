//! Worker endpoints: the spawn/connect/handshake lifecycle behind the
//! fleet transport.
//!
//! A [`WorkerEndpoint`] is one live link to one `obftf worker` process,
//! independent of how the bytes travel:
//!
//! * [`LinkMode::Pipes`] — the worker's stdin/stdout (the PR-5 wiring);
//! * [`LinkMode::Unix`]  — a Unix-domain socket the worker listens on;
//! * [`LinkMode::Tcp`]   — a loopback TCP socket (`TCP_NODELAY`).
//!
//! Socket bootstrap: the leader passes `--listen <addr>` (for TCP,
//! port 0 — the kernel picks), the worker binds, prints one
//! `OBFTF_LISTEN <addr>` line on stdout and accepts exactly one
//! connection. The leader reads that line *under the fleet timeout* and
//! connects, so a hung or crashed listener surfaces as a contextual
//! error naming the endpoint instead of a silent stall. On every link
//! the worker's first frame is [`Frame::Hello`] (protocol version +
//! worker id), which the leader verifies before treating the endpoint
//! as live.
//!
//! [`EndpointSpawner`] captures everything needed to (re)create a
//! worker's endpoint, which is what makes the supervised-restart policy
//! in `ipc.rs` possible: respawning worker `w` at generation `g+1` is
//! one `spawner.spawn(w, g + 1, None, false)` call. A *late* worker
//! admitted mid-run spawns with `join = true`, which adds `--join` to
//! its argv: it announces [`Frame::Join`] instead of `Hello` and owns
//! no shard until its first `Reshard`.
//!
//! [`Frame::Join`]: crate::coordinator::proto::Frame::Join
//!
//! [`Frame::Hello`]: crate::coordinator::proto::Frame::Hello

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// How leader and worker exchange `coordinator::proto` frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkMode {
    /// Child stdin/stdout pipes.
    Pipes,
    /// Unix-domain socket (worker listens, leader connects).
    Unix,
    /// Loopback TCP socket (worker listens on an ephemeral port).
    Tcp,
}

impl LinkMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            LinkMode::Pipes => "pipes",
            LinkMode::Unix => "unix socket",
            LinkMode::Tcp => "tcp socket",
        }
    }
}

/// Everything needed to (re)spawn one worker's endpoint. Cloned into
/// the fleet transport so dead workers can be respawned mid-run.
#[derive(Clone, Debug)]
pub struct EndpointSpawner {
    pub bin: PathBuf,
    pub model: String,
    pub flavour: String,
    pub workers: usize,
    pub capacity: usize,
    pub max_age: u64,
    /// Scoring-forward precision the worker runs ("f32" | "bf16").
    /// The *param broadcast* precision needs no argv twin: workers
    /// detect a bf16 `ParamUpdate` from the wire dtype and expand on
    /// receipt, so a respawned worker at any generation stays correct
    /// whatever the leader's `param_precision`.
    pub score_precision: String,
    pub link: LinkMode,
    /// Bound on spawn-side waits (socket bootstrap line, connect).
    pub timeout: Duration,
}

/// One live leader↔worker link: the child process plus the write half
/// of its byte stream. The read half is handed to the transport's
/// reader thread at spawn time.
pub struct WorkerEndpoint {
    pub worker: usize,
    /// Incarnation counter: bumped on every supervised restart so stale
    /// events from a dead predecessor can be told apart.
    pub generation: u64,
    /// Human-readable endpoint name for contextual errors
    /// (e.g. `worker 1 gen 2 (unix socket /tmp/obftf-….sock)`).
    pub describe: String,
    child: Child,
    writer: Option<Box<dyn Write + Send>>,
}

impl WorkerEndpoint {
    /// Write raw frame bytes to the worker. The transport hands in a
    /// slice of its pooled per-connection encode buffer (or the shared
    /// pre-encoded param broadcast), so this path never copies or
    /// allocates — the endpoint must not buffer beyond the stream's own
    /// `BufWriter`.
    pub fn write_all(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        match self.writer.as_mut() {
            Some(w) => w.write_all(bytes),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "endpoint input already closed",
            )),
        }
    }

    /// Close the leader→worker half (EOF backup if a Shutdown was lost).
    pub fn close_input(&mut self) {
        self.writer.take();
    }

    /// Hand the write half to a dedicated writer thread (the overlapped
    /// leader's per-endpoint fan-out). Subsequent `write_all` calls on
    /// the endpoint itself fail `BrokenPipe`, so a stray serial-path
    /// write can never interleave with the thread's frames.
    pub fn take_writer(&mut self) -> Option<Box<dyn Write + Send>> {
        self.writer.take()
    }

    /// The child's exit status, for error context.
    pub fn status_string(&mut self) -> String {
        match self.child.try_wait() {
            Ok(Some(s)) => s.to_string(),
            Ok(None) => "still running".to_string(),
            Err(_) => "unknown".to_string(),
        }
    }

    /// Kill and reap the child (idempotent).
    pub fn reap(&mut self) {
        self.writer.take();
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for WorkerEndpoint {
    fn drop(&mut self) {
        self.reap();
    }
}

impl EndpointSpawner {
    /// Spawn worker `worker` at incarnation `generation`; returns the
    /// endpoint (write half) and the read half for a reader thread.
    /// Socket modes block — bounded by `timeout` — on the worker's
    /// bootstrap line and the connect; the Hello handshake itself is
    /// awaited by the transport's event loop.
    pub fn spawn(
        &self,
        worker: usize,
        generation: u64,
        fail_after: Option<u64>,
        join: bool,
    ) -> Result<(WorkerEndpoint, Box<dyn Read + Send>)> {
        let mut cmd = Command::new(&self.bin);
        cmd.arg("worker")
            .arg("--worker-id")
            .arg(worker.to_string())
            .arg("--workers")
            .arg(self.workers.to_string())
            .arg("--model")
            .arg(&self.model)
            .arg("--flavour")
            .arg(&self.flavour)
            .arg("--capacity")
            .arg(self.capacity.to_string())
            .arg("--max-age")
            .arg(self.max_age.to_string())
            .arg("--score-precision")
            .arg(&self.score_precision)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped());
        if let Some(k) = fail_after {
            cmd.arg("--fail-after").arg(k.to_string());
        }
        if join {
            cmd.arg("--join");
        }
        let listen = match self.link {
            LinkMode::Pipes => None,
            // generation-unique path: a restarted worker must never
            // race its dead predecessor's leftover bind
            LinkMode::Unix => Some(format!(
                "unix:{}",
                std::env::temp_dir()
                    .join(format!(
                        "obftf-{}-w{worker}-g{generation}.sock",
                        std::process::id()
                    ))
                    .display()
            )),
            LinkMode::Tcp => Some("tcp:127.0.0.1:0".to_string()),
        };
        if let Some(l) = &listen {
            cmd.arg("--listen").arg(l);
        }
        let mut child = cmd.spawn().with_context(|| {
            format!("spawning pipeline worker {worker} ({})", self.bin.display())
        })?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        let describe = |addr: &str| {
            format!("worker {worker} gen {generation} ({} {addr})", self.link.as_str())
        };
        match self.link {
            LinkMode::Pipes => Ok((
                WorkerEndpoint {
                    worker,
                    generation,
                    describe: format!("worker {worker} gen {generation} (pipes)"),
                    child,
                    writer: Some(Box::new(stdin)),
                },
                Box::new(stdout),
            )),
            LinkMode::Unix | LinkMode::Tcp => {
                let deadline = Instant::now() + self.timeout;
                let requested = listen.expect("socket mode has a listen addr");
                let addr = match read_bootstrap_line(stdout, deadline) {
                    Ok(a) => a,
                    Err(e) => {
                        let _ = child.kill();
                        let _ = child.wait();
                        return Err(e.context(format!(
                            "connecting to {} (listen {requested})",
                            describe(&requested)
                        )));
                    }
                };
                let ep = describe(&addr);
                match connect(self.link, &addr, deadline) {
                    Ok((writer, reader)) => Ok((
                        WorkerEndpoint {
                            worker,
                            generation,
                            describe: ep,
                            child,
                            writer: Some(writer),
                        },
                        reader,
                    )),
                    Err(e) => {
                        let _ = child.kill();
                        let _ = child.wait();
                        Err(e.context(format!("connecting to {ep}")))
                    }
                }
            }
        }
    }
}

/// Read the worker's `OBFTF_LISTEN <addr>` stdout line, bounded by
/// `deadline` (pipes have no read timeout, so the read runs on a helper
/// thread and the wait goes through a channel).
fn read_bootstrap_line(stdout: impl Read + Send + 'static, deadline: Instant) -> Result<String> {
    let (tx, rx) = mpsc::channel::<std::io::Result<String>>();
    std::thread::Builder::new()
        .name("obftf-bootstrap-rx".into())
        .spawn(move || {
            let mut line = String::new();
            let r = BufReader::new(stdout).read_line(&mut line).map(|_| line);
            let _ = tx.send(r);
        })
        .context("spawn bootstrap reader thread")?;
    let remain = deadline.saturating_duration_since(Instant::now());
    let line = match rx.recv_timeout(remain) {
        Ok(Ok(line)) => line,
        Ok(Err(e)) => return Err(e).context("reading socket bootstrap line"),
        Err(_) => bail!(
            "timed out after {remain:?} waiting for the worker's \
             OBFTF_LISTEN bootstrap line"
        ),
    };
    let addr = line
        .trim()
        .strip_prefix("OBFTF_LISTEN ")
        .with_context(|| format!("bad bootstrap line from worker: {line:?}"))?;
    Ok(addr.to_string())
}

/// Connect to a worker's listener; returns (write half, read half).
fn connect(
    link: LinkMode,
    addr: &str,
    deadline: Instant,
) -> Result<(Box<dyn Write + Send>, Box<dyn Read + Send>)> {
    match link {
        LinkMode::Pipes => unreachable!("pipes endpoints do not connect"),
        LinkMode::Unix => {
            let path = addr.strip_prefix("unix:").unwrap_or(addr);
            // the worker binds before printing the line, so the first
            // attempt normally succeeds; retry briefly anyway in case
            // the filesystem view lags the print
            let stream = loop {
                match UnixStream::connect(path) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            return Err(e)
                                .with_context(|| format!("connect to unix socket {path}"));
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                }
            };
            let writer = stream.try_clone().context("clone unix stream")?;
            Ok((Box::new(writer), Box::new(stream)))
        }
        LinkMode::Tcp => {
            let host = addr.strip_prefix("tcp:").unwrap_or(addr);
            let sock: std::net::SocketAddr = host
                .parse()
                .with_context(|| format!("bad tcp address {host:?}"))?;
            let remain = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(1));
            let stream = TcpStream::connect_timeout(&sock, remain)
                .with_context(|| format!("connect to tcp socket {host}"))?;
            stream.set_nodelay(true).context("TCP_NODELAY")?;
            let writer = stream.try_clone().context("clone tcp stream")?;
            Ok((Box::new(writer), Box::new(stream)))
        }
    }
}

/// Worker-side socket serving: bind `--listen <addr>`, print the
/// `OBFTF_LISTEN` bootstrap line, accept exactly one leader connection
/// and run the worker protocol loop over it.
pub fn serve_worker(cfg: &crate::coordinator::ipc::WorkerConfig, listen: &str) -> Result<()> {
    if let Some(path) = listen.strip_prefix("unix:") {
        // a stale path from a crashed predecessor would fail the bind
        let _ = std::fs::remove_file(path);
        let listener =
            UnixListener::bind(path).with_context(|| format!("binding unix socket {path}"))?;
        announce(&format!("unix:{path}"))?;
        let (stream, _) = listener
            .accept()
            .with_context(|| format!("accepting leader on unix socket {path}"))?;
        // connected: the filesystem name has done its job
        let _ = std::fs::remove_file(path);
        let input = BufReader::new(stream.try_clone().context("clone unix stream")?);
        let output = BufWriter::new(stream);
        crate::coordinator::ipc::run_worker(cfg, input, output)
    } else {
        let host = listen.strip_prefix("tcp:").unwrap_or(listen);
        let listener =
            TcpListener::bind(host).with_context(|| format!("binding tcp socket {host}"))?;
        let local = listener.local_addr().context("tcp local_addr")?;
        announce(&format!("tcp:{local}"))?;
        let (stream, _) = listener
            .accept()
            .with_context(|| format!("accepting leader on tcp socket {local}"))?;
        stream.set_nodelay(true).context("TCP_NODELAY")?;
        let input = BufReader::new(stream.try_clone().context("clone tcp stream")?);
        let output = BufWriter::new(stream);
        crate::coordinator::ipc::run_worker(cfg, input, output)
    }
}

fn announce(addr: &str) -> Result<()> {
    use std::io::Write as _;
    let mut out = std::io::stdout().lock();
    writeln!(out, "OBFTF_LISTEN {addr}").context("writing bootstrap line")?;
    out.flush().context("flushing bootstrap line")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_mode_names() {
        assert_eq!(LinkMode::Pipes.as_str(), "pipes");
        assert_eq!(LinkMode::Unix.as_str(), "unix socket");
        assert_eq!(LinkMode::Tcp.as_str(), "tcp socket");
    }

    #[test]
    fn bootstrap_line_parses_and_times_out() {
        let ok: &[u8] = b"OBFTF_LISTEN tcp:127.0.0.1:4312\n";
        let addr =
            read_bootstrap_line(ok, Instant::now() + Duration::from_secs(1)).unwrap();
        assert_eq!(addr, "tcp:127.0.0.1:4312");
        let bad: &[u8] = b"something else\n";
        let err = read_bootstrap_line(bad, Instant::now() + Duration::from_secs(1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("bad bootstrap line"), "{err:#}");
        // a reader that never produces the line hits the deadline
        let (never_tx, never_rx) = std::sync::mpsc::channel::<u8>();
        struct Never(std::sync::mpsc::Receiver<u8>);
        impl Read for Never {
            fn read(&mut self, _b: &mut [u8]) -> std::io::Result<usize> {
                let _ = self.0.recv(); // blocks until the sender drops
                Ok(0)
            }
        }
        let err = read_bootstrap_line(
            Never(never_rx),
            Instant::now() + Duration::from_millis(50),
        )
        .unwrap_err();
        drop(never_tx);
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    }

    /// The full socket bootstrap, hermetically: a thread plays the
    /// worker (bind → announce-style handoff → accept), the test plays
    /// the leader (connect), and one byte crosses each way.
    #[test]
    fn unix_connect_roundtrip() {
        let path = std::env::temp_dir().join(format!("obftf-test-{}.sock", std::process::id()));
        let path_s = path.display().to_string();
        let listener = UnixListener::bind(&path).unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut b = [0u8; 1];
            s.read_exact(&mut b).unwrap();
            s.write_all(&[b[0] + 1]).unwrap();
        });
        let (mut w, mut r) = connect(
            LinkMode::Unix,
            &format!("unix:{path_s}"),
            Instant::now() + Duration::from_secs(2),
        )
        .unwrap();
        w.write_all(&[41]).unwrap();
        w.flush().unwrap();
        let mut b = [0u8; 1];
        r.read_exact(&mut b).unwrap();
        assert_eq!(b[0], 42);
        server.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tcp_connect_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut b = [0u8; 1];
            s.read_exact(&mut b).unwrap();
            s.write_all(&[b[0] * 2]).unwrap();
        });
        let (mut w, mut r) = connect(
            LinkMode::Tcp,
            &format!("tcp:{addr}"),
            Instant::now() + Duration::from_secs(2),
        )
        .unwrap();
        w.write_all(&[21]).unwrap();
        w.flush().unwrap();
        let mut b = [0u8; 1];
        r.read_exact(&mut b).unwrap();
        assert_eq!(b[0], 42);
        server.join().unwrap();
    }
}
