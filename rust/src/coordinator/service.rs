//! Status/control plane for long-running training jobs.
//!
//! A production continuous-training subsystem must be observable while
//! it runs. [`StatusBoard`] is a cheap shared snapshot the trainer
//! updates each step; [`serve`] exposes it as one-line JSON over TCP on
//! a dedicated acceptor thread (`nc host port` or `obftf status` reads
//! it). Offline note: tokio is not in the vendored dependency set, so
//! the event loop is a std-net acceptor thread — same wire protocol.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Live snapshot of a training run.
#[derive(Clone, Debug, Default)]
pub struct Status {
    pub model: String,
    pub method: String,
    pub step: u64,
    pub sel_loss: f32,
    pub batch_loss: f32,
    pub realized_ratio: f64,
    pub steps_per_sec: f64,
    pub producer_blocked_ms: u64,
    /// Loss-cache counters (lookup granularity; `cache_stale` ⊆
    /// `cache_misses` — misses caused by age rather than absence).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_stale: u64,
    /// Milliseconds the pipeline's training stage spent blocked handing
    /// weight snapshots to the async-eval stage (serial modes: 0).
    pub eval_stall_ms: u64,
    /// Inference-fleet workers currently alive (threads or `obftf
    /// worker` child processes; serial modes: 0).
    pub workers_alive: u64,
    /// Fleet workers relaunched mid-run (always 0 under the current
    /// fail-fast policy; reserved for supervised restart).
    pub worker_restarts: u64,
    /// Per-worker scored-batch counts (from `WorkerStats` traffic).
    pub worker_scored: Vec<u64>,
    /// Wire frames the leader sent in the latest step (0 without a
    /// proc fleet).
    pub frames_per_step: u64,
    /// `ParamUpdate` bytes broadcast in the latest step (0 without a
    /// proc fleet; halved under `param_precision = bf16`).
    pub publish_bytes: u64,
    /// Reshard events so far (mid-run worker joins + retirements; 0
    /// without an elastic proc fleet).
    pub reshards: u64,
    /// Fleet members under the current ownership map (0 without a
    /// fleet; diverges from `workers_alive` only mid-transition).
    pub n_workers: u64,
    /// Entries evicted by the `cache_max_entries` bound (loss cache +
    /// routed-row journal; 0 when unbounded).
    pub evictions: u64,
    /// Wall time of the latest step's parameter publish (slowest
    /// writer under the overlapped leader; 0 without a proc fleet).
    pub publish_us: u64,
    /// Round-trip time of the `CacheLookup` fan-out serving the latest
    /// step (issue-to-merge, so prefetched lookups report the hidden
    /// RTT; 0 without a proc fleet).
    pub lookup_rtt_us: u64,
    pub done: bool,
}

impl Status {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::Str(self.model.clone()))
            .set("method", Json::Str(self.method.clone()))
            .set("step", Json::Num(self.step as f64))
            .set("sel_loss", Json::Num(self.sel_loss as f64))
            .set("batch_loss", Json::Num(self.batch_loss as f64))
            .set("realized_ratio", Json::Num(self.realized_ratio))
            .set("steps_per_sec", Json::Num(self.steps_per_sec))
            .set("producer_blocked_ms", Json::Num(self.producer_blocked_ms as f64))
            .set("cache_hits", Json::Num(self.cache_hits as f64))
            .set("cache_misses", Json::Num(self.cache_misses as f64))
            .set("cache_stale", Json::Num(self.cache_stale as f64))
            .set("cache_hit_rate", Json::Num(self.cache_hit_rate()))
            .set("eval_stall_ms", Json::Num(self.eval_stall_ms as f64))
            .set("workers_alive", Json::Num(self.workers_alive as f64))
            .set("worker_restarts", Json::Num(self.worker_restarts as f64))
            .set(
                "worker_scored",
                Json::Arr(self.worker_scored.iter().map(|&c| Json::Num(c as f64)).collect()),
            )
            .set("frames_per_step", Json::Num(self.frames_per_step as f64))
            .set("publish_bytes", Json::Num(self.publish_bytes as f64))
            .set("reshards", Json::Num(self.reshards as f64))
            .set("n_workers", Json::Num(self.n_workers as f64))
            .set("evictions", Json::Num(self.evictions as f64))
            .set("publish_us", Json::Num(self.publish_us as f64))
            .set("lookup_rtt_us", Json::Num(self.lookup_rtt_us as f64))
            .set("done", Json::Bool(self.done));
        j
    }

    /// Hit fraction over all cache lookups so far (0.0 before any).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn from_json(j: &Json) -> Result<Status> {
        Ok(Status {
            model: j.need("model")?.as_str()?.to_string(),
            method: j.need("method")?.as_str()?.to_string(),
            step: j.need("step")?.as_f64()? as u64,
            sel_loss: j.need("sel_loss")?.as_f64()? as f32,
            batch_loss: j.need("batch_loss")?.as_f64()? as f32,
            realized_ratio: j.need("realized_ratio")?.as_f64()?,
            steps_per_sec: j.need("steps_per_sec")?.as_f64()?,
            producer_blocked_ms: j.need("producer_blocked_ms")?.as_f64()? as u64,
            cache_hits: j.need("cache_hits")?.as_f64()? as u64,
            cache_misses: j.need("cache_misses")?.as_f64()? as u64,
            cache_stale: j.need("cache_stale")?.as_f64()? as u64,
            eval_stall_ms: j.need("eval_stall_ms")?.as_f64()? as u64,
            workers_alive: j.need("workers_alive")?.as_f64()? as u64,
            worker_restarts: j.need("worker_restarts")?.as_f64()? as u64,
            worker_scored: j
                .need("worker_scored")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_f64()? as u64))
                .collect::<Result<Vec<u64>>>()?,
            frames_per_step: j.need("frames_per_step")?.as_f64()? as u64,
            publish_bytes: j.need("publish_bytes")?.as_f64()? as u64,
            reshards: j.need("reshards")?.as_f64()? as u64,
            n_workers: j.need("n_workers")?.as_f64()? as u64,
            evictions: j.need("evictions")?.as_f64()? as u64,
            publish_us: j.need("publish_us")?.as_f64()? as u64,
            lookup_rtt_us: j.need("lookup_rtt_us")?.as_f64()? as u64,
            done: j.need("done")?.as_bool()?,
        })
    }
}

/// Shared, cheaply-clonable handle to the live status.
#[derive(Clone, Default)]
pub struct StatusBoard {
    inner: Arc<Mutex<Status>>,
}

impl StatusBoard {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&self, f: impl FnOnce(&mut Status)) {
        let mut s = self.inner.lock().expect("status lock");
        f(&mut s);
    }

    pub fn snapshot(&self) -> Status {
        self.inner.lock().expect("status lock").clone()
    }
}

/// Handle to a running status server; dropping stops the acceptor.
pub struct StatusServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Serve the board as one JSON line per connection. Bind with port 0 to
/// let the OS choose; the chosen address is in the returned handle.
pub fn serve(board: StatusBoard, addr: &str) -> Result<StatusServer> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let tstop = stop.clone();
    let handle = std::thread::Builder::new()
        .name("obftf-status".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if tstop.load(Ordering::Relaxed) {
                    return;
                }
                let Ok(mut sock) = conn else { continue };
                let line = board.snapshot().to_json().to_string_compact();
                let _ = sock.write_all(line.as_bytes());
                let _ = sock.write_all(b"\n");
            }
        })
        .context("spawn status thread")?;
    Ok(StatusServer { addr: local, stop, handle: Some(handle) })
}

/// Blocking one-shot client: read the status line from `addr`.
pub fn read_status(addr: &str) -> Result<Status> {
    let mut sock = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let mut buf = String::new();
    sock.read_to_string(&mut buf)?;
    let j = json::parse(buf.trim())?;
    Status::from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_update_and_snapshot() {
        let b = StatusBoard::new();
        b.update(|s| {
            s.step = 7;
            s.sel_loss = 0.5;
        });
        let snap = b.snapshot();
        assert_eq!(snap.step, 7);
        assert_eq!(snap.sel_loss, 0.5);
    }

    #[test]
    fn status_json_roundtrip() {
        let s = Status {
            model: "mlp".into(),
            method: "obftf".into(),
            step: 42,
            sel_loss: 1.25,
            batch_loss: 2.5,
            realized_ratio: 0.25,
            steps_per_sec: 10.0,
            producer_blocked_ms: 3,
            cache_hits: 30,
            cache_misses: 10,
            cache_stale: 4,
            eval_stall_ms: 17,
            workers_alive: 3,
            worker_restarts: 1,
            worker_scored: vec![12, 9, 21],
            frames_per_step: 6,
            publish_bytes: 2048,
            reshards: 2,
            n_workers: 3,
            evictions: 128,
            publish_us: 45,
            lookup_rtt_us: 260,
            done: true,
        };
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
        let j = s.to_json();
        assert!(j.to_string_compact().contains("cache_hit_rate"));
        let got = Status::from_json(&json::parse(&j.to_string_compact()).unwrap()).unwrap();
        assert_eq!(got.step, 42);
        assert_eq!(got.model, "mlp");
        assert_eq!(got.cache_hits, 30);
        assert_eq!(got.cache_misses, 10);
        assert_eq!(got.cache_stale, 4);
        assert_eq!(got.eval_stall_ms, 17);
        assert_eq!(got.workers_alive, 3);
        assert_eq!(got.worker_restarts, 1);
        assert_eq!(got.worker_scored, vec![12, 9, 21]);
        assert_eq!(got.frames_per_step, 6);
        assert_eq!(got.publish_bytes, 2048);
        assert_eq!(got.reshards, 2);
        assert_eq!(got.n_workers, 3);
        assert_eq!(got.evictions, 128);
        assert_eq!(got.publish_us, 45);
        assert_eq!(got.lookup_rtt_us, 260);
        assert!(got.done);
    }

    #[test]
    fn serve_and_read_roundtrip() {
        let board = StatusBoard::new();
        board.update(|s| {
            s.model = "mlp".into();
            s.step = 42;
        });
        let server = serve(board.clone(), "127.0.0.1:0").unwrap();
        let got = read_status(&server.addr.to_string()).unwrap();
        assert_eq!(got.step, 42);
        assert_eq!(got.model, "mlp");
        // live update visible on next connection
        board.update(|s| s.step = 43);
        let got = read_status(&server.addr.to_string()).unwrap();
        assert_eq!(got.step, 43);
        drop(server); // must not hang
    }
}
