//! Per-instance loss cache — the paper's production premise made
//! concrete.
//!
//! The abstract's key insight: deployed systems "continuously perform
//! forward passes on data instances during inference", so the training
//! subsystem can *record a constant amount of information per instance*
//! (the loss) from those passes instead of re-running its own forward.
//! [`LossCache`] is that record: per-example losses stamped with the
//! step that produced them. When every valid row of a batch has a
//! fresh-enough entry, the trainer skips the fwd_loss execution
//! entirely — the "ten forward" become free — at the cost of selecting
//! on slightly stale losses (the staleness/accuracy trade-off is the
//! `loss_max_age` knob, ablated in EXPERIMENTS.md).

/// Fixed-capacity per-example loss store, keyed by dataset index.
#[derive(Clone, Debug)]
pub struct LossCache {
    losses: Vec<f32>,
    /// Step at which each loss was recorded (`u64::MAX` = never).
    stamp: Vec<u64>,
    /// Max allowed age in steps (0 = any age accepted).
    max_age: u64,
    hits: u64,
    misses: u64,
}

impl LossCache {
    /// `capacity` = training-set size; `max_age` in steps (0 = ∞).
    pub fn new(capacity: usize, max_age: u64) -> Self {
        LossCache {
            losses: vec![0.0; capacity],
            stamp: vec![u64::MAX; capacity],
            max_age,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.losses.len()
    }

    /// `(hits, misses)` at the batch granularity.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    fn fresh(&self, id: usize, now: u64) -> bool {
        if id >= self.stamp.len() || self.stamp[id] == u64::MAX {
            return false;
        }
        self.max_age == 0 || now.saturating_sub(self.stamp[id]) <= self.max_age
    }

    /// If every valid row has a fresh loss, return the cached loss
    /// vector (padding rows filled with 0.0) — the "forward for free"
    /// path. Counts a hit/miss per call.
    pub fn lookup_batch(
        &mut self,
        ids: &[usize],
        valid: &[f32],
        now: u64,
    ) -> Option<Vec<f32>> {
        let all_fresh = ids
            .iter()
            .zip(valid)
            .filter(|(_, &m)| m > 0.0)
            .all(|(&id, _)| self.fresh(id, now));
        if !all_fresh {
            self.misses += 1;
            return None;
        }
        self.hits += 1;
        Some(
            ids.iter()
                .zip(valid)
                .map(|(&id, &m)| if m > 0.0 { self.losses[id] } else { 0.0 })
                .collect(),
        )
    }

    /// Record freshly computed losses for a batch.
    pub fn record_batch(&mut self, ids: &[usize], valid: &[f32], losses: &[f32], now: u64) {
        for ((&id, &m), &l) in ids.iter().zip(valid).zip(losses) {
            if m > 0.0 && id < self.losses.len() {
                self.losses[id] = l;
                self.stamp[id] = now;
            }
        }
    }

    /// Update entries for a subset of rows (e.g. the selected rows whose
    /// post-step loss the backward pass reported).
    pub fn invalidate(&mut self, ids: &[usize]) {
        for &id in ids {
            if id < self.stamp.len() {
                self.stamp[id] = u64::MAX;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_until_recorded_then_hit() {
        let mut c = LossCache::new(8, 0);
        let ids = [0, 1, 2, usize::MAX];
        let valid = [1.0, 1.0, 1.0, 0.0];
        assert!(c.lookup_batch(&ids, &valid, 0).is_none());
        c.record_batch(&ids, &valid, &[0.5, 0.6, 0.7, 9.9], 0);
        let got = c.lookup_batch(&ids, &valid, 1).unwrap();
        assert_eq!(got, vec![0.5, 0.6, 0.7, 0.0]); // padding zeroed
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn staleness_expires_entries() {
        let mut c = LossCache::new(4, 10);
        let ids = [0, 1];
        let valid = [1.0, 1.0];
        c.record_batch(&ids, &valid, &[1.0, 2.0], 0);
        assert!(c.lookup_batch(&ids, &valid, 10).is_some());
        assert!(c.lookup_batch(&ids, &valid, 11).is_none());
    }

    #[test]
    fn partial_coverage_is_a_miss() {
        let mut c = LossCache::new(4, 0);
        c.record_batch(&[0], &[1.0], &[1.0], 0);
        assert!(c.lookup_batch(&[0, 1], &[1.0, 1.0], 1).is_none());
        // but if the uncovered row is padding, it's a hit
        assert!(c.lookup_batch(&[0, 1], &[1.0, 0.0], 1).is_some());
    }

    #[test]
    fn invalidate_forces_refresh() {
        let mut c = LossCache::new(4, 0);
        let ids = [2, 3];
        let valid = [1.0, 1.0];
        c.record_batch(&ids, &valid, &[1.0, 2.0], 0);
        c.invalidate(&[3]);
        assert!(c.lookup_batch(&ids, &valid, 1).is_none());
    }

    #[test]
    fn out_of_range_ids_never_fresh() {
        let mut c = LossCache::new(2, 0);
        assert!(c.lookup_batch(&[5], &[1.0], 0).is_none());
        c.record_batch(&[5], &[1.0], &[1.0], 0); // silently ignored
        assert!(c.lookup_batch(&[5], &[1.0], 1).is_none());
    }
}
