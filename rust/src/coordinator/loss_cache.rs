//! Per-instance loss cache — the paper's production premise made
//! concrete.
//!
//! The abstract's key insight: deployed systems "continuously perform
//! forward passes on data instances during inference", so the training
//! subsystem can *record a constant amount of information per instance*
//! (the loss) from those passes instead of re-running its own forward.
//! [`LossCache`] is that record: per-example losses stamped with the
//! step that produced them. When every valid row of a batch has a
//! fresh-enough entry, the trainer skips the fwd_loss execution
//! entirely — the "ten forward" become free — at the cost of selecting
//! on slightly stale losses (the staleness/accuracy trade-off is the
//! `loss_max_age` knob, ablated in EXPERIMENTS.md).
//!
//! Two implementations share the freshness semantics:
//!
//! * [`LossCache`] — single-owner, used by the serial [`Trainer`]
//!   (the numerical oracle path);
//! * [`ShardedLossCache`] — N lock-striped shards keyed by dataset
//!   index, written concurrently by the pipeline's inference workers
//!   and read by the selection stage (`coordinator::pipeline`), with
//!   per-shard hit/miss/staleness row counters.
//!
//! The sharded variant optionally bounds its *live* entry count
//! ([`ShardedLossCache::with_max_entries`]): when a long stream touches
//! more distinct ids than the bound, the oldest-stamped entries are
//! evicted first (deterministically — ties break on the smaller slot),
//! so an async soak over millions of ids holds steady-state memory
//! instead of growing without limit.
//!
//! [`Trainer`]: crate::coordinator::Trainer

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Aggregated cache counters. For [`LossCache`] and
/// [`ShardedLossCache::stats`] the granularity is per *lookup* (one
/// batch lookup = one hit or one miss); [`ShardedLossCache::shard_stats`]
/// counts per *row* instead. `stale` counts lookups (rows) that failed
/// freshness although every row (the row) had been recorded — i.e.
/// misses caused by age rather than by absence.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stale: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups (0.0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// `stamp` value meaning "never recorded". Shared with the wire
/// protocol: a `CacheView` row (`coordinator::proto`) carries this stamp
/// when the owning worker has no entry for the id.
pub const NEVER: u64 = u64::MAX;

/// The one freshness rule every cache variant (serial, sharded,
/// distributed-ownership) applies: recorded, and within `max_age`
/// parameter versions of `now` (`max_age == 0` accepts any age).
#[inline]
pub fn is_fresh(stamp: u64, now: u64, max_age: u64) -> bool {
    stamp != NEVER && (max_age == 0 || now.saturating_sub(stamp) <= max_age)
}

/// Fixed-capacity per-example loss store, keyed by dataset index.
#[derive(Clone, Debug)]
pub struct LossCache {
    losses: Vec<f32>,
    /// Step at which each loss was recorded (`u64::MAX` = never).
    stamp: Vec<u64>,
    /// Max allowed age in steps (0 = any age accepted).
    max_age: u64,
    hits: u64,
    misses: u64,
    stale: u64,
}

impl LossCache {
    /// `capacity` = training-set size; `max_age` in steps (0 = ∞).
    pub fn new(capacity: usize, max_age: u64) -> Self {
        LossCache {
            losses: vec![0.0; capacity],
            stamp: vec![NEVER; capacity],
            max_age,
            hits: 0,
            misses: 0,
            stale: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.losses.len()
    }

    /// `(hits, misses)` at the batch granularity.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Full counters (batch granularity; `stale` ⊆ `misses`).
    pub fn counters(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses, stale: self.stale }
    }

    /// The recorded `(loss, stamp)` for one id, if any.
    pub fn entry(&self, id: usize) -> Option<(f32, u64)> {
        if id < self.stamp.len() && self.stamp[id] != NEVER {
            Some((self.losses[id], self.stamp[id]))
        } else {
            None
        }
    }

    fn fresh(&self, id: usize, now: u64) -> bool {
        if id >= self.stamp.len() {
            return false;
        }
        is_fresh(self.stamp[id], now, self.max_age)
    }

    /// If every valid row has a fresh loss, return the cached loss
    /// vector (padding rows filled with 0.0) — the "forward for free"
    /// path. Counts a hit/miss per call.
    pub fn lookup_batch(
        &mut self,
        ids: &[usize],
        valid: &[f32],
        now: u64,
    ) -> Option<Vec<f32>> {
        let all_fresh = ids
            .iter()
            .zip(valid)
            .filter(|(_, &m)| m > 0.0)
            .all(|(&id, _)| self.fresh(id, now));
        if !all_fresh {
            self.misses += 1;
            // age-caused miss: every valid row was recorded at some point
            let all_recorded = ids
                .iter()
                .zip(valid)
                .filter(|(_, &m)| m > 0.0)
                .all(|(&id, _)| id < self.stamp.len() && self.stamp[id] != NEVER);
            if all_recorded {
                self.stale += 1;
            }
            return None;
        }
        self.hits += 1;
        Some(
            ids.iter()
                .zip(valid)
                .map(|(&id, &m)| if m > 0.0 { self.losses[id] } else { 0.0 })
                .collect(),
        )
    }

    /// Record freshly computed losses for a batch.
    pub fn record_batch(&mut self, ids: &[usize], valid: &[f32], losses: &[f32], now: u64) {
        for ((&id, &m), &l) in ids.iter().zip(valid).zip(losses) {
            if m > 0.0 && id < self.losses.len() {
                self.losses[id] = l;
                self.stamp[id] = now;
            }
        }
    }

    /// Update entries for a subset of rows (e.g. the selected rows whose
    /// post-step loss the backward pass reported).
    pub fn invalidate(&mut self, ids: &[usize]) {
        for &id in ids {
            if id < self.stamp.len() {
                self.stamp[id] = NEVER;
            }
        }
    }

    /// Write one entry at its exact slot with an explicit stamp — the
    /// shard-migration path (`ShardTransfer` replay). A migrated row
    /// must keep the stamp its previous owner recorded, or freshness
    /// accounting would shift across a reshard. Out-of-range ids are
    /// ignored, exactly like [`LossCache::record_batch`].
    pub fn restore(&mut self, id: usize, loss: f32, stamp: u64) {
        if id < self.stamp.len() {
            self.losses[id] = loss;
            self.stamp[id] = stamp;
        }
    }

    /// Drop every recorded entry whose id fails the ownership
    /// predicate — applied when a reshard shrinks this worker's shard,
    /// so rows it no longer owns cannot leak into later `CacheView`
    /// replies with stale contents.
    pub fn retain_owned(&mut self, f: impl Fn(usize) -> bool) {
        for id in 0..self.stamp.len() {
            if self.stamp[id] != NEVER && !f(id) {
                self.stamp[id] = NEVER;
            }
        }
    }
}

/// Outcome of a non-counting [`ShardedLossCache::probe_batch`].
#[derive(Clone, Debug, PartialEq)]
pub enum CacheProbe {
    /// Every valid row fresh — the cached losses (padding rows 0.0).
    Fresh(Vec<f32>),
    /// Every valid row recorded, but at least one too old; `min_stamp`
    /// is the oldest stamp seen (the re-score watermark).
    Stale { min_stamp: u64 },
    /// At least one valid row was never recorded.
    Incomplete,
}

#[derive(Debug, Default)]
struct ShardSlots {
    losses: Vec<f32>,
    stamp: Vec<u64>,
    /// Live `(stamp, slot)` pairs, oldest first. Maintained only when
    /// the cache is bounded (`max_entries > 0`): the unbounded path
    /// stays index-free, and the bounded path evicts the oldest stamp
    /// in `O(log live)` instead of scanning the whole dense shard.
    live: BTreeSet<(u64, usize)>,
}

#[derive(Debug, Default)]
struct ShardCounters {
    hit_rows: AtomicU64,
    miss_rows: AtomicU64,
    stale_rows: AtomicU64,
}

/// Concurrent, lock-striped per-example loss store.
///
/// Dataset index `id` lives in shard `id % n_shards`, slot
/// `id / n_shards`, so contiguous batches spread their writes across
/// every stripe. Writers ([`ShardedLossCache::record_batch`]) and the
/// reader ([`ShardedLossCache::lookup_batch`] /
/// [`ShardedLossCache::probe_batch`]) take `&self`; each shard is an
/// independent mutex, locked at most once per call.
#[derive(Debug)]
pub struct ShardedLossCache {
    shards: Vec<Mutex<ShardSlots>>,
    row_counters: Vec<ShardCounters>,
    hits: AtomicU64,
    misses: AtomicU64,
    stale: AtomicU64,
    capacity: usize,
    max_age: u64,
    /// Bound on live entries across all shards (0 = unbounded). Each
    /// shard keeps at most `max(1, max_entries / n_shards)` entries.
    max_entries: u64,
    evictions: AtomicU64,
}

impl ShardedLossCache {
    /// `capacity` = training-set size; `max_age` in steps (0 = ∞);
    /// `n_shards` lock stripes (clamped to `[1, max(capacity, 1)]`).
    /// Unbounded — delegates to [`ShardedLossCache::with_max_entries`]
    /// with `max_entries = 0`.
    pub fn new(capacity: usize, max_age: u64, n_shards: usize) -> Self {
        Self::with_max_entries(capacity, max_age, n_shards, 0)
    }

    /// As [`ShardedLossCache::new`], plus a bound on live entries:
    /// when `max_entries > 0`, each shard evicts its oldest-stamped
    /// entries (ties break on the smaller slot, deterministically)
    /// whenever a `record_batch` pushes it past its share,
    /// `max(1, max_entries / n_shards)`.
    pub fn with_max_entries(
        capacity: usize,
        max_age: u64,
        n_shards: usize,
        max_entries: u64,
    ) -> Self {
        let n = n_shards.clamp(1, capacity.max(1));
        let shards = (0..n)
            .map(|k| {
                // shard k owns ids {k, k+n, k+2n, ...} < capacity
                let slots = capacity / n + usize::from(k < capacity % n);
                Mutex::new(ShardSlots {
                    losses: vec![0.0; slots],
                    stamp: vec![NEVER; slots],
                    live: BTreeSet::new(),
                })
            })
            .collect();
        ShardedLossCache {
            shards,
            row_counters: (0..n).map(|_| ShardCounters::default()).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stale: AtomicU64::new(0),
            capacity,
            max_age,
            max_entries,
            evictions: AtomicU64::new(0),
        }
    }

    /// Per-shard live-entry budget (`usize::MAX` when unbounded).
    fn shard_budget(&self) -> usize {
        if self.max_entries == 0 {
            usize::MAX
        } else {
            (self.max_entries / self.shards.len() as u64).max(1) as usize
        }
    }

    /// Entries evicted by the `max_entries` bound since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Live (recorded, non-evicted) entries across all shards. For the
    /// unbounded cache this scans every slot — telemetry/test use only.
    pub fn entries(&self) -> u64 {
        let mut total = 0u64;
        for shard in &self.shards {
            let slots = shard.lock().expect("shard lock");
            if self.max_entries > 0 {
                total += slots.live.len() as u64;
            } else {
                total += slots.stamp.iter().filter(|&&s| s != NEVER).count() as u64;
            }
        }
        total
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn max_age(&self) -> u64 {
        self.max_age
    }

    /// Lookup-granularity counters (one hit or miss per
    /// [`ShardedLossCache::lookup_batch`] call; `stale` ⊆ `misses`).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stale: self.stale.load(Ordering::Relaxed),
        }
    }

    /// Row-granularity counters for one shard (accumulated by counting
    /// lookups only, never by probes).
    pub fn shard_stats(&self, shard: usize) -> CacheStats {
        let c = &self.row_counters[shard];
        CacheStats {
            hits: c.hit_rows.load(Ordering::Relaxed),
            misses: c.miss_rows.load(Ordering::Relaxed),
            stale: c.stale_rows.load(Ordering::Relaxed),
        }
    }

    /// The recorded `(loss, stamp)` for one id, if any.
    pub fn entry(&self, id: usize) -> Option<(f32, u64)> {
        if id >= self.capacity {
            return None;
        }
        let n = self.shards.len();
        let slots = self.shards[id % n].lock().expect("shard lock");
        let i = id / n;
        if slots.stamp[i] != NEVER {
            Some((slots.losses[i], slots.stamp[i]))
        } else {
            None
        }
    }

    /// Bucket the valid, in-range rows of a batch by owning shard (one
    /// pass over the batch; each touched shard is then locked exactly
    /// once). Out-of-range valid rows are returned separately.
    fn bucket_rows(&self, ids: &[usize], valid: &[f32]) -> (Vec<Vec<u32>>, u32) {
        let n = self.shards.len();
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut out_of_range = 0u32;
        for (row, (&id, &m)) in ids.iter().zip(valid).enumerate() {
            if m <= 0.0 {
                continue;
            }
            if id >= self.capacity {
                out_of_range += 1;
            } else {
                buckets[id % n].push(row as u32);
            }
        }
        (buckets, out_of_range)
    }

    /// Record freshly computed losses for a batch (concurrent-safe;
    /// last writer per id wins). Out-of-range ids and padding rows are
    /// ignored, exactly like [`LossCache::record_batch`]. When the
    /// cache is bounded, a shard pushed past its budget evicts its
    /// oldest-stamped entries before the lock drops.
    pub fn record_batch(&self, ids: &[usize], valid: &[f32], losses: &[f32], now: u64) {
        let n = self.shards.len();
        let bounded = self.max_entries > 0;
        let budget = self.shard_budget();
        let (buckets, _) = self.bucket_rows(ids, valid);
        for (k, rows) in buckets.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let mut slots = self.shards[k].lock().expect("shard lock");
            for &row in rows {
                let id = ids[row as usize];
                let i = id / n;
                if bounded {
                    let old = slots.stamp[i];
                    if old != NEVER {
                        slots.live.remove(&(old, i));
                    }
                    slots.live.insert((now, i));
                }
                slots.losses[i] = losses[row as usize];
                slots.stamp[i] = now;
            }
            if bounded && slots.live.len() > budget {
                let mut evicted = 0u64;
                while slots.live.len() > budget {
                    let (_, i) = slots.live.pop_first().expect("non-empty live index");
                    slots.stamp[i] = NEVER;
                    evicted += 1;
                }
                self.evictions.fetch_add(evicted, Ordering::Relaxed);
            }
        }
    }

    /// Shared scan behind probe/lookup. Returns the loss vector (valid
    /// when `missing == 0 && stale_rows == 0`) plus per-row tallies.
    /// `exact` demands `stamp == now` instead of the age window — the
    /// synchronous-handoff freshness rule.
    fn scan(
        &self,
        ids: &[usize],
        valid: &[f32],
        now: u64,
        exact: bool,
        count_rows: bool,
    ) -> (Vec<f32>, usize, usize, u64) {
        let n = self.shards.len();
        let mut out = vec![0.0f32; ids.len()];
        // out-of-range valid rows are permanent misses, tallied under
        // shard 0 so they count exactly once
        let (buckets, out_of_range) = self.bucket_rows(ids, valid);
        let mut missing = out_of_range as usize;
        let mut stale_rows = 0usize;
        let mut min_stamp = NEVER;
        if count_rows && out_of_range > 0 {
            self.row_counters[0]
                .miss_rows
                .fetch_add(out_of_range as u64, Ordering::Relaxed);
        }
        for (k, rows) in buckets.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let (mut hit_k, mut miss_k, mut stale_k) = (0u64, 0u64, 0u64);
            let slots = self.shards[k].lock().expect("shard lock");
            for &row in rows {
                let i = ids[row as usize] / n;
                let stamp = slots.stamp[i];
                let fresh = if exact {
                    stamp == now
                } else {
                    is_fresh(stamp, now, self.max_age)
                };
                if stamp == NEVER {
                    missing += 1;
                    miss_k += 1;
                } else if fresh {
                    out[row as usize] = slots.losses[i];
                    min_stamp = min_stamp.min(stamp);
                    hit_k += 1;
                } else {
                    stale_rows += 1;
                    min_stamp = min_stamp.min(stamp);
                    miss_k += 1;
                    stale_k += 1;
                }
            }
            drop(slots);
            if count_rows {
                let c = &self.row_counters[k];
                c.hit_rows.fetch_add(hit_k, Ordering::Relaxed);
                c.miss_rows.fetch_add(miss_k, Ordering::Relaxed);
                c.stale_rows.fetch_add(stale_k, Ordering::Relaxed);
            }
        }
        (out, missing, stale_rows, min_stamp)
    }

    /// Non-counting freshness probe (the pipeline's wait loop polls
    /// this; only the first, counting [`ShardedLossCache::lookup_batch`]
    /// contributes to hit/miss statistics).
    pub fn probe_batch(&self, ids: &[usize], valid: &[f32], now: u64) -> CacheProbe {
        let (out, missing, stale_rows, min_stamp) = self.scan(ids, valid, now, false, false);
        if missing > 0 {
            CacheProbe::Incomplete
        } else if stale_rows > 0 {
            CacheProbe::Stale { min_stamp }
        } else {
            CacheProbe::Fresh(out)
        }
    }

    /// Exact-stamp probe: the losses only when every valid row was
    /// recorded at exactly `stamp`. This is the synchronous-handoff
    /// rule ("staleness forced to 0") — an entry written under any
    /// other parameter version does not count, which is what makes the
    /// sync pipeline bit-identical to the serial trainer. Non-counting.
    pub fn probe_stamped(&self, ids: &[usize], valid: &[f32], stamp: u64) -> Option<Vec<f32>> {
        let (out, missing, stale_rows, _) = self.scan(ids, valid, stamp, true, false);
        if missing == 0 && stale_rows == 0 {
            Some(out)
        } else {
            None
        }
    }

    /// All-or-nothing batch lookup with the same semantics as
    /// [`LossCache::lookup_batch`]; counts one aggregate hit/miss per
    /// call plus per-shard row counters.
    pub fn lookup_batch(&self, ids: &[usize], valid: &[f32], now: u64) -> Option<Vec<f32>> {
        let (out, missing, stale_rows, _) = self.scan(ids, valid, now, false, true);
        if missing == 0 && stale_rows == 0 {
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(out)
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            if missing == 0 {
                self.stale.fetch_add(1, Ordering::Relaxed);
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_until_recorded_then_hit() {
        let mut c = LossCache::new(8, 0);
        let ids = [0, 1, 2, usize::MAX];
        let valid = [1.0, 1.0, 1.0, 0.0];
        assert!(c.lookup_batch(&ids, &valid, 0).is_none());
        c.record_batch(&ids, &valid, &[0.5, 0.6, 0.7, 9.9], 0);
        let got = c.lookup_batch(&ids, &valid, 1).unwrap();
        assert_eq!(got, vec![0.5, 0.6, 0.7, 0.0]); // padding zeroed
        assert_eq!(c.stats(), (1, 1));
        // the initial miss was an absence, not an expiry
        assert_eq!(c.counters().stale, 0);
    }

    #[test]
    fn staleness_expires_entries_and_counts() {
        let mut c = LossCache::new(4, 10);
        let ids = [0, 1];
        let valid = [1.0, 1.0];
        c.record_batch(&ids, &valid, &[1.0, 2.0], 0);
        assert!(c.lookup_batch(&ids, &valid, 10).is_some());
        assert!(c.lookup_batch(&ids, &valid, 11).is_none());
        let stats = c.counters();
        assert_eq!((stats.hits, stats.misses, stats.stale), (1, 1, 1));
    }

    #[test]
    fn partial_coverage_is_a_miss() {
        let mut c = LossCache::new(4, 0);
        c.record_batch(&[0], &[1.0], &[1.0], 0);
        assert!(c.lookup_batch(&[0, 1], &[1.0, 1.0], 1).is_none());
        // but if the uncovered row is padding, it's a hit
        assert!(c.lookup_batch(&[0, 1], &[1.0, 0.0], 1).is_some());
    }

    #[test]
    fn invalidate_forces_refresh() {
        let mut c = LossCache::new(4, 0);
        let ids = [2, 3];
        let valid = [1.0, 1.0];
        c.record_batch(&ids, &valid, &[1.0, 2.0], 0);
        c.invalidate(&[3]);
        assert!(c.lookup_batch(&ids, &valid, 1).is_none());
    }

    #[test]
    fn out_of_range_ids_never_fresh() {
        let mut c = LossCache::new(2, 0);
        assert!(c.lookup_batch(&[5], &[1.0], 0).is_none());
        c.record_batch(&[5], &[1.0], &[1.0], 0); // silently ignored
        assert!(c.lookup_batch(&[5], &[1.0], 1).is_none());
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats { hits: 3, misses: 1, stale: 0 };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn sharded_matches_serial_on_a_serial_schedule() {
        let mut serial = LossCache::new(10, 5);
        let sharded = ShardedLossCache::new(10, 5, 3);
        let ids = [0, 3, 7, 9];
        let valid = [1.0, 1.0, 1.0, 1.0];
        let losses = [0.1, 0.3, 0.7, 0.9];
        serial.record_batch(&ids, &valid, &losses, 2);
        sharded.record_batch(&ids, &valid, &losses, 2);
        for now in [2u64, 7, 8] {
            assert_eq!(
                serial.lookup_batch(&ids, &valid, now),
                sharded.lookup_batch(&ids, &valid, now),
                "now={now}"
            );
        }
        for id in 0..10 {
            assert_eq!(serial.entry(id), sharded.entry(id), "id={id}");
        }
    }

    #[test]
    fn sharded_probe_classifies_missing_vs_stale() {
        let c = ShardedLossCache::new(8, 2, 4);
        let ids = [1, 5];
        let valid = [1.0, 1.0];
        assert_eq!(c.probe_batch(&ids, &valid, 0), CacheProbe::Incomplete);
        c.record_batch(&ids, &valid, &[0.5, 0.6], 1);
        assert_eq!(
            c.probe_batch(&ids, &valid, 2),
            CacheProbe::Fresh(vec![0.5, 0.6])
        );
        assert_eq!(
            c.probe_batch(&ids, &valid, 9),
            CacheProbe::Stale { min_stamp: 1 }
        );
        // probes never touch the counters
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn probe_stamped_requires_exact_version() {
        let c = ShardedLossCache::new(8, 0, 3);
        let ids = [0, 4];
        let valid = [1.0, 1.0];
        c.record_batch(&ids, &valid, &[0.1, 0.4], 3);
        // max_age = 0 (any age) would accept these — the exact probe
        // must not
        assert!(c.lookup_batch(&ids, &valid, 7).is_some());
        assert_eq!(c.probe_stamped(&ids, &valid, 7), None);
        assert_eq!(c.probe_stamped(&ids, &valid, 3), Some(vec![0.1, 0.4]));
        // partial re-stamp is still a refusal
        c.record_batch(&[0], &[1.0], &[0.9], 7);
        assert_eq!(c.probe_stamped(&ids, &valid, 7), None);
        c.record_batch(&[4], &[1.0], &[0.5], 7);
        assert_eq!(c.probe_stamped(&ids, &valid, 7), Some(vec![0.9, 0.5]));
    }

    #[test]
    fn sharded_counters_attribute_rows_to_shards() {
        let c = ShardedLossCache::new(6, 0, 2);
        // ids 0,2,4 → shard 0; ids 1,3,5 → shard 1
        c.record_batch(&[0, 1], &[1.0, 1.0], &[1.0, 2.0], 0);
        assert!(c.lookup_batch(&[0, 1, 2], &[1.0, 1.0, 1.0], 1).is_none());
        let s0 = c.shard_stats(0);
        let s1 = c.shard_stats(1);
        assert_eq!((s0.hits, s0.misses), (1, 1)); // id 0 hit, id 2 missing
        assert_eq!((s1.hits, s1.misses), (1, 0)); // id 1 hit
        let agg = c.stats();
        assert_eq!((agg.hits, agg.misses, agg.stale), (0, 1, 0));
    }

    #[test]
    fn sharded_out_of_range_ids_counted_once() {
        let c = ShardedLossCache::new(4, 0, 4);
        assert!(c.lookup_batch(&[99], &[1.0], 0).is_none());
        let total_miss_rows: u64 = (0..4).map(|k| c.shard_stats(k).misses).sum();
        assert_eq!(total_miss_rows, 1);
        c.record_batch(&[99], &[1.0], &[1.0], 0); // silently ignored
        assert!(c.lookup_batch(&[99], &[1.0], 1).is_none());
        assert_eq!(c.entry(99), None);
    }

    #[test]
    fn sharded_single_shard_degenerates_to_serial() {
        let mut serial = LossCache::new(5, 3);
        let sharded = ShardedLossCache::new(5, 3, 1);
        for (now, id) in [(0u64, 0usize), (1, 2), (4, 4), (9, 0)] {
            serial.record_batch(&[id], &[1.0], &[id as f32], now);
            sharded.record_batch(&[id], &[1.0], &[id as f32], now);
        }
        let ids = [0, 2, 4];
        let valid = [1.0; 3];
        for now in 0..12u64 {
            assert_eq!(
                serial.lookup_batch(&ids, &valid, now),
                sharded.lookup_batch(&ids, &valid, now),
                "now={now}"
            );
        }
    }

    #[test]
    fn shard_count_clamped_to_capacity() {
        let c = ShardedLossCache::new(2, 0, 64);
        assert_eq!(c.n_shards(), 2);
        let c = ShardedLossCache::new(0, 0, 4);
        assert_eq!(c.n_shards(), 1);
        assert!(c.lookup_batch(&[], &[], 0).is_some()); // vacuous hit
    }

    #[test]
    fn restore_keeps_the_transferred_stamp() {
        let mut c = LossCache::new(8, 0);
        c.restore(3, 0.25, 7);
        assert_eq!(c.entry(3), Some((0.25, 7)));
        // unlike record_batch, the stamp is the migrated one, not "now"
        c.record_batch(&[3], &[1.0], &[0.5], 9);
        assert_eq!(c.entry(3), Some((0.5, 9)));
        c.restore(99, 1.0, 0); // out of range: silently ignored
        assert_eq!(c.entry(99), None);
    }

    #[test]
    fn retain_owned_drops_exactly_the_disowned_ids() {
        let mut c = LossCache::new(6, 0);
        let ids = [0, 1, 2, 3, 4, 5];
        let valid = [1.0; 6];
        c.record_batch(&ids, &valid, &[0.0, 0.1, 0.2, 0.3, 0.4, 0.5], 2);
        // shrink ownership to even ids (a 2-shard reshard, position 0)
        c.retain_owned(|id| id % 2 == 0);
        for id in 0..6 {
            if id % 2 == 0 {
                assert_eq!(c.entry(id), Some((id as f32 * 0.1, 2)), "id={id}");
            } else {
                assert_eq!(c.entry(id), None, "id={id}");
            }
        }
    }

    #[test]
    fn bounded_cache_evicts_oldest_stamp_first() {
        let c = ShardedLossCache::with_max_entries(16, 0, 1, 4);
        for id in 0..8usize {
            c.record_batch(&[id], &[1.0], &[id as f32], id as u64);
        }
        assert_eq!(c.entries(), 4);
        assert_eq!(c.evictions(), 4);
        // survivors are the newest stamps, oldest went first
        for id in 0..4 {
            assert_eq!(c.entry(id), None, "id={id}");
        }
        for id in 4..8 {
            assert_eq!(c.entry(id), Some((id as f32, id as u64)), "id={id}");
        }
        // unbounded default keeps everything
        let u = ShardedLossCache::new(16, 0, 1);
        for id in 0..8usize {
            u.record_batch(&[id], &[1.0], &[id as f32], id as u64);
        }
        assert_eq!(u.entries(), 8);
        assert_eq!(u.evictions(), 0);
    }

    #[test]
    fn re_recording_an_entry_does_not_double_count() {
        let c = ShardedLossCache::with_max_entries(8, 0, 2, 8);
        for stamp in 0..5u64 {
            c.record_batch(&[1, 2], &[1.0, 1.0], &[0.1, 0.2], stamp);
        }
        assert_eq!(c.entries(), 2);
        assert_eq!(c.evictions(), 0);
        // the overwrite re-keyed the live index: the old stamp is gone,
        // so a later eviction pass orders by the *latest* stamp
        assert_eq!(c.entry(1), Some((0.1, 4)));
    }

    /// The eviction-bound property the async soak relies on: streaming
    /// over ≥1M distinct ids, the live entry count never exceeds the
    /// configured bound, for any shard count — and every recorded id is
    /// either still live or accounted for in `evictions`.
    #[test]
    fn eviction_bound_holds_over_a_million_distinct_ids() {
        const N: usize = 1 << 20; // 1,048,576 distinct ids
        const CHUNK: usize = 256;
        for (shards, bound) in [(1usize, 512u64), (4, 1024), (7, 333)] {
            let c = ShardedLossCache::with_max_entries(N, 0, shards, bound);
            let valid = [1.0f32; CHUNK];
            let losses = [0.5f32; CHUNK];
            let mut peak = 0u64;
            for (stamp, start) in (0..N).step_by(CHUNK).enumerate() {
                let ids: Vec<usize> = (start..start + CHUNK).collect();
                c.record_batch(&ids, &valid, &losses, stamp as u64);
                peak = peak.max(c.entries());
            }
            assert!(
                peak <= bound,
                "shards={shards} bound={bound}: peak live entries {peak}"
            );
            assert_eq!(
                c.evictions() + c.entries(),
                N as u64,
                "shards={shards}: every id must be live or evicted"
            );
        }
    }
}
