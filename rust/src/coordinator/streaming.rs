//! Serial streaming (continuous-training) mode: the paper's production
//! setting, where the model trains on an endless stream rather than
//! epochs over a finite set.
//!
//! The [`crate::data::stream::Prefetcher`] produces batches on its own
//! thread behind a bounded channel (backpressure); the trainer consumes
//! them and runs Algorithm 1 per batch. Stall accounting from the
//! prefetcher makes it observable whether ingestion or training is the
//! bottleneck. This is the *serial* baseline the staged
//! [`crate::coordinator::PipelineTrainer`] is benchmarked (and, in sync
//! mode, bit-for-bit verified) against.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::service::StatusBoard;
use crate::coordinator::trainer::{EvalResult, TrainReport, Trainer};
use crate::data::stream::Prefetcher;
use crate::metrics::EvalRecord;
use crate::runtime::Manifest;

/// Streaming driver wrapping a single-process [`Trainer`].
pub struct StreamingTrainer {
    trainer: Trainer,
    prefetcher: Prefetcher,
    steps: usize,
    eval_every_steps: usize,
}

impl StreamingTrainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<StreamingTrainer> {
        let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
        Self::with_manifest(cfg, &manifest)
    }

    pub fn with_manifest(cfg: &TrainConfig, manifest: &Manifest) -> Result<StreamingTrainer> {
        anyhow::ensure!(cfg.stream_steps > 0, "stream_steps must be > 0 for streaming mode");
        let trainer = Trainer::with_manifest(cfg, manifest)?;
        // the stream resamples the training split (with optional drift)
        let (train, _) = crate::coordinator::build_datasets(cfg)?;
        let source = crate::coordinator::stream_source(cfg, train);
        let prefetcher =
            Prefetcher::spawn(source, manifest.batch, cfg.prefetch_depth);
        let eval_every_steps = if cfg.eval_every > 0 {
            (cfg.stream_steps / cfg.eval_every.max(1)).max(1)
        } else {
            0
        };
        Ok(StreamingTrainer {
            trainer,
            prefetcher,
            steps: cfg.stream_steps,
            eval_every_steps,
        })
    }

    /// Producer-side stall time (ns) — nonzero means training is the
    /// bottleneck and backpressure engaged (healthy); a large consumer
    /// wait would instead show up as low steps/sec with zero stall.
    pub fn producer_blocked_ns(&self) -> u64 {
        self.prefetcher
            .stats
            .blocked_ns
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Run `stream_steps` batches from the stream.
    pub fn run(&mut self) -> Result<TrainReport> {
        let board = StatusBoard::new();
        self.run_with_board(&board)
    }

    /// Run, publishing per-step state to `board` (the live status
    /// endpoint) and checkpointing at the eval cadence when configured.
    pub fn run_with_board(&mut self, board: &StatusBoard) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        for s in 0..self.steps {
            let batch = self.prefetcher.next();
            let rec = self.trainer.step_batch(&batch)?;
            let blocked_ms = self.producer_blocked_ns() / 1_000_000;
            let ratio = self.trainer.budget.realized_ratio();
            let cache = self.trainer.cache_counters();
            board.update(|st| {
                st.step = rec.step + 1;
                st.sel_loss = rec.sel_loss;
                st.batch_loss = rec.batch_loss;
                st.realized_ratio = ratio;
                st.steps_per_sec = (s + 1) as f64 / t0.elapsed().as_secs_f64();
                st.producer_blocked_ms = blocked_ms;
                st.cache_hits = cache.hits;
                st.cache_misses = cache.misses;
                st.cache_stale = cache.stale;
            });
            if self.eval_every_steps > 0 && (s + 1) % self.eval_every_steps == 0 {
                let ev: EvalResult = self.trainer.evaluate()?;
                let step = self.trainer.step_count();
                self.trainer.recorder.record_eval(EvalRecord {
                    step,
                    epoch: 0,
                    loss: ev.loss,
                    metric: ev.metric,
                });
                if let Some(path) = self.trainer.cfg.checkpoint.clone() {
                    self.trainer.save_checkpoint(std::path::Path::new(&path))?;
                }
            }
        }
        self.trainer.report()
    }
}
