//! Leader/worker sync data-parallel trainer (the paper's 32-GPU setup,
//! scaled to worker threads with private PJRT clients).
//!
//! Dataflow per step — identical numerics to the serial [`Trainer`]:
//!
//! ```text
//!   leader: shard batch ── x,y ──▶ workers: fwd_loss   (parallel)
//!   leader: gather losses, run selection (global batch order)
//!   leader: shard mask ── x,y,m ──▶ workers: grads     (parallel)
//!   leader: weighted-average grads (k_w / K), broadcast apply
//! ```

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::budget::BudgetTracker;
use crate::coordinator::build_datasets;
use crate::coordinator::trainer::{EvalResult, TrainReport};
use crate::data::dataset::{Batch, BatchIter, InMemoryDataset};
use crate::data::rng::Rng;
use crate::data::shard::{gather_losses, shard_batch, shard_mask};
use crate::metrics::{EvalRecord, Recorder, StepRecord};
use crate::runtime::engine::weighted_average_grads;
use crate::runtime::{Engine, Flavour, Manifest};
use crate::sampling::{budget_for, selection_mask, Sampler};

/// Data-parallel trainer over an [`Engine`] worker pool.
pub struct ParallelTrainer {
    pub cfg: TrainConfig,
    engine: Engine,
    sampler: Box<dyn Sampler>,
    train: InMemoryDataset,
    test: InMemoryDataset,
    rng: Rng,
    pub recorder: Recorder,
    pub budget: BudgetTracker,
    batch_size: usize,
    step: u64,
    epoch: usize,
}

impl ParallelTrainer {
    pub fn from_config(cfg: &TrainConfig) -> Result<ParallelTrainer> {
        let manifest = Manifest::load_or_native(&crate::artifacts_dir())?;
        Self::with_manifest(cfg, &manifest)
    }

    pub fn with_manifest(cfg: &TrainConfig, manifest: &Manifest) -> Result<ParallelTrainer> {
        cfg.validate()?;
        let flavour: Flavour = manifest.resolve_flavour(&cfg.flavour)?;
        let engine = Engine::new(manifest, &cfg.model, flavour, cfg.workers)
            .context("building worker engine")?;
        engine.init_broadcast(cfg.seed as i32)?;
        let (train, test) = build_datasets(cfg)?;
        let sampler = cfg.method.build(cfg.gamma);
        // IMPORTANT: same rng derivation as Trainer so parallel == serial
        let rng = crate::coordinator::selection_rng(cfg);
        Ok(ParallelTrainer {
            cfg: cfg.clone(),
            engine,
            sampler,
            train,
            test,
            rng,
            recorder: Recorder::new(),
            budget: BudgetTracker::new(),
            batch_size: manifest.batch,
            step: 0,
            epoch: 0,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.engine.n_workers()
    }

    /// One data-parallel Algorithm-1 iteration.
    pub fn step_batch(&mut self, batch: &Batch) -> Result<StepRecord> {
        let n = batch.batch_size();
        let shards = shard_batch(batch, self.engine.n_workers())?;

        // (1) sharded forward
        let t0 = Instant::now();
        let fwd_in: Vec<_> = shards
            .iter()
            .map(|s| (s.batch.x.clone(), s.batch.y.clone()))
            .collect();
        let per_shard = self.engine.fwd_loss_sharded(fwd_in)?;
        let losses = gather_losses(&shards, &per_shard, n);
        let fwd_us = t0.elapsed().as_micros() as u64;

        // (2) global selection on the leader
        let t1 = Instant::now();
        let b = budget_for(self.cfg.sampling_ratio, batch.real);
        let selected = self.sampler.select(&losses, &batch.valid_mask, b, &mut self.rng);
        let mask = selection_mask(&selected, n);
        let sel_us = t1.elapsed().as_micros() as u64;

        // (3) sharded backward + leader reduce + broadcast apply
        let t2 = Instant::now();
        let mut counts = Vec::with_capacity(shards.len());
        let grads_in: Vec<_> = shards
            .iter()
            .map(|s| {
                let local = shard_mask(s, &mask);
                counts.push(local.iter().filter(|&&m| m > 0.0).count());
                (s.batch.x.clone(), s.batch.y.clone(), local)
            })
            .collect();
        let per_worker = self.engine.grads_sharded(grads_in)?;
        let (avg, sel_loss) = weighted_average_grads(&per_worker, &counts)?;
        self.engine.apply_broadcast(&avg, self.cfg.lr)?;
        let bwd_us = t2.elapsed().as_micros() as u64;

        let batch_loss = super::masked_mean_loss(&losses, &batch.valid_mask);

        self.budget.record_step(batch.real, selected.len());
        let rec = StepRecord {
            step: self.step,
            epoch: self.epoch,
            sel_loss,
            batch_loss,
            n_forward: batch.real,
            n_selected: selected.len(),
            fwd_us,
            sel_us,
            bwd_us,
            cache_hits: 0,
            cache_misses: 0,
            cache_stale: 0,
            sel_hash: crate::sampling::selection_hash(&selected),
            workers_alive: 0,
            worker_restarts: 0,
            frames_per_step: 0,
            publish_bytes: 0,
            reshards: 0,
            n_workers: 0,
            publish_us: 0,
            lookup_rtt_us: 0,
        };
        self.recorder.record_step(rec);
        self.step += 1;
        Ok(rec)
    }

    pub fn run_epoch(&mut self) -> Result<()> {
        let mut shuffle_rng = self.rng.split();
        let batches: Vec<Batch> =
            BatchIter::new(&self.train, self.batch_size, Some(&mut shuffle_rng)).collect();
        for b in &batches {
            self.step_batch(b)?;
        }
        self.epoch += 1;
        Ok(())
    }

    /// Sharded evaluation over the test split.
    pub fn evaluate(&mut self) -> Result<EvalResult> {
        let batches = self.test.batches(self.batch_size);
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        for b in &batches {
            let shards = shard_batch(b, self.engine.n_workers())?;
            let ev_in: Vec<_> = shards
                .iter()
                .map(|s| {
                    (
                        s.batch.x.clone(),
                        s.batch.y.clone(),
                        s.batch.valid_mask.clone(),
                    )
                })
                .collect();
            let (l, m, c) = self.engine.eval_sharded(ev_in)?;
            sums.0 += l;
            sums.1 += m;
            sums.2 += c;
        }
        let count = sums.2.max(1.0);
        Ok(EvalResult { loss: sums.0 / count, metric: sums.1 / count })
    }

    /// Fetch current parameters (e.g. to compare against the serial
    /// trainer in tests).
    pub fn params_to_host(&self) -> Result<Vec<crate::data::HostTensor>> {
        self.engine.params_to_host()
    }

    pub fn run(&mut self) -> Result<TrainReport> {
        for e in 0..self.cfg.epochs {
            self.run_epoch()?;
            let is_last = e + 1 == self.cfg.epochs;
            if is_last
                || (self.cfg.eval_every > 0 && (e + 1) % self.cfg.eval_every == 0)
            {
                let ev = self.evaluate()?;
                self.recorder.record_eval(EvalRecord {
                    step: self.step,
                    epoch: self.epoch,
                    loss: ev.loss,
                    metric: ev.metric,
                });
            }
        }
        let final_eval = match self.recorder.evals.last() {
            Some(e) => EvalResult { loss: e.loss, metric: e.metric },
            None => self.evaluate()?,
        };
        let (fwd, bwd) = self.recorder.totals();
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            method: self.cfg.method.as_str().to_string(),
            sampling_ratio: self.cfg.sampling_ratio,
            epochs: self.epoch,
            steps: self.step,
            final_eval,
            evals: self.recorder.evals.clone(),
            forward_examples: fwd,
            backward_examples: bwd,
            realized_ratio: self.budget.realized_ratio(),
            saved_fraction: self.budget.saved_fraction(),
            steps_per_sec: self.recorder.throughput(),
            latency_summary: self.recorder.latency_summary(),
        })
    }
}
