//! Wire protocol for the multi-process inference fleet.
//!
//! Every stage handoff that crosses a process boundary is one of these
//! typed frames, carried over a byte stream (today: the worker child's
//! stdin/stdout pipes; the frame layer is transport-agnostic so a
//! socket works the same way). The codec is hand-rolled — the offline
//! dependency set has no serde — and deliberately boring:
//!
//! ```text
//!   [len: u32 LE] [tag: u8] [payload…]      (len counts tag + payload)
//! ```
//!
//! All integers are little-endian; f32 payloads are raw IEEE-754 bits,
//! so losses and weight snapshots cross the boundary bit-identically
//! (the sync-mode pipeline-equivalence guarantee depends on this).
//! Decoding rejects truncated frames, unknown tags, trailing bytes and
//! implausible lengths without allocating for them.
//!
//! Frame vocabulary (leader ⇄ worker):
//!
//! * [`Frame::Hello`]        worker → leader: the first frame on every
//!   link — protocol version + worker id, checked by the leader before
//!   the endpoint is considered live (the socket/pipe handshake);
//! * [`Frame::ParamUpdate`]  leader → worker: versioned weight snapshot
//!   (the `ParamStore` publish crossing the boundary);
//! * [`Frame::ScoreBatch`]   leader → worker: run `fwd_loss` on a batch;
//! * [`Frame::LossRecords`]  worker → leader: the scored rows, stamped
//!   with the scorer's parameter version; also leader → worker to route
//!   rows to the shard owner (`id % n_workers`);
//! * [`Frame::CacheLookup`]  leader → worker: per-row view request over
//!   the worker's owned loss-cache shards;
//! * [`Frame::CacheView`]    worker → leader: `(row, loss, stamp)` for
//!   the owned rows of a lookup;
//! * [`Frame::Shutdown`]     leader → worker: drain and exit;
//! * [`Frame::WorkerStats`]  worker → leader: final work counters;
//! * [`Frame::Join`]         late worker → leader: the `Hello` of a
//!   worker spawned into an already-running fleet (`obftf worker
//!   --join`); the leader folds it in with a reshard;
//! * [`Frame::Reshard`]      leader → worker: epoch-tagged ownership
//!   map — the active worker slots in order. Receivers recompute their
//!   shard index and invalidate rows they no longer own;
//! * [`Frame::ShardTransfer`] leader → worker: one shard's journal
//!   rows (`(id, loss, stamp)`, sorted by `(stamp, id)`) migrated to
//!   their owner after a reshard or a supervised restart.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::data::dataset::Batch;
use crate::data::tensor::HostTensor;
use crate::runtime::backend::ScorePrecision;

/// Wire-protocol version carried in the [`Frame::Hello`] handshake.
/// Bump on any incompatible frame-layout change; the leader refuses a
/// worker announcing a different version.
pub const PROTO_VERSION: u32 = 1;

/// Hard ceiling on one frame's encoded size (tag + payload). Large
/// enough for any batch or weight snapshot we ship (64 MiB); small
/// enough that a corrupted length prefix from a bad peer is rejected
/// outright — and the body is read incrementally, so even an in-range
/// garbage length can never size a giant allocation up front.
pub const MAX_FRAME_BYTES: usize = 1 << 26;

/// Row id wire value for "padding row / no id" (`usize::MAX` host-side).
pub const NO_ID: u64 = u64::MAX;

/// One `(row position, loss, stamp)` entry of a [`Frame::CacheView`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ViewRow {
    /// Row index within the looked-up batch.
    pub pos: u32,
    pub loss: f32,
    /// Parameter version the loss was recorded under
    /// ([`crate::coordinator::loss_cache::NEVER`] = never recorded).
    pub stamp: u64,
}

/// A worker's cumulative work counters (shipped on shutdown; the leader
/// also tracks live per-worker counts from `LossRecords` traffic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker: u32,
    /// `ScoreBatch` frames executed.
    pub scored_batches: u64,
    /// Real (non-padding) rows forwarded.
    pub scored_rows: u64,
    /// Rows recorded into this worker's owned shards (own scores plus
    /// rows routed from other scorers).
    pub recorded_rows: u64,
    /// `CacheLookup` frames served.
    pub lookups: u64,
}

/// A typed protocol frame (see module docs for direction and intent).
#[derive(Clone, Debug)]
pub enum Frame {
    /// First frame on every link, worker → leader: announce protocol
    /// version and worker id so the leader can reject a mismatched
    /// binary (or a crossed wire) before any state crosses it.
    Hello {
        proto: u32,
        worker: u32,
    },
    ScoreBatch {
        seq: u64,
        batch: Batch,
    },
    LossRecords {
        /// The `ScoreBatch` sequence this answers (`u64::MAX` when the
        /// leader routes rows to their shard owner).
        seq: u64,
        /// Worker that computed the losses.
        worker: u32,
        /// Parameter version the losses were computed under.
        stamp: u64,
        /// Dataset ids of the real rows (no padding entries).
        ids: Vec<u64>,
        /// Losses parallel to `ids`.
        losses: Vec<f32>,
    },
    ParamUpdate {
        version: u64,
        weights: Vec<HostTensor>,
    },
    CacheLookup {
        req: u64,
        /// Current step / parameter version the freshness rule is
        /// evaluated against (leader-side; workers only echo views).
        now: u64,
        /// Exact-stamp (sync oracle) lookup rather than an age window.
        exact: bool,
        /// Per-row dataset id, [`NO_ID`] for padding rows, so view
        /// positions map 1:1 onto batch rows.
        ids: Vec<u64>,
    },
    CacheView {
        req: u64,
        worker: u32,
        /// Entries for the requested rows this worker owns.
        rows: Vec<ViewRow>,
    },
    Shutdown,
    WorkerStats(WorkerStats),
    /// First frame of a worker spawned into a *running* fleet (`obftf
    /// worker --join`): same contract as [`Frame::Hello`], but the
    /// leader knows to admit the slot with a reshard instead of
    /// expecting it in the spawn-time handshake.
    Join {
        proto: u32,
        worker: u32,
    },
    /// Epoch-tagged ownership map, leader → every active worker after a
    /// join/leave reshard (and to a respawned worker whose fleet's
    /// membership is no longer the spawn-time identity map). `members`
    /// lists the active worker slots in shard order: member `k` owns
    /// ids with `id % members.len() == k`.
    Reshard {
        epoch: u64,
        members: Vec<u64>,
    },
    /// One shard's rows migrated to their (new) owner: parallel
    /// `(id, loss, stamp)` triples, sorted by `(stamp, id)` so replay
    /// order is deterministic. Receivers overwrite exactly (stamps
    /// included) and do **not** count these toward `recorded_rows` —
    /// migration is bookkeeping, not new work.
    ShardTransfer {
        epoch: u64,
        worker: u32,
        ids: Vec<u64>,
        losses: Vec<f32>,
        stamps: Vec<u64>,
    },
    /// Envelope coalescing several frames into one write/read, so the
    /// per-step routed `LossRecords` fan-out rides the selection-time
    /// `CacheLookup` in a single syscall per worker. One level deep
    /// only — a nested `Batch` member is a protocol error.
    Batch(Vec<Frame>),
}

const TAG_SCORE_BATCH: u8 = 1;
const TAG_LOSS_RECORDS: u8 = 2;
const TAG_PARAM_UPDATE: u8 = 3;
const TAG_CACHE_LOOKUP: u8 = 4;
const TAG_CACHE_VIEW: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_WORKER_STATS: u8 = 7;
const TAG_HELLO: u8 = 8;
const TAG_BATCH: u8 = 9;
const TAG_JOIN: u8 = 10;
const TAG_RESHARD: u8 = 11;
const TAG_SHARD_TRANSFER: u8 = 12;

impl Frame {
    /// Frame name for diagnostics ("worker 2 died after ScoreBatch").
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::ScoreBatch { .. } => "ScoreBatch",
            Frame::LossRecords { .. } => "LossRecords",
            Frame::ParamUpdate { .. } => "ParamUpdate",
            Frame::CacheLookup { .. } => "CacheLookup",
            Frame::CacheView { .. } => "CacheView",
            Frame::Shutdown => "Shutdown",
            Frame::WorkerStats(_) => "WorkerStats",
            Frame::Join { .. } => "Join",
            Frame::Reshard { .. } => "Reshard",
            Frame::ShardTransfer { .. } => "ShardTransfer",
            Frame::Batch(_) => "Batch",
        }
    }

    /// Append this frame's body (tag + payload, no length prefix).
    fn encode_body(&self, body: &mut Vec<u8>) {
        match self {
            Frame::Hello { proto, worker } => {
                body.push(TAG_HELLO);
                put_u32(body, *proto);
                put_u32(body, *worker);
            }
            Frame::ScoreBatch { seq, batch } => {
                body.push(TAG_SCORE_BATCH);
                put_u64(body, *seq);
                put_batch(body, batch);
            }
            Frame::LossRecords { seq, worker, stamp, ids, losses } => {
                put_loss_records_body(body, *seq, *worker, *stamp, ids, losses);
            }
            Frame::ParamUpdate { version, weights } => {
                // count + per-tensor wire form (matches `tensors_to_bytes`);
                // bf16 tensors carry their own dtype tag, so a decoded bf16
                // broadcast re-encodes byte-identically
                body.push(TAG_PARAM_UPDATE);
                put_u64(body, *version);
                put_u64(body, weights.len() as u64);
                for t in weights {
                    t.encode_into(body);
                }
            }
            Frame::CacheLookup { req, now, exact, ids } => {
                put_cache_lookup_body(body, *req, *now, *exact, ids);
            }
            Frame::CacheView { req, worker, rows } => {
                put_cache_view_body(body, *req, *worker, rows);
            }
            Frame::Shutdown => body.push(TAG_SHUTDOWN),
            Frame::WorkerStats(s) => {
                body.push(TAG_WORKER_STATS);
                put_u32(body, s.worker);
                put_u64(body, s.scored_batches);
                put_u64(body, s.scored_rows);
                put_u64(body, s.recorded_rows);
                put_u64(body, s.lookups);
            }
            Frame::Join { proto, worker } => {
                body.push(TAG_JOIN);
                put_u32(body, *proto);
                put_u32(body, *worker);
            }
            Frame::Reshard { epoch, members } => {
                put_reshard_body(body, *epoch, members);
            }
            Frame::ShardTransfer { epoch, worker, ids, losses, stamps } => {
                put_shard_transfer_body(body, *epoch, *worker, ids, losses, stamps);
            }
            Frame::Batch(members) => {
                body.push(TAG_BATCH);
                put_u64(body, members.len() as u64);
                for m in members {
                    debug_assert!(
                        !matches!(m, Frame::Batch(_)),
                        "Batch envelopes do not nest"
                    );
                    let at = body.len();
                    body.extend_from_slice(&[0u8; 4]);
                    m.encode_body(body);
                    let mlen = body.len() - at - 4;
                    body[at..at + 4].copy_from_slice(&(mlen as u32).to_le_bytes());
                }
            }
        }
    }

    /// Encode as a complete length-prefixed frame into a caller-owned
    /// buffer (cleared first). The pooled hot path: steady-state writes
    /// reuse one warm scratch buffer per connection and allocate nothing.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; 4]);
        self.encode_body(out);
        patch_frame_len(out);
    }

    /// Encode as a complete length-prefixed frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        self.encode_into(&mut out);
        out
    }

    /// Decode a frame body (the bytes after the length prefix). Rejects
    /// unknown tags, truncation and trailing bytes. Payload vectors are
    /// freshly allocated; the steady-state transports use
    /// [`Frame::decode_pooled`] instead.
    pub fn decode(body: &[u8]) -> Result<Frame> {
        Frame::decode_pooled(body, &mut FramePools::default())
    }

    /// [`Frame::decode`] drawing payload vectors (`ids`/`losses`/
    /// `rows`/envelope member lists) from a reusable pool instead of
    /// the allocator. Once the pool has warmed to the connection's
    /// traffic shape, decoding a payload frame allocates nothing —
    /// callers return vectors via [`FramePools::recycle`] when done.
    pub fn decode_pooled(body: &[u8], pools: &mut FramePools) -> Result<Frame> {
        let mut r = Reader { b: body, pos: 0 };
        let tag = r.u8().context("frame tag")?;
        let frame = match tag {
            TAG_HELLO => Frame::Hello { proto: r.u32()?, worker: r.u32()? },
            TAG_SCORE_BATCH => {
                let seq = r.u64()?;
                let batch = get_batch(&mut r)?;
                Frame::ScoreBatch { seq, batch }
            }
            TAG_LOSS_RECORDS => {
                let seq = r.u64()?;
                let worker = r.u32()?;
                let stamp = r.u64()?;
                let mut ids = pools.get_u64s();
                r.u64s_into(&mut ids)?;
                let mut losses = pools.get_f32s();
                r.f32s_into(&mut losses)?;
                if ids.len() != losses.len() {
                    bail!("LossRecords: {} ids vs {} losses", ids.len(), losses.len());
                }
                Frame::LossRecords { seq, worker, stamp, ids, losses }
            }
            TAG_PARAM_UPDATE => {
                let version = r.u64()?;
                let weights = crate::data::tensor::tensors_from_bytes(r.rest())
                    .context("ParamUpdate weights")?;
                return Ok(Frame::ParamUpdate { version, weights });
            }
            TAG_CACHE_LOOKUP => {
                let req = r.u64()?;
                let now = r.u64()?;
                let exact = match r.u8()? {
                    0 => false,
                    1 => true,
                    other => bail!("CacheLookup: bad bool byte {other}"),
                };
                let mut ids = pools.get_u64s();
                r.u64s_into(&mut ids)?;
                Frame::CacheLookup { req, now, exact, ids }
            }
            TAG_CACHE_VIEW => {
                let req = r.u64()?;
                let worker = r.u32()?;
                let n = r.len_prefix(4 + 4 + 8)?;
                let mut rows = pools.get_views();
                rows.reserve(n);
                for _ in 0..n {
                    rows.push(ViewRow { pos: r.u32()?, loss: r.f32()?, stamp: r.u64()? });
                }
                Frame::CacheView { req, worker, rows }
            }
            TAG_SHUTDOWN => Frame::Shutdown,
            TAG_WORKER_STATS => Frame::WorkerStats(WorkerStats {
                worker: r.u32()?,
                scored_batches: r.u64()?,
                scored_rows: r.u64()?,
                recorded_rows: r.u64()?,
                lookups: r.u64()?,
            }),
            TAG_JOIN => Frame::Join { proto: r.u32()?, worker: r.u32()? },
            TAG_RESHARD => {
                let epoch = r.u64()?;
                let mut members = pools.get_u64s();
                r.u64s_into(&mut members)?;
                if members.is_empty() {
                    bail!("Reshard: empty membership");
                }
                Frame::Reshard { epoch, members }
            }
            TAG_SHARD_TRANSFER => {
                let epoch = r.u64()?;
                let worker = r.u32()?;
                let mut ids = pools.get_u64s();
                r.u64s_into(&mut ids)?;
                let mut losses = pools.get_f32s();
                r.f32s_into(&mut losses)?;
                let mut stamps = pools.get_u64s();
                r.u64s_into(&mut stamps)?;
                if ids.len() != losses.len() || ids.len() != stamps.len() {
                    bail!(
                        "ShardTransfer: {} ids vs {} losses vs {} stamps",
                        ids.len(),
                        losses.len(),
                        stamps.len()
                    );
                }
                Frame::ShardTransfer { epoch, worker, ids, losses, stamps }
            }
            TAG_BATCH => {
                // each member needs at least a 4-byte length + 1 tag byte
                let n = r.len_prefix(5)?;
                let mut members = pools.get_frames();
                members.reserve(n);
                for i in 0..n {
                    let mlen = r.u32()? as usize;
                    let mbody = r
                        .take(mlen)
                        .with_context(|| format!("batch member {i}/{n}"))?;
                    let m = Frame::decode_pooled(mbody, pools)
                        .with_context(|| format!("batch member {i}/{n}"))?;
                    if matches!(m, Frame::Batch(_)) {
                        bail!("nested Batch envelope (member {i}/{n})");
                    }
                    members.push(m);
                }
                Frame::Batch(members)
            }
            other => bail!("unknown frame tag {other}"),
        };
        r.done()?;
        Ok(frame)
    }
}

/// Reusable payload-vector pools for the decode side of the wire path.
/// A decoded frame's `ids`/`losses`/`rows` vectors and envelope member
/// lists are drawn from here ([`Frame::decode_pooled`]) and handed back
/// via [`recycle`](FramePools::recycle) once the frame is handled, so
/// the steady state allocates nothing per frame — closing the PR-8
/// residual that pinned decode at one allocation per payload vector.
#[derive(Default)]
pub struct FramePools {
    u64s: Vec<Vec<u64>>,
    f32s: Vec<Vec<f32>>,
    views: Vec<Vec<ViewRow>>,
    frames: Vec<Vec<Frame>>,
}

impl FramePools {
    pub fn new() -> FramePools {
        FramePools::default()
    }

    fn get_u64s(&mut self) -> Vec<u64> {
        self.u64s.pop().unwrap_or_default()
    }

    fn get_f32s(&mut self) -> Vec<f32> {
        self.f32s.pop().unwrap_or_default()
    }

    fn get_views(&mut self) -> Vec<ViewRow> {
        self.views.pop().unwrap_or_default()
    }

    fn get_frames(&mut self) -> Vec<Frame> {
        self.frames.pop().unwrap_or_default()
    }

    pub fn recycle_u64s(&mut self, mut v: Vec<u64>) {
        v.clear();
        self.u64s.push(v);
    }

    pub fn recycle_f32s(&mut self, mut v: Vec<f32>) {
        v.clear();
        self.f32s.push(v);
    }

    pub fn recycle_views(&mut self, mut v: Vec<ViewRow>) {
        v.clear();
        self.views.push(v);
    }

    /// Return every payload vector a handled frame owns to the pools
    /// (envelope members are recursed). Frames without pooled payloads
    /// are simply dropped.
    pub fn recycle(&mut self, frame: Frame) {
        match frame {
            Frame::LossRecords { ids, losses, .. } => {
                self.recycle_u64s(ids);
                self.recycle_f32s(losses);
            }
            Frame::CacheLookup { ids, .. } => self.recycle_u64s(ids),
            Frame::CacheView { rows, .. } => self.recycle_views(rows),
            Frame::Reshard { members, .. } => self.recycle_u64s(members),
            Frame::ShardTransfer { ids, losses, stamps, .. } => {
                self.recycle_u64s(ids);
                self.recycle_f32s(losses);
                self.recycle_u64s(stamps);
            }
            Frame::Batch(mut members) => {
                for m in members.drain(..) {
                    self.recycle(m);
                }
                self.frames.push(members);
            }
            _ => {}
        }
    }
}

// -- borrowed zero-allocation encoders --------------------------------------
//
// Complete length-prefixed frames written into a caller-owned buffer
// (cleared first) from borrowed payload slices — no `Frame` is built,
// no `Vec` is returned. These are the steady-state hot paths: once the
// scratch buffers are warm, encoding allocates nothing. Each delegates
// to the same `put_*_body` writer as [`Frame::encode`], so the two
// encodings cannot drift.

/// Start a length-prefixed frame in `out` (cleared, prefix reserved).
fn begin_frame(out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
}

/// Patch the reserved length prefix once the body is complete.
fn patch_frame_len(out: &mut Vec<u8>) {
    let len = out.len() - 4;
    debug_assert!(len <= MAX_FRAME_BYTES);
    out[..4].copy_from_slice(&(len as u32).to_le_bytes());
}

/// Encode a complete `LossRecords` frame from borrowed rows (worker
/// score replies; leader route flushes on shutdown).
pub fn encode_loss_records_into(
    seq: u64,
    worker: u32,
    stamp: u64,
    ids: &[u64],
    losses: &[f32],
    out: &mut Vec<u8>,
) {
    begin_frame(out);
    put_loss_records_body(out, seq, worker, stamp, ids, losses);
    patch_frame_len(out);
}

/// Encode a complete `CacheView` frame from borrowed rows (worker
/// lookup replies).
pub fn encode_cache_view_into(req: u64, worker: u32, rows: &[ViewRow], out: &mut Vec<u8>) {
    begin_frame(out);
    put_cache_view_body(out, req, worker, rows);
    patch_frame_len(out);
}

/// Encode a complete `CacheLookup` frame from borrowed ids (the
/// leader's selection-time fan-out when no routes are pending).
pub fn encode_cache_lookup_into(req: u64, now: u64, exact: bool, ids: &[u64], out: &mut Vec<u8>) {
    begin_frame(out);
    put_cache_lookup_body(out, req, now, exact, ids);
    patch_frame_len(out);
}

/// Encode a complete `Reshard` frame from a borrowed membership list
/// (the leader's ownership-map broadcast after a join/leave).
pub fn encode_reshard_into(epoch: u64, members: &[u64], out: &mut Vec<u8>) {
    begin_frame(out);
    put_reshard_body(out, epoch, members);
    patch_frame_len(out);
}

/// Encode a complete `ShardTransfer` frame from borrowed parallel
/// `(id, loss, stamp)` columns (the leader's shard migration / re-warm
/// path; callers pre-sort by `(stamp, id)`).
pub fn encode_shard_transfer_into(
    epoch: u64,
    worker: u32,
    ids: &[u64],
    losses: &[f32],
    stamps: &[u64],
    out: &mut Vec<u8>,
) {
    debug_assert!(ids.len() == losses.len() && ids.len() == stamps.len());
    begin_frame(out);
    put_shard_transfer_body(out, epoch, worker, ids, losses, stamps);
    patch_frame_len(out);
}

/// Encode a complete `ParamUpdate` frame directly from a borrowed
/// weight snapshot into a caller-owned buffer. The leader's publish
/// encodes once per training step and broadcasts the same bytes to
/// every worker; this path avoids cloning the tensors into a [`Frame`]
/// just to serialize them. With `precision = bf16` each f32 tensor is
/// RNE-rounded to the half-size dtype-2 wire form
/// ([`HostTensor::encode_as_bf16_into`]); workers expand on receipt.
/// At f32 the bytes are identical to [`Frame::encode`] on the
/// equivalent `ParamUpdate` (covered by a test, so the encodings
/// cannot drift).
pub fn encode_param_update_into(
    version: u64,
    weights: &[HostTensor],
    precision: ScorePrecision,
    out: &mut Vec<u8>,
) {
    begin_frame(out);
    out.push(TAG_PARAM_UPDATE);
    put_u64(out, version);
    put_u64(out, weights.len() as u64);
    for t in weights {
        match precision {
            ScorePrecision::F32 => t.encode_into(out),
            ScorePrecision::Bf16 => t.encode_as_bf16_into(out),
        }
    }
    patch_frame_len(out);
}

/// Allocating convenience wrapper around [`encode_param_update_into`].
pub fn encode_param_update(
    version: u64,
    weights: &[HostTensor],
    precision: ScorePrecision,
) -> Vec<u8> {
    let mut out = Vec::new();
    encode_param_update_into(version, weights, precision, &mut out);
    out
}

/// Incremental encoder for a [`Frame::Batch`] envelope built from
/// borrowed payloads — the leader's per-worker coalescing path. Usage:
/// [`EnvelopeEncoder::begin`] on a (reused) scratch buffer, one
/// `member_*` call per coalesced frame, then [`EnvelopeEncoder::finish`]
/// to patch the member count and outer length prefix. Byte-identical to
/// encoding the equivalent `Frame::Batch`, without building the frames.
pub struct EnvelopeEncoder<'a> {
    buf: &'a mut Vec<u8>,
    count_at: usize,
    members: u64,
}

impl<'a> EnvelopeEncoder<'a> {
    pub fn begin(buf: &'a mut Vec<u8>) -> EnvelopeEncoder<'a> {
        begin_frame(buf);
        buf.push(TAG_BATCH);
        let count_at = buf.len();
        buf.extend_from_slice(&[0u8; 8]);
        EnvelopeEncoder { buf, count_at, members: 0 }
    }

    fn begin_member(&mut self) -> usize {
        let at = self.buf.len();
        self.buf.extend_from_slice(&[0u8; 4]);
        at
    }

    fn end_member(&mut self, at: usize) {
        let mlen = self.buf.len() - at - 4;
        self.buf[at..at + 4].copy_from_slice(&(mlen as u32).to_le_bytes());
        self.members += 1;
    }

    pub fn member_loss_records(
        &mut self,
        seq: u64,
        worker: u32,
        stamp: u64,
        ids: &[u64],
        losses: &[f32],
    ) {
        let at = self.begin_member();
        put_loss_records_body(self.buf, seq, worker, stamp, ids, losses);
        self.end_member(at);
    }

    pub fn member_cache_lookup(&mut self, req: u64, now: u64, exact: bool, ids: &[u64]) {
        let at = self.begin_member();
        put_cache_lookup_body(self.buf, req, now, exact, ids);
        self.end_member(at);
    }

    /// Number of members written so far.
    pub fn members(&self) -> u64 {
        self.members
    }

    pub fn finish(self) {
        self.buf[self.count_at..self.count_at + 8]
            .copy_from_slice(&self.members.to_le_bytes());
        patch_frame_len(self.buf);
    }
}

/// Write one frame; returns the bytes written (length prefix included).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<usize> {
    let bytes = frame.encode();
    w.write_all(&bytes)
        .with_context(|| format!("writing {} frame", frame.name()))?;
    Ok(bytes.len())
}

/// Read one frame into a caller-owned (reused) body buffer. `Ok(None)`
/// on clean EOF at a frame boundary; truncation inside a frame is an
/// error. Returns the frame and its total wire size (length prefix
/// included). Once `body` has warmed to the connection's largest frame,
/// the framing layer itself allocates nothing per frame.
pub fn read_frame_into(r: &mut impl Read, body: &mut Vec<u8>) -> Result<Option<(Frame, usize)>> {
    match read_body(r, body)? {
        None => Ok(None),
        Some(len) => Ok(Some((Frame::decode(body)?, 4 + len))),
    }
}

/// [`read_frame_into`] decoding payload vectors out of a reusable
/// [`FramePools`] — the fully pooled steady state: warm framing buffer
/// + warm pools = zero heap allocations per payload frame.
pub fn read_frame_pooled(
    r: &mut impl Read,
    body: &mut Vec<u8>,
    pools: &mut FramePools,
) -> Result<Option<(Frame, usize)>> {
    match read_body(r, body)? {
        None => Ok(None),
        Some(len) => Ok(Some((Frame::decode_pooled(body, pools)?, 4 + len))),
    }
}

/// The framing layer alone: read one length-prefixed body into the
/// reused buffer, returning its length (`None` on clean EOF) without
/// decoding. Public for callers that must separate the (blocking) body
/// read from the decode — e.g. a fleet reader thread that decodes under
/// a shared [`FramePools`] lock but must not hold that lock across a
/// blocking socket read.
pub fn read_frame_body(r: &mut impl Read, body: &mut Vec<u8>) -> Result<Option<usize>> {
    read_body(r, body)
}

/// The shared framing layer: read one length-prefixed body into the
/// reused buffer, returning its length (`None` on clean EOF).
fn read_body(r: &mut impl Read, body: &mut Vec<u8>) -> Result<Option<usize>> {
    let mut len_buf = [0u8; 4];
    // distinguish EOF-at-boundary from EOF-mid-prefix by hand
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut len_buf[got..]).context("reading frame length")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("stream ended inside a frame length prefix ({got}/4 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        bail!("implausible frame length {len} (cap {MAX_FRAME_BYTES})");
    }
    // read incrementally via a bounded take: a garbage length prefix
    // that slipped under the cap fails at the stream's real end instead
    // of sizing a `len`-byte buffer up front on the peer's say-so. The
    // +1 keeps spare capacity nonzero after a full read, so a warm
    // buffer never reallocates on `read_to_end`'s final zero-probe.
    body.clear();
    body.reserve(len.min(1 << 16) + 1);
    r.take(len as u64)
        .read_to_end(body)
        .context("reading frame body")?;
    if body.len() != len {
        bail!("frame body truncated (wanted {len} bytes, got {})", body.len());
    }
    Ok(Some(len))
}

/// [`read_frame_into`] with a throwaway body buffer (tests, handshake).
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Frame, usize)>> {
    let mut body = Vec::new();
    read_frame_into(r, &mut body)
}

// -- payload primitives ----------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64s(buf: &mut Vec<u8>, vs: &[u64]) {
    put_u64(buf, vs.len() as u64);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u64(buf, vs.len() as u64);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

// frame-body writers shared by `Frame::encode_body` and the borrowed
// zero-allocation encoders above, so the encodings cannot drift

fn put_loss_records_body(
    buf: &mut Vec<u8>,
    seq: u64,
    worker: u32,
    stamp: u64,
    ids: &[u64],
    losses: &[f32],
) {
    buf.push(TAG_LOSS_RECORDS);
    put_u64(buf, seq);
    put_u32(buf, worker);
    put_u64(buf, stamp);
    put_u64s(buf, ids);
    put_f32s(buf, losses);
}

fn put_cache_lookup_body(buf: &mut Vec<u8>, req: u64, now: u64, exact: bool, ids: &[u64]) {
    buf.push(TAG_CACHE_LOOKUP);
    put_u64(buf, req);
    put_u64(buf, now);
    buf.push(u8::from(exact));
    put_u64s(buf, ids);
}

fn put_reshard_body(buf: &mut Vec<u8>, epoch: u64, members: &[u64]) {
    buf.push(TAG_RESHARD);
    put_u64(buf, epoch);
    put_u64s(buf, members);
}

fn put_shard_transfer_body(
    buf: &mut Vec<u8>,
    epoch: u64,
    worker: u32,
    ids: &[u64],
    losses: &[f32],
    stamps: &[u64],
) {
    buf.push(TAG_SHARD_TRANSFER);
    put_u64(buf, epoch);
    put_u32(buf, worker);
    put_u64s(buf, ids);
    put_f32s(buf, losses);
    put_u64s(buf, stamps);
}

fn put_cache_view_body(buf: &mut Vec<u8>, req: u64, worker: u32, rows: &[ViewRow]) {
    buf.push(TAG_CACHE_VIEW);
    put_u64(buf, req);
    put_u32(buf, worker);
    put_u64(buf, rows.len() as u64);
    for r in rows {
        put_u32(buf, r.pos);
        buf.extend_from_slice(&r.loss.to_le_bytes());
        put_u64(buf, r.stamp);
    }
}

fn put_batch(buf: &mut Vec<u8>, b: &Batch) {
    b.x.encode_into(buf);
    b.y.encode_into(buf);
    put_f32s(buf, &b.valid_mask);
    put_u64(buf, b.real as u64);
    put_u64(buf, b.ids.len() as u64);
    for &i in &b.ids {
        let wire = if i == usize::MAX { NO_ID } else { i as u64 };
        buf.extend_from_slice(&wire.to_le_bytes());
    }
}

fn get_batch(r: &mut Reader) -> Result<Batch> {
    let (x, used) = HostTensor::decode_from(r.rest()).context("batch x")?;
    r.pos += used;
    let (y, used) = HostTensor::decode_from(r.rest()).context("batch y")?;
    r.pos += used;
    let valid_mask = r.f32s().context("batch valid_mask")?;
    let real = r.u64()? as usize;
    let wire_ids = r.u64s().context("batch ids")?;
    let rows = *x.shape.first().unwrap_or(&0);
    if valid_mask.len() != rows || wire_ids.len() != rows {
        bail!(
            "batch rows disagree: x {rows}, valid {}, ids {}",
            valid_mask.len(),
            wire_ids.len()
        );
    }
    if y.shape != vec![rows] {
        bail!("batch y shape {:?} != [{rows}]", y.shape);
    }
    if real > rows {
        bail!("batch real {real} > rows {rows}");
    }
    let ids = wire_ids
        .into_iter()
        .map(|i| if i == NO_ID { usize::MAX } else { i as usize })
        .collect();
    Ok(Batch { x, y, valid_mask, real, ids })
}

/// Bounded little-endian payload reader.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn rest(&self) -> &'a [u8] {
        &self.b[self.pos..]
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let Some(s) = self.b.get(self.pos..self.pos + n) else {
            bail!("payload truncated at byte {} (wanted {n} more)", self.pos);
        };
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    /// A `u64` element count, validated against the bytes that actually
    /// remain (`elem_bytes` each) so corrupt counts cannot allocate.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let remain = (self.b.len() - self.pos) as u64;
        if n > remain / elem_bytes as u64 {
            bail!("length {n} exceeds remaining payload ({remain} bytes)");
        }
        Ok(n as usize)
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        self.u64s_into(&mut out)?;
        Ok(out)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.f32s_into(&mut out)?;
        Ok(out)
    }

    /// Length-prefixed u64 run into a caller-owned (pooled) vector.
    fn u64s_into(&mut self, out: &mut Vec<u64>) -> Result<()> {
        let n = self.len_prefix(8)?;
        out.reserve(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(())
    }

    /// Length-prefixed f32 run into a caller-owned (pooled) vector.
    fn f32s_into(&mut self, out: &mut Vec<f32>) -> Result<()> {
        let n = self.len_prefix(4)?;
        out.reserve(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("{} trailing bytes in frame payload", self.b.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let mut cur = Cursor::new(bytes.clone());
        let (back, used) = read_frame(&mut cur).unwrap().expect("one frame");
        assert_eq!(used, bytes.len());
        // re-encoding must be byte-identical (covers NaN payloads where
        // PartialEq would lie)
        assert_eq!(back.encode(), bytes, "{} re-encode differs", f.name());
        back
    }

    #[test]
    fn hello_roundtrips_and_carries_version() {
        let got = roundtrip(&Frame::Hello { proto: PROTO_VERSION, worker: 3 });
        let Frame::Hello { proto, worker } = got else { panic!("wrong frame") };
        assert_eq!((proto, worker), (PROTO_VERSION, 3));
    }

    #[test]
    fn over_cap_length_prefix_rejected_before_any_read() {
        // a length prefix one past the cap must fail on the prefix
        // alone — the (empty) body is never consulted
        let bytes = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        let err = read_frame(&mut Cursor::new(bytes)).unwrap_err();
        assert!(format!("{err:#}").contains("implausible frame length"));
        // an in-range but lying length fails at the stream's real end
        // (incremental read), not with a huge up-front allocation
        let mut bytes = ((MAX_FRAME_BYTES) as u32).to_le_bytes().to_vec();
        bytes.push(TAG_SHUTDOWN);
        let err = read_frame(&mut Cursor::new(bytes)).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn scalar_frames_roundtrip() {
        roundtrip(&Frame::Shutdown);
        let got = roundtrip(&Frame::WorkerStats(WorkerStats {
            worker: 3,
            scored_batches: 10,
            scored_rows: 1280,
            recorded_rows: 640,
            lookups: 4,
        }));
        let Frame::WorkerStats(s) = got else { panic!("wrong frame") };
        assert_eq!(s.worker, 3);
        assert_eq!(s.scored_rows, 1280);
    }

    #[test]
    fn loss_records_roundtrip_including_nan() {
        let got = roundtrip(&Frame::LossRecords {
            seq: u64::MAX,
            worker: 1,
            stamp: 7,
            ids: vec![0, 5, 11],
            losses: vec![f32::NAN, 0.5, -0.0],
        });
        let Frame::LossRecords { losses, .. } = got else { panic!("wrong frame") };
        assert!(losses[0].is_nan());
        assert_eq!(losses[2].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn cache_frames_roundtrip() {
        roundtrip(&Frame::CacheLookup {
            req: 9,
            now: u64::MAX - 1,
            exact: true,
            ids: vec![4, NO_ID, 2],
        });
        roundtrip(&Frame::CacheView {
            req: 9,
            worker: 0,
            rows: vec![
                ViewRow { pos: 0, loss: 1.5, stamp: 3 },
                ViewRow { pos: 2, loss: 0.0, stamp: u64::MAX },
            ],
        });
    }

    #[test]
    fn score_batch_roundtrip_maps_padding_ids() {
        let batch = Batch {
            x: HostTensor::f32(vec![3, 2], vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0]).unwrap(),
            y: HostTensor::i32(vec![3], vec![1, 0, 0]).unwrap(),
            valid_mask: vec![1.0, 1.0, 0.0],
            real: 2,
            ids: vec![10, 4, usize::MAX],
        };
        let got = roundtrip(&Frame::ScoreBatch { seq: 42, batch });
        let Frame::ScoreBatch { seq, batch } = got else { panic!("wrong frame") };
        assert_eq!(seq, 42);
        assert_eq!(batch.ids, vec![10, 4, usize::MAX]);
        assert_eq!(batch.real, 2);
    }

    #[test]
    fn param_update_roundtrip() {
        let ws = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap(),
            HostTensor::f32(vec![2], vec![0.1, 0.2]).unwrap(),
        ];
        let got = roundtrip(&Frame::ParamUpdate { version: 12, weights: ws.clone() });
        let Frame::ParamUpdate { version, weights } = got else { panic!("wrong frame") };
        assert_eq!(version, 12);
        assert_eq!(weights.len(), 2);
        // the borrowed hot-path encoder and the Frame encoder agree
        assert_eq!(
            encode_param_update(12, &ws, ScorePrecision::F32),
            Frame::ParamUpdate { version: 12, weights: ws }.encode()
        );
    }

    #[test]
    fn bf16_param_update_halves_and_reencodes_byte_identically() {
        let ws = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, -2.7, f32::NAN, f32::INFINITY]).unwrap(),
            HostTensor::f32(vec![3], vec![0.1, -0.0, f32::NEG_INFINITY]).unwrap(),
        ];
        let f32_bytes = encode_param_update(7, &ws, ScorePrecision::F32);
        let bf_bytes = encode_param_update(7, &ws, ScorePrecision::Bf16);
        assert!(bf_bytes.len() < f32_bytes.len());
        let mut cur = Cursor::new(bf_bytes.clone());
        let (frame, used) = read_frame(&mut cur).unwrap().expect("one frame");
        assert_eq!(used, bf_bytes.len());
        let Frame::ParamUpdate { version, weights } = &frame else { panic!("wrong frame") };
        assert_eq!(*version, 7);
        // decoded tensors keep the bf16 dtype → re-encode is byte-identical
        assert_eq!(frame.encode(), bf_bytes);
        // expansion is the exact top-half-of-f32 semantics: NaN stays NaN
        // (quieted), ±Inf exact, finite values RNE-rounded
        let w0 = weights[0].expand_to_f32();
        let v0 = w0.as_f32().unwrap();
        assert_eq!(v0[0], 1.0);
        assert!(v0[2].is_nan());
        assert_eq!(v0[3], f32::INFINITY);
        let w1 = weights[1].expand_to_f32();
        let v1 = w1.as_f32().unwrap();
        assert_eq!(v1[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(v1[2], f32::NEG_INFINITY);
        // strict prefixes of the bf16 frame must not decode
        for cut in 1..bf_bytes.len() {
            let mut cur = Cursor::new(bf_bytes[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "prefix {cut} must error");
        }
    }

    #[test]
    fn batch_envelope_roundtrips() {
        // empty, single and multi-member envelopes all survive
        roundtrip(&Frame::Batch(vec![]));
        roundtrip(&Frame::Batch(vec![Frame::Shutdown]));
        let got = roundtrip(&Frame::Batch(vec![
            Frame::LossRecords {
                seq: u64::MAX,
                worker: 1,
                stamp: 4,
                ids: vec![3, 9],
                losses: vec![0.25, f32::NAN],
            },
            Frame::CacheLookup { req: 2, now: 5, exact: true, ids: vec![4, NO_ID] },
        ]));
        let Frame::Batch(members) = got else { panic!("wrong frame") };
        assert_eq!(members.len(), 2);
        assert_eq!(members[0].name(), "LossRecords");
        assert_eq!(members[1].name(), "CacheLookup");
    }

    #[test]
    fn envelope_encoder_matches_frame_encode() {
        let ids = [3u64, 9];
        let losses = [0.25f32, -1.5];
        let lids = [4u64, NO_ID];
        let mut buf = Vec::new();
        let mut enc = EnvelopeEncoder::begin(&mut buf);
        enc.member_loss_records(u64::MAX, 1, 4, &ids, &losses);
        enc.member_cache_lookup(2, 5, true, &lids);
        assert_eq!(enc.members(), 2);
        enc.finish();
        let want = Frame::Batch(vec![
            Frame::LossRecords {
                seq: u64::MAX,
                worker: 1,
                stamp: 4,
                ids: ids.to_vec(),
                losses: losses.to_vec(),
            },
            Frame::CacheLookup { req: 2, now: 5, exact: true, ids: lids.to_vec() },
        ])
        .encode();
        assert_eq!(buf, want);
    }

    #[test]
    fn borrowed_encoders_match_frame_encode() {
        let mut buf = Vec::new();
        encode_loss_records_into(7, 2, 9, &[1, 2], &[0.5, f32::NAN], &mut buf);
        let want = Frame::LossRecords {
            seq: 7,
            worker: 2,
            stamp: 9,
            ids: vec![1, 2],
            losses: vec![0.5, f32::NAN],
        }
        .encode();
        assert_eq!(buf, want);
        encode_cache_lookup_into(3, 11, false, &[NO_ID, 5], &mut buf);
        let want =
            Frame::CacheLookup { req: 3, now: 11, exact: false, ids: vec![NO_ID, 5] }.encode();
        assert_eq!(buf, want);
        let rows = vec![ViewRow { pos: 1, loss: 0.25, stamp: 8 }];
        encode_cache_view_into(3, 0, &rows, &mut buf);
        assert_eq!(buf, Frame::CacheView { req: 3, worker: 0, rows }.encode());
    }

    #[test]
    fn batch_envelope_rejections() {
        // nesting is a protocol error even though it encodes
        let nested = Frame::Batch(vec![Frame::Shutdown]);
        let mut body = vec![TAG_BATCH];
        body.extend_from_slice(&1u64.to_le_bytes());
        let mut inner = Vec::new();
        nested.encode_into(&mut inner);
        body.extend_from_slice(&((inner.len() - 4) as u32).to_le_bytes());
        body.extend_from_slice(&inner[4..]);
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("nested Batch"), "{err:#}");
        // a corrupt member rejects the whole envelope
        let good = Frame::Batch(vec![Frame::Shutdown, Frame::Hello { proto: 1, worker: 0 }]);
        let enc = good.encode();
        // flip the second member's tag byte to garbage: layout is
        // [outer len 4][TAG_BATCH][count 8][mlen 4][SHUTDOWN][mlen 4][tag..]
        let second_tag_at = 4 + 1 + 8 + 4 + 1 + 4;
        let mut bad = enc.clone();
        assert_eq!(bad[second_tag_at], TAG_HELLO);
        bad[second_tag_at] = 250;
        assert!(Frame::decode(&bad[4..]).is_err());
        // member length lying past the envelope end
        let mut overrun = enc.clone();
        overrun[4 + 1 + 8] = 200; // first member claims 200 bytes
        assert!(Frame::decode(&overrun[4..]).is_err());
        // count exceeding what the remaining bytes could hold
        let mut overcount = enc;
        overcount[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Frame::decode(&overcount[4..]).is_err());
        // strict prefixes of a multi-member envelope must not decode
        let bytes = good.encode();
        for cut in 1..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "prefix {cut} must error");
        }
    }

    #[test]
    fn reshard_frames_roundtrip() {
        let got = roundtrip(&Frame::Join { proto: PROTO_VERSION, worker: 5 });
        let Frame::Join { proto, worker } = got else { panic!("wrong frame") };
        assert_eq!((proto, worker), (PROTO_VERSION, 5));

        let got = roundtrip(&Frame::Reshard { epoch: 3, members: vec![0, 2, 3] });
        let Frame::Reshard { epoch, members } = got else { panic!("wrong frame") };
        assert_eq!((epoch, members), (3, vec![0, 2, 3]));

        let got = roundtrip(&Frame::ShardTransfer {
            epoch: 3,
            worker: 2,
            ids: vec![4, 1, 7],
            losses: vec![0.5, f32::NAN, -0.0],
            stamps: vec![0, 2, u64::MAX],
        });
        let Frame::ShardTransfer { worker, ids, losses, stamps, .. } = got else {
            panic!("wrong frame")
        };
        assert_eq!(worker, 2);
        assert_eq!(ids, vec![4, 1, 7]);
        assert!(losses[1].is_nan());
        assert_eq!(losses[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(stamps[2], u64::MAX);

        // the borrowed encoders agree with Frame::encode byte for byte
        let mut buf = Vec::new();
        encode_reshard_into(9, &[1, 4], &mut buf);
        assert_eq!(buf, Frame::Reshard { epoch: 9, members: vec![1, 4] }.encode());
        encode_shard_transfer_into(9, 1, &[3], &[0.25], &[7], &mut buf);
        let want = Frame::ShardTransfer {
            epoch: 9,
            worker: 1,
            ids: vec![3],
            losses: vec![0.25],
            stamps: vec![7],
        }
        .encode();
        assert_eq!(buf, want);
    }

    #[test]
    fn reshard_frames_rejections() {
        // mismatched ShardTransfer column lengths
        let f = Frame::ShardTransfer {
            epoch: 0,
            worker: 0,
            ids: vec![1, 2],
            losses: vec![0.5, 0.5],
            stamps: vec![3],
        };
        let enc = f.encode();
        assert!(Frame::decode(&enc[4..]).is_err(), "stamp count mismatch must reject");
        // an empty membership map is meaningless
        let enc = Frame::Reshard { epoch: 1, members: vec![] }.encode();
        assert!(Frame::decode(&enc[4..]).is_err(), "empty Reshard must reject");
        // strict prefixes must not decode
        let bytes = Frame::ShardTransfer {
            epoch: 2,
            worker: 1,
            ids: vec![5],
            losses: vec![1.0],
            stamps: vec![2],
        }
        .encode();
        for cut in 1..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "prefix {cut} must error");
        }
    }

    #[test]
    fn pooled_decode_matches_plain_and_reuses_vectors() {
        let frames = [
            Frame::LossRecords {
                seq: 1,
                worker: 0,
                stamp: 2,
                ids: (0..16).collect(),
                losses: (0..16).map(|i| i as f32).collect(),
            },
            Frame::CacheLookup { req: 3, now: 4, exact: true, ids: vec![NO_ID, 7] },
            Frame::CacheView {
                req: 3,
                worker: 1,
                rows: vec![ViewRow { pos: 0, loss: 0.5, stamp: 1 }],
            },
            Frame::Batch(vec![Frame::CacheLookup {
                req: 5,
                now: 6,
                exact: false,
                ids: vec![9],
            }]),
            Frame::ShardTransfer {
                epoch: 1,
                worker: 0,
                ids: vec![2, 4],
                losses: vec![0.5, 1.5],
                stamps: vec![0, 1],
            },
        ];
        let mut pools = FramePools::new();
        for f in &frames {
            let enc = f.encode();
            // pooled and plain decodes re-encode identically
            let pooled = Frame::decode_pooled(&enc[4..], &mut pools).unwrap();
            assert_eq!(pooled.encode(), enc, "{} pooled decode drifts", f.name());
            pools.recycle(pooled);
            // a second pooled decode reuses the recycled vectors: ids
            // capacity survives the round trip
            let again = Frame::decode_pooled(&enc[4..], &mut pools).unwrap();
            assert_eq!(again.encode(), enc);
            pools.recycle(again);
        }
        // the pool actually held the vectors between decodes
        assert!(!pools.u64s.is_empty());
        assert!(!pools.f32s.is_empty());
        assert!(!pools.views.is_empty());
        assert!(!pools.frames.is_empty());
    }

    #[test]
    fn read_frame_into_reuses_the_body_buffer() {
        let a = Frame::LossRecords {
            seq: 1,
            worker: 0,
            stamp: 2,
            ids: vec![1, 2, 3],
            losses: vec![0.1, 0.2, 0.3],
        };
        let mut wire = a.encode();
        wire.extend_from_slice(&Frame::Shutdown.encode());
        let mut cur = Cursor::new(wire);
        let mut body = Vec::new();
        let (f1, _) = read_frame_into(&mut cur, &mut body).unwrap().expect("frame 1");
        assert_eq!(f1.name(), "LossRecords");
        let cap = body.capacity();
        let (f2, _) = read_frame_into(&mut cur, &mut body).unwrap().expect("frame 2");
        assert_eq!(f2.name(), "Shutdown");
        assert_eq!(body.capacity(), cap, "warm buffer must not reallocate");
        assert!(read_frame_into(&mut cur, &mut body).unwrap().is_none());
    }

    #[test]
    fn eof_at_boundary_is_none_mid_frame_is_error() {
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
        let bytes = Frame::Shutdown.encode();
        for cut in 1..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "prefix {cut} must error");
        }
    }

    #[test]
    fn garbage_rejected() {
        // zero length
        let mut cur = Cursor::new(0u32.to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
        // absurd length
        let mut cur = Cursor::new((u32::MAX).to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
        // unknown tag
        let mut bytes = 1u32.to_le_bytes().to_vec();
        bytes.push(200);
        let mut cur = Cursor::new(bytes);
        assert!(read_frame(&mut cur).is_err());
        // trailing payload bytes after a Shutdown
        let mut bytes = 2u32.to_le_bytes().to_vec();
        bytes.push(super::TAG_SHUTDOWN);
        bytes.push(0);
        let mut cur = Cursor::new(bytes);
        assert!(read_frame(&mut cur).is_err());
        // mismatched ids/losses lengths
        let f = Frame::LossRecords { seq: 0, worker: 0, stamp: 0, ids: vec![1], losses: vec![] };
        let enc = f.encode();
        assert!(Frame::decode(&enc[4..]).is_err());
    }
}
