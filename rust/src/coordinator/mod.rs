//! L3 coordinator: the paper's system contribution.
//!
//! * [`trainer`]   — the single-process OBFTF training loop
//!   (Algorithm 1: forward all → select → backward selected); the
//!   numerical oracle every concurrent driver is bounded against;
//! * [`parallel`]  — leader/worker sync data-parallel variant;
//! * [`streaming`] — serial streaming (continuous-training) mode with
//!   bounded prefetch and backpressure accounting;
//! * [`pipeline`]  — the staged continuous-training pipeline: an
//!   inference-fleet stage writing a sharded loss cache, a selection
//!   stage reading it, a backward-only training stage, and async eval;
//! * [`proto`]     — the typed frames + length-prefixed wire codec the
//!   pipeline stages speak across a process boundary;
//! * [`endpoint`]  — the worker-endpoint lifecycle (spawn / socket
//!   bootstrap / connect) shared by every fleet link mode;
//! * [`ipc`]       — the [`Transport`] seam: the fleet as in-process
//!   threads ([`InProcTransport`]) or `obftf worker` child processes —
//!   pipes, Unix sockets or loopback TCP — with distributed loss-cache
//!   shard ownership and supervised restart ([`FleetTransport`]);
//! * [`budget`]    — forward/backward compute accounting (the paper's
//!   "ten forward, one backward" economics);
//! * [`service`]   — status/control plane for long-running jobs.
//!
//! Shared construction helpers live here so every driver derives the
//! *same* datasets, selection RNG stream and stream source from a
//! config — the serial/parallel/pipeline equivalence guarantees all
//! hang off that determinism.

pub mod budget;
pub mod endpoint;
pub mod ipc;
pub mod loss_cache;
pub mod parallel;
pub mod pipeline;
pub mod proto;
pub mod service;
pub mod streaming;
pub mod trainer;

pub use budget::BudgetTracker;
pub use endpoint::LinkMode;
pub use ipc::{
    FleetSpec, FleetSummary, FleetTransport, InProcSpec, InProcTransport, Transport, WireStats,
    WorkerConfig,
};
pub use loss_cache::{CacheStats, LossCache, ShardedLossCache};
pub use parallel::ParallelTrainer;
pub use pipeline::PipelineTrainer;
pub use proto::{Frame, WorkerStats};
pub use streaming::StreamingTrainer;
pub use trainer::{EvalResult, TrainReport, Trainer};

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::dataset::InMemoryDataset;
use crate::data::rng::Rng;
use crate::data::stream::{ResamplingStream, StreamSource};

/// Build the (train, test) datasets a config names, honouring size and
/// label-noise overrides. Every trainer variant (serial, parallel,
/// streaming, pipeline) constructs its data through this one helper so
/// a given config always yields bit-identical datasets.
pub fn build_datasets(cfg: &TrainConfig) -> Result<(InMemoryDataset, InMemoryDataset)> {
    use crate::data::{imagenet_proxy::ImagenetProxySpec, mnist_proxy::MnistProxySpec,
                      regression::RegressionSpec};
    let name = cfg.dataset_name();
    let seed = cfg.seed;
    Ok(match name.as_str() {
        "regression" | "regression_outliers" => {
            let mut spec = if name == "regression_outliers" {
                RegressionSpec::with_outliers()
            } else {
                RegressionSpec::default()
            };
            if let Some(n) = cfg.n_train {
                spec.n_train = n;
            }
            if let Some(n) = cfg.n_test {
                spec.n_test = n;
            }
            spec.build(seed)
        }
        "mnist_proxy" => {
            let mut spec = MnistProxySpec::default();
            if let Some(n) = cfg.n_train {
                spec.n_train = n;
            }
            if let Some(n) = cfg.n_test {
                spec.n_test = n;
            }
            spec.label_noise = cfg.label_noise;
            spec.build(seed)
        }
        "imagenet_proxy" => {
            let mut spec = ImagenetProxySpec::default();
            if let Some(n) = cfg.n_train {
                spec.n_train = n;
            }
            if let Some(n) = cfg.n_test {
                spec.n_test = n;
            }
            spec.label_noise = cfg.label_noise;
            spec.build(seed)
        }
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

/// The selection-RNG stream for a config: seeded from `cfg.seed`, with
/// the epoch-shuffle child stream split off (and discarded here —
/// epoch-mode trainers re-split per epoch). Serial, parallel and
/// pipeline trainers all derive their sampler coins through this one
/// function, which is what makes their selections comparable
/// step-for-step.
pub fn selection_rng(cfg: &TrainConfig) -> Rng {
    let mut rng = Rng::seed_from(cfg.seed ^ 0x747261696e657221);
    let _shuffle_stream = rng.split();
    rng
}

/// Masked mean of per-instance losses: padding rows carry mask 0 and
/// drop out of both the sum and the count. Every trainer variant
/// (serial, parallel, pipeline leader — including its off-critical-path
/// recorder thread) reports `batch_loss` through this one helper, with
/// a fixed per-element f64 accumulation order, so the oracle and the
/// pipeline cannot silently diverge bitwise.
pub fn masked_mean_loss(losses: &[f32], valid_mask: &[f32]) -> f32 {
    let mut sum = 0.0f64;
    let mut cnt = 0.0f64;
    for (l, m) in losses.iter().zip(valid_mask) {
        sum += (*l as f64) * (*m as f64);
        cnt += *m as f64;
    }
    (sum / cnt.max(1.0)) as f32
}

/// The streaming-mode batch source for a config: resamples `train`
/// (with optional concept drift) under a seed derived from `cfg.seed`.
/// Shared by the serial streaming trainer and the staged pipeline so
/// both consume the identical batch sequence.
pub fn stream_source(cfg: &TrainConfig, train: InMemoryDataset) -> Box<dyn StreamSource> {
    Box::new(ResamplingStream::new(train, cfg.seed ^ 0x73747265616d, cfg.drift))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_rng_is_deterministic_per_seed() {
        let cfg = TrainConfig { seed: 123, ..Default::default() };
        let mut a = selection_rng(&cfg);
        let mut b = selection_rng(&cfg);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let cfg2 = TrainConfig { seed: 124, ..Default::default() };
        let mut c = selection_rng(&cfg2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn stream_source_is_deterministic_per_seed() {
        let cfg = TrainConfig {
            model: "linreg".into(),
            seed: 5,
            n_train: Some(64),
            ..Default::default()
        };
        let (train, _) = build_datasets(&cfg).unwrap();
        let mut a = stream_source(&cfg, train.clone());
        let mut b = stream_source(&cfg, train);
        for _ in 0..4 {
            let ba = a.next_batch(8);
            let bb = b.next_batch(8);
            assert_eq!(ba.ids, bb.ids);
        }
    }
}
