//! L3 coordinator: the paper's system contribution.
//!
//! * [`trainer`]  — the single-process OBFTF training loop
//!   (Algorithm 1: forward all → select → backward selected);
//! * [`parallel`] — leader/worker sync data-parallel variant;
//! * [`pipeline`] — streaming (continuous-training) mode with bounded
//!   prefetch and backpressure accounting;
//! * [`budget`]   — forward/backward compute accounting (the paper's
//!   "ten forward, one backward" economics);
//! * [`service`]  — tokio status/control plane for long-running jobs.

pub mod budget;
pub mod loss_cache;
pub mod parallel;
pub mod pipeline;
pub mod service;
pub mod trainer;

pub use budget::BudgetTracker;
pub use loss_cache::LossCache;
pub use parallel::ParallelTrainer;
pub use pipeline::StreamingTrainer;
pub use trainer::{EvalResult, TrainReport, Trainer};
