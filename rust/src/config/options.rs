//! The one typed resolution point for every pipeline knob.
//!
//! The pipeline's shape used to be scattered across three surfaces —
//! `OBFTF_PIPELINE_*` environment variables, `TrainConfig` TOML keys,
//! and ad-hoc CLI flags — each consulted at a different place. This
//! module folds them into a single builder, [`PipelineOptions`], with
//! one documented precedence:
//!
//! ```text
//!   CLI flag  >  OBFTF_* env var  >  config file  >  built-in default
//! ```
//!
//! CLI-layer values travel as a [`PipelineOverrides`] (every field
//! optional) carried on `TrainConfig` — only `main.rs` populates it, so
//! programmatic callers and benches keep the historical env-over-config
//! behaviour. `obftf config --print-effective` dumps the resolved
//! values so a surprising run can be explained without re-reading three
//! sources.
//!
//! | knob | CLI | env | config key | default |
//! |------|-----|-----|------------|---------|
//! | workers        | `--pipeline-workers`  | `OBFTF_PIPELINE_WORKERS`  | `pipeline_workers`  | 2 |
//! | depth          | `--pipeline-depth`    | `OBFTF_PIPELINE_DEPTH`    | `pipeline_depth`    | 4 |
//! | shards         | (none)                | `OBFTF_PIPELINE_SHARDS`   | `cache_shards`      | 0 = auto |
//! | sync           | `--pipeline-sync`     | `OBFTF_PIPELINE_SYNC`     | `pipeline_sync`     | false |
//! | proc fleet     | `--pipeline-proc`     | `OBFTF_PIPELINE_PROC`     | `pipeline_proc`     | false |
//! | socket link    | `--pipeline-socket`   | `OBFTF_PIPELINE_SOCKET`   | `pipeline_socket`   | "" = pipes |
//! | affinity       | `--pipeline-affinity` | `OBFTF_PIPELINE_AFFINITY` | `pipeline_affinity` | true |
//! | restart limit  | `--restart-limit`     | `OBFTF_PIPELINE_RESTART_LIMIT` | `pipeline_restart_limit` | 2 |
//! | fleet timeout  | (none)                | `OBFTF_PROC_TIMEOUT_MS`   | `proc_timeout_ms`   | 0 = 30 s |
//! | score precision | `--score-precision`  | `OBFTF_SCORE_PRECISION`   | `score_precision`   | f32 |
//! | param precision | `--param-precision`  | `OBFTF_PARAM_PRECISION`   | `param_precision`   | f32 |
//! | worker floor   | `--pipeline-min-workers` | `OBFTF_PIPELINE_MIN_WORKERS` | `pipeline_min_workers` | 1 |
//! | mid-run join   | `--pipeline-join`     | `OBFTF_PIPELINE_JOIN`     | `pipeline_join`     | "" = none |
//! | cache bound    | `--cache-max-entries` | `OBFTF_CACHE_MAX_ENTRIES` | `cache_max_entries` | 0 = ∞ |
//! | overlap        | `--pipeline-overlap`  | `OBFTF_PIPELINE_OVERLAP`  | `pipeline_overlap`  | false |

use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::runtime::ScorePrecision;

/// Which transport carries the inference fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process worker threads over a shared sharded cache.
    Threads,
    /// `obftf worker` child processes over stdin/stdout pipes.
    Pipes,
    /// `obftf worker` child processes over Unix-domain sockets.
    UnixSocket,
    /// `obftf worker` child processes over loopback TCP sockets.
    TcpSocket,
}

impl TransportKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::Threads => "threads",
            TransportKind::Pipes => "pipes",
            TransportKind::UnixSocket => "unix-socket",
            TransportKind::TcpSocket => "tcp-socket",
        }
    }

    /// True for the multi-process transports (child `obftf worker`
    /// fleet with distributed shard ownership).
    pub fn is_fleet(&self) -> bool {
        !matches!(self, TransportKind::Threads)
    }
}

/// CLI-layer knob values, every field optional. Populated only by the
/// `obftf` binary's flag parser and carried on [`TrainConfig`]; a
/// `Some` here beats both the environment and the config file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineOverrides {
    pub workers: Option<usize>,
    pub depth: Option<usize>,
    pub shards: Option<usize>,
    pub sync: Option<bool>,
    pub proc: Option<bool>,
    /// Socket link: "unix" | "tcp" | "" (pipes).
    pub socket: Option<String>,
    pub affinity: Option<bool>,
    pub restart_limit: Option<u32>,
    pub timeout_ms: Option<u64>,
    /// Scoring-forward precision: "f32" | "bf16".
    pub score_precision: Option<String>,
    /// Parameter-broadcast wire precision: "f32" | "bf16".
    pub param_precision: Option<String>,
    /// Worker-count floor for retire-instead-of-abort.
    pub min_workers: Option<usize>,
    /// Mid-run join directive: "step" or "step:count".
    pub join: Option<String>,
    /// Bound on live loss-cache + journal entries (0 = unbounded).
    pub cache_max_entries: Option<u64>,
    /// Overlapped-step leader (prefetch + parallel publish + async
    /// epilogue).
    pub overlap: Option<bool>,
}

impl PipelineOverrides {
    pub fn is_empty(&self) -> bool {
        *self == PipelineOverrides::default()
    }
}

/// Fully-resolved pipeline shape: what the staged pipeline actually
/// runs with after CLI > env > config > default resolution.
#[derive(Clone, Copy, Debug)]
pub struct PipelineOptions {
    /// Inference-fleet workers (threads, or child processes for fleet
    /// transports).
    pub workers: usize,
    /// Batches the fleet may score ahead of the training stage (async
    /// mode; sync mode pins this to 0).
    pub depth: usize,
    /// Loss-cache lock stripes (fleet transports: one owned shard set
    /// per worker, so this equals `workers`).
    pub shards: usize,
    /// Synchronous handoffs — the bit-identical oracle mode.
    pub sync: bool,
    /// Which transport carries the fleet.
    pub transport: TransportKind,
    /// Shard-owner affinity routing for `ScoreBatch` submissions.
    pub affinity: bool,
    /// Supervised restarts allowed before a worker death is fatal.
    pub restart_limit: u32,
    /// Max accepted loss age in parameter versions (resolved from the
    /// same auto window the serial trainer uses).
    pub max_age: u64,
    /// Fleet spawn/connect/handshake/await bound.
    pub timeout: Duration,
    /// Precision of the fleet's scoring forward. `Bf16` is async-only:
    /// [`PipelineOptions::resolve`] rejects it in sync mode so the
    /// bit-identical oracle stays bit-identical.
    pub score_precision: ScorePrecision,
    /// Wire precision of the leader's parameter broadcast. `Bf16`
    /// halves `ParamUpdate` frames (workers expand to f32 on receipt;
    /// leader training/eval stay exact f32) and is async-only for the
    /// same reason as `score_precision`.
    pub param_precision: ScorePrecision,
    /// Fleet-size floor: a worker whose restart budget is spent is
    /// *retired* (shards migrate to the survivors) instead of aborting
    /// the run, as long as the fleet stays at or above this floor.
    pub min_workers: usize,
    /// Mid-run admission: at step `.0`, admit `.1` late workers into
    /// the fleet (each triggers a reshard). `None` = static fleet.
    pub join: Option<(u64, usize)>,
    /// Bound on live entries in the sharded loss cache and the
    /// leader's routed-row journal (0 = unbounded). Async-only:
    /// evicting an entry the sync handoff is waiting on would stall
    /// the bit-identical oracle, so `resolve` rejects the combination.
    pub cache_max_entries: u64,
    /// Overlapped-step leader: prefetch the next step's `CacheLookup`
    /// fan-out during backward, broadcast `ParamUpdate` over all worker
    /// links concurrently via per-endpoint writer threads, and move
    /// the recording epilogue off the hot loop. Async-only: the sync
    /// oracle's byte-for-byte serial schedule is the whole point of
    /// sync mode, so `resolve` rejects the combination.
    pub overlap: bool,
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn env_u32(key: &str) -> Option<u32> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn env_bool(key: &str) -> Option<bool> {
    std::env::var(key)
        .ok()
        .map(|v| matches!(v.trim(), "1" | "true" | "yes" | "on"))
}

fn env_str(key: &str) -> Option<String> {
    std::env::var(key).ok()
}

/// Parse a socket-link name ("", "none", "pipes" → no socket).
fn socket_kind(s: &str) -> Result<Option<TransportKind>> {
    match s.trim() {
        "" | "none" | "pipes" => Ok(None),
        "unix" => Ok(Some(TransportKind::UnixSocket)),
        "tcp" => Ok(Some(TransportKind::TcpSocket)),
        other => bail!("unknown pipeline socket mode {other:?} (want unix | tcp | none)"),
    }
}

/// Parse a mid-run join directive: `""`/`"none"` → no join, `"step"` →
/// one worker at `step`, `"step:count"` → `count` workers at `step`.
pub fn parse_join(s: &str) -> Result<Option<(u64, usize)>> {
    let s = s.trim();
    if s.is_empty() || s == "none" {
        return Ok(None);
    }
    let (step_s, count_s) = s.split_once(':').unwrap_or((s, "1"));
    match (step_s.trim().parse::<u64>(), count_s.trim().parse::<usize>()) {
        (Ok(step), Ok(count)) if count > 0 => Ok(Some((step, count))),
        _ => bail!(
            "bad pipeline_join {s:?} (want \"step\" or \"step:count\" with count ≥ 1)"
        ),
    }
}

impl PipelineOptions {
    /// Resolve every knob with CLI > env > config > default precedence
    /// (config values already carry the built-in defaults).
    /// `train_len`/`batch` size the auto `max_age`: two epochs' worth
    /// of steps, exactly like the serial trainer's `reuse_losses`
    /// window.
    pub fn resolve(cfg: &TrainConfig, train_len: usize, batch: usize) -> Result<PipelineOptions> {
        let ov = &cfg.overrides;
        let workers = ov
            .workers
            .or_else(|| env_usize("OBFTF_PIPELINE_WORKERS"))
            .unwrap_or(cfg.pipeline_workers)
            .max(1);
        let depth = ov
            .depth
            .or_else(|| env_usize("OBFTF_PIPELINE_DEPTH"))
            .unwrap_or(cfg.pipeline_depth)
            .max(1);
        let sync = ov
            .sync
            .or_else(|| env_bool("OBFTF_PIPELINE_SYNC"))
            .unwrap_or(cfg.pipeline_sync);
        let proc = ov
            .proc
            .or_else(|| env_bool("OBFTF_PIPELINE_PROC"))
            .unwrap_or(cfg.pipeline_proc);
        let socket = ov
            .socket
            .clone()
            .or_else(|| env_str("OBFTF_PIPELINE_SOCKET"))
            .unwrap_or_else(|| cfg.pipeline_socket.clone());
        // a socket link implies the multi-process fleet
        let transport = match socket_kind(&socket)? {
            Some(k) => k,
            None if proc => TransportKind::Pipes,
            None => TransportKind::Threads,
        };
        let shards_cfg = ov
            .shards
            .or_else(|| env_usize("OBFTF_PIPELINE_SHARDS"))
            .unwrap_or(cfg.cache_shards);
        let shards = if transport.is_fleet() {
            // distributed ownership: exactly one shard set per worker
            workers
        } else if shards_cfg == 0 {
            (workers * 2).clamp(4, 16)
        } else {
            shards_cfg
        };
        let affinity = ov
            .affinity
            .or_else(|| env_bool("OBFTF_PIPELINE_AFFINITY"))
            .unwrap_or(cfg.pipeline_affinity);
        let restart_limit = ov
            .restart_limit
            .or_else(|| env_u32("OBFTF_PIPELINE_RESTART_LIMIT"))
            .unwrap_or(cfg.pipeline_restart_limit);
        let timeout_ms = ov
            .timeout_ms
            .or_else(|| env_u64("OBFTF_PROC_TIMEOUT_MS"))
            .unwrap_or(cfg.proc_timeout_ms);
        let timeout = if timeout_ms > 0 {
            Duration::from_millis(timeout_ms)
        } else {
            crate::coordinator::ipc::STALL_TIMEOUT
        };
        let score_str = ov
            .score_precision
            .clone()
            .or_else(|| env_str("OBFTF_SCORE_PRECISION"))
            .unwrap_or_else(|| cfg.score_precision.clone());
        let score_precision = ScorePrecision::parse(score_str.trim())?;
        if sync && score_precision == ScorePrecision::Bf16 {
            bail!(
                "score_precision = bf16 is incompatible with pipeline_sync: sync mode is \
                 the bit-identical oracle and must score in exact f32 (drop --pipeline-sync \
                 or use score_precision = f32)"
            );
        }
        let param_str = ov
            .param_precision
            .clone()
            .or_else(|| env_str("OBFTF_PARAM_PRECISION"))
            .unwrap_or_else(|| cfg.param_precision.clone());
        let param_precision = ScorePrecision::parse(param_str.trim())?;
        if sync && param_precision == ScorePrecision::Bf16 {
            bail!(
                "param_precision = bf16 is incompatible with pipeline_sync: sync mode is \
                 the bit-identical oracle and must broadcast exact f32 params (drop \
                 --pipeline-sync or use param_precision = f32)"
            );
        }
        let min_workers = ov
            .min_workers
            .or_else(|| env_usize("OBFTF_PIPELINE_MIN_WORKERS"))
            .unwrap_or(cfg.pipeline_min_workers);
        if min_workers < 1 || min_workers > workers {
            bail!(
                "pipeline_min_workers = {min_workers} must be in 1..={workers} \
                 (the fleet size)"
            );
        }
        let join_str = ov
            .join
            .clone()
            .or_else(|| env_str("OBFTF_PIPELINE_JOIN"))
            .unwrap_or_else(|| cfg.pipeline_join.clone());
        let join = parse_join(&join_str)?;
        if join.is_some() && !transport.is_fleet() {
            bail!(
                "pipeline_join requires a process-fleet transport (--pipeline-proc or \
                 --pipeline-socket): the in-process threads transport has a fixed pool"
            );
        }
        let cache_max_entries = ov
            .cache_max_entries
            .or_else(|| env_u64("OBFTF_CACHE_MAX_ENTRIES"))
            .unwrap_or(cfg.cache_max_entries);
        if sync && cache_max_entries > 0 {
            bail!(
                "cache_max_entries is incompatible with pipeline_sync: the bit-identical \
                 oracle's exact-stamp handoff must never lose the entry it is waiting on \
                 (drop --pipeline-sync or use cache_max_entries = 0)"
            );
        }
        // CLI or config asking for overlap under sync is a hard error;
        // the *env* source alone is advisory and silently stays off, so
        // a fleet-wide OBFTF_PIPELINE_OVERLAP=1 default (e.g. the CI
        // overlap test leg running the whole suite, sync oracles
        // included) cannot invalidate an explicitly synchronous run.
        if sync && ov.overlap.unwrap_or(cfg.pipeline_overlap) {
            bail!(
                "pipeline_overlap is incompatible with pipeline_sync: sync mode is the \
                 bit-identical oracle and must keep the leader's lookup → select → backward \
                 → publish schedule byte-for-byte serial (drop --pipeline-sync or \
                 pipeline_overlap)"
            );
        }
        let overlap = !sync
            && ov
                .overlap
                .or_else(|| env_bool("OBFTF_PIPELINE_OVERLAP"))
                .unwrap_or(cfg.pipeline_overlap);
        let max_age = if cfg.loss_max_age > 0 {
            cfg.loss_max_age
        } else {
            2 * train_len.div_ceil(batch.max(1)) as u64
        };
        Ok(PipelineOptions {
            workers,
            depth,
            shards,
            sync,
            transport,
            affinity,
            restart_limit,
            max_age,
            timeout,
            score_precision,
            param_precision,
            min_workers,
            join,
            cache_max_entries,
            overlap,
        })
    }

    /// Human-readable dump for `obftf config --print-effective`:
    /// one `key = value` line per resolved knob. `max_age` prints
    /// "auto" when the config left it 0 and no dataset is at hand to
    /// size the window.
    pub fn effective_lines(&self, max_age_auto: bool) -> Vec<String> {
        vec![
            format!("pipeline_workers = {}", self.workers),
            format!("pipeline_depth = {}", self.depth),
            format!("cache_shards = {}", self.shards),
            format!("pipeline_sync = {}", self.sync),
            format!("pipeline_transport = {}", self.transport.as_str()),
            format!("pipeline_affinity = {}", self.affinity),
            format!("pipeline_restart_limit = {}", self.restart_limit),
            format!(
                "loss_max_age = {}",
                if max_age_auto { "auto".to_string() } else { self.max_age.to_string() }
            ),
            format!("proc_timeout_ms = {}", self.timeout.as_millis()),
            format!("score_precision = {}", self.score_precision),
            format!("param_precision = {}", self.param_precision),
            format!("pipeline_min_workers = {}", self.min_workers),
            format!(
                "pipeline_join = {}",
                match self.join {
                    Some((step, count)) => format!("{step}:{count}"),
                    None => "none".to_string(),
                }
            ),
            format!("cache_max_entries = {}", self.cache_max_entries),
            format!("pipeline_overlap = {}", self.overlap),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> TrainConfig {
        TrainConfig { stream_steps: 10, pipeline: true, ..Default::default() }
    }

    #[test]
    fn defaults_resolve_to_threads_with_affinity_and_restart_budget() {
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert_eq!(o.transport, TransportKind::Threads);
        assert!(!o.transport.is_fleet());
        assert_eq!(o.workers, 2);
        assert!(o.affinity, "affinity routing defaults on");
        assert_eq!(o.restart_limit, 2, "elastic by default");
        assert_eq!(o.max_age, 2 * 8, "two epochs of 64/8 steps");
        assert_eq!(o.timeout, crate::coordinator::ipc::STALL_TIMEOUT);
    }

    #[test]
    fn socket_config_implies_fleet_transport() {
        let mut cfg = base();
        cfg.pipeline_socket = "unix".into();
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.transport, TransportKind::UnixSocket);
        assert!(o.transport.is_fleet());
        assert_eq!(o.shards, o.workers, "one owned shard set per worker");
        cfg.pipeline_socket = "tcp".into();
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.transport, TransportKind::TcpSocket);
        cfg.pipeline_socket = "carrier-pigeon".into();
        assert!(PipelineOptions::resolve(&cfg, 64, 8).is_err());
    }

    #[test]
    fn cli_overrides_beat_config() {
        let mut cfg = base();
        cfg.pipeline_workers = 2;
        cfg.pipeline_socket = "unix".into();
        cfg.overrides = PipelineOverrides {
            workers: Some(5),
            socket: Some("tcp".into()),
            affinity: Some(false),
            restart_limit: Some(0),
            timeout_ms: Some(1234),
            ..Default::default()
        };
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.workers, 5);
        assert_eq!(o.transport, TransportKind::TcpSocket);
        assert!(!o.affinity);
        assert_eq!(o.restart_limit, 0);
        assert_eq!(o.timeout, Duration::from_millis(1234));
        cfg.overrides.score_precision = Some("bf16".into());
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.score_precision, ScorePrecision::Bf16);
    }

    /// bf16 scoring is an async-only fast path: the resolver accepts it
    /// whenever handoffs are asynchronous and rejects it in sync mode
    /// (the bit-identical oracle), from any source of the knob.
    #[test]
    fn bf16_scoring_is_async_only() {
        let mut cfg = base();
        cfg.score_precision = "bf16".into();
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.score_precision, ScorePrecision::Bf16);
        cfg.pipeline_sync = true;
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("pipeline_sync"), "err: {err}");
        // the CLI spelling is validated here too
        let mut cfg = base();
        cfg.overrides.score_precision = Some("f64".into());
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("f32 | bf16"), "err: {err}");
        // default stays exact
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert_eq!(o.score_precision, ScorePrecision::F32);
    }

    /// bf16 param broadcast mirrors the scoring knob's contract: fine
    /// async (workers expand on receipt), rejected in sync mode from
    /// any source, junk spellings rejected at resolve.
    #[test]
    fn bf16_param_broadcast_is_async_only() {
        let mut cfg = base();
        cfg.param_precision = "bf16".into();
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.param_precision, ScorePrecision::Bf16);
        assert_eq!(o.score_precision, ScorePrecision::F32, "knobs are independent");
        cfg.pipeline_sync = true;
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("param_precision"), "err: {err}");
        assert!(err.contains("pipeline_sync"), "err: {err}");
        // the CLI spelling is validated too, and the override wins
        let mut cfg = base();
        cfg.param_precision = "f32".into();
        cfg.overrides.param_precision = Some("bf16".into());
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.param_precision, ScorePrecision::Bf16);
        cfg.overrides.param_precision = Some("f64".into());
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("f32 | bf16"), "err: {err}");
        // default stays exact
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert_eq!(o.param_precision, ScorePrecision::F32);
    }

    /// One env-injection test (process env is shared across a test
    /// binary's threads, so no other test in this binary asserts on
    /// the depth knob): the env beats config, and the CLI overrides
    /// beat the env.
    #[test]
    fn env_beats_config_and_cli_beats_env() {
        std::env::set_var("OBFTF_PIPELINE_DEPTH", "7");
        let mut cfg = base();
        cfg.pipeline_depth = 3;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.depth, 7, "env beats config");
        cfg.overrides.depth = Some(1);
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.depth, 1, "CLI beats env");
        std::env::remove_var("OBFTF_PIPELINE_DEPTH");
    }

    #[test]
    fn effective_lines_cover_every_knob() {
        let o = PipelineOptions::resolve(&base(), 0, 0).unwrap();
        let lines = o.effective_lines(true);
        assert!(lines.iter().any(|l| l == "loss_max_age = auto"));
        assert!(lines.iter().any(|l| l.starts_with("pipeline_transport = threads")));
        assert!(lines.iter().any(|l| l.starts_with("pipeline_affinity = true")));
        for key in [
            "pipeline_workers",
            "pipeline_depth",
            "cache_shards",
            "pipeline_sync",
            "pipeline_restart_limit",
            "proc_timeout_ms",
            "score_precision",
            "param_precision",
            "pipeline_min_workers",
            "pipeline_join",
            "cache_max_entries",
            "pipeline_overlap",
        ] {
            assert!(lines.iter().any(|l| l.starts_with(key)), "missing {key}");
        }
        assert!(lines.iter().any(|l| l == "pipeline_join = none"));
    }

    #[test]
    fn join_directive_parses_and_demands_a_fleet() {
        assert_eq!(parse_join("").unwrap(), None);
        assert_eq!(parse_join("none").unwrap(), None);
        assert_eq!(parse_join("12").unwrap(), Some((12, 1)));
        assert_eq!(parse_join(" 12 : 3 ").unwrap(), Some((12, 3)));
        assert!(parse_join("12:0").is_err(), "count 0 is meaningless");
        assert!(parse_join("early").is_err());
        // the knob resolves, but only on a fleet transport
        let mut cfg = base();
        cfg.pipeline_socket = "unix".into();
        cfg.pipeline_join = "5:2".into();
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.join, Some((5, 2)));
        cfg.pipeline_socket = String::new();
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("fleet"), "err: {err}");
        // CLI override beats config
        cfg.pipeline_socket = "unix".into();
        cfg.overrides.join = Some("9".into());
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.join, Some((9, 1)));
    }

    #[test]
    fn min_workers_floor_is_validated_against_the_fleet_size() {
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert_eq!(o.min_workers, 1, "default floor");
        let mut cfg = base();
        cfg.pipeline_workers = 3;
        cfg.pipeline_min_workers = 3;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.min_workers, 3);
        cfg.pipeline_min_workers = 4;
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("pipeline_min_workers"), "err: {err}");
        cfg.pipeline_min_workers = 4;
        cfg.overrides.min_workers = Some(2);
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.min_workers, 2, "CLI beats config");
    }

    /// The cache bound is async-only: evicting the entry a sync
    /// handoff is waiting on would stall the oracle, so the resolver
    /// rejects the combination from any knob source.
    #[test]
    fn cache_bound_is_async_only() {
        let mut cfg = base();
        cfg.cache_max_entries = 4096;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.cache_max_entries, 4096);
        cfg.pipeline_sync = true;
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("cache_max_entries"), "err: {err}");
        assert!(err.contains("pipeline_sync"), "err: {err}");
        // sync with the bound left at 0 stays fine
        cfg.cache_max_entries = 0;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert_eq!(o.cache_max_entries, 0);
    }

    /// The overlapped leader is async-only: sync mode's value *is* the
    /// byte-for-byte serial schedule, so the resolver rejects a CLI or
    /// config request for the combination and the error names both
    /// knobs. The *env* source alone is advisory — under sync it
    /// silently stays off, so a fleet-wide `OBFTF_PIPELINE_OVERLAP=1`
    /// default (e.g. a CI leg running the whole suite, sync oracles
    /// included) cannot invalidate an explicitly synchronous run.
    /// (Process env is shared across the test binary's threads; no
    /// other test in this binary asserts on the overlap knob, and the
    /// leading remove_var keeps this one hermetic when CI's overlap
    /// leg exports the variable suite-wide.)
    #[test]
    fn overlap_is_async_only() {
        std::env::remove_var("OBFTF_PIPELINE_OVERLAP");
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert!(!o.overlap, "defaults off");
        let mut cfg = base();
        cfg.pipeline_overlap = true;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert!(o.overlap);
        cfg.pipeline_sync = true;
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("pipeline_overlap"), "err: {err}");
        assert!(err.contains("pipeline_sync"), "err: {err}");
        // the CLI override wins over config
        let mut cfg = base();
        cfg.pipeline_overlap = true;
        cfg.overrides.overlap = Some(false);
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert!(!o.overlap, "CLI beats config");
        // env turns async runs on...
        std::env::set_var("OBFTF_PIPELINE_OVERLAP", "1");
        let o = PipelineOptions::resolve(&base(), 64, 8).unwrap();
        assert!(o.overlap, "env beats config default");
        // ...but under sync it is advisory: resolves fine, overlap off
        let mut cfg = base();
        cfg.pipeline_sync = true;
        let o = PipelineOptions::resolve(&cfg, 64, 8).unwrap();
        assert!(o.sync && !o.overlap, "env overlap is advisory under sync");
        // an explicit CLI ask still errors even with the env set
        cfg.overrides.overlap = Some(true);
        let err = PipelineOptions::resolve(&cfg, 64, 8).unwrap_err().to_string();
        assert!(err.contains("pipeline_overlap"), "err: {err}");
        std::env::remove_var("OBFTF_PIPELINE_OVERLAP");
    }
}
