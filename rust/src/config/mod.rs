//! Configuration system: TOML files + programmatic defaults, validated
//! before anything heavy starts. The CLI (`rust/src/main.rs`) overlays
//! flag overrides on top of a loaded file.
//!
//! Offline note: the `toml`/`serde` crates are unavailable; parsing goes
//! through [`crate::util::toml_min`], and unknown keys are rejected so
//! typos fail loudly exactly as `deny_unknown_fields` would.
//!
//! Pipeline knobs resolve through [`options::PipelineOptions`] with
//! CLI > env > config > default precedence — see that module for the
//! full knob table.

pub mod options;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use options::{PipelineOptions, PipelineOverrides, TransportKind};

use crate::sampling::Method;
use crate::util::toml_min::{self, TomlValue};

/// Everything a training run needs. A TOML file only has to mention
/// what it changes from [`TrainConfig::default`].
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model name from the manifest: linreg | mlp | cnn | cnn_lite.
    pub model: String,
    /// Execution flavour: auto (manifest default) | native (pure-Rust
    /// CPU backend, no artifacts) | pallas (paper-faithful L1 kernels)
    /// | jnp. The artifact flavours need the `pjrt` cargo feature.
    pub flavour: String,
    /// Dataset: regression | regression_outliers | mnist_proxy |
    /// imagenet_proxy (defaults to the model's conventional pairing).
    pub dataset: Option<String>,
    /// Selection method.
    pub method: Method,
    /// Sampling ratio r: the per-batch backward budget is `round(r·n)`.
    pub sampling_ratio: f64,
    /// Selective-backprop γ.
    pub gamma: f32,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// Evaluate on the test split every `eval_every` epochs (0 = only at
    /// the end).
    pub eval_every: usize,
    /// Data-parallel workers (1 = single-process trainer).
    pub workers: usize,
    /// Override dataset sizes (None = generator defaults).
    pub n_train: Option<usize>,
    pub n_test: Option<usize>,
    /// Label-noise fraction for the classification proxies.
    pub label_noise: f32,
    /// Checkpoint path (written at the end of each epoch when set).
    pub checkpoint: Option<String>,
    /// Metrics CSV output path.
    pub metrics_out: Option<String>,
    /// Streaming mode: train on a resampling stream for `stream_steps`
    /// steps instead of epochs (0 = epoch mode).
    pub stream_steps: usize,
    /// Prefetch depth for streaming mode.
    pub prefetch_depth: usize,
    /// Concept-drift magnitude for the streaming source.
    pub drift: f32,
    /// Status service bind address for streaming jobs (e.g.
    /// "127.0.0.1:7878"); None = no service.
    pub status_addr: Option<String>,
    /// Reuse per-instance losses recorded from earlier forward passes
    /// (the paper's production premise: inference already computed
    /// them). When a batch is fully covered by fresh cache entries the
    /// fwd_loss execution is skipped.
    pub reuse_losses: bool,
    /// Max cache age in steps (0 = auto: two epochs' worth of steps,
    /// in both the serial trainer and the pipeline).
    pub loss_max_age: u64,
    /// Force the masked full-batch backward instead of the gathered
    /// sub-batch backward (identical numerics, O(n) vs O(b) cost; kept
    /// as the perf-ablation knob — EXPERIMENTS.md §Perf).
    pub masked_backward: bool,
    /// Streaming mode only: run the staged pipeline (inference-fleet
    /// workers + sharded loss cache + backward-only training stage +
    /// async eval) instead of the serial streaming loop.
    pub pipeline: bool,
    /// Inference-fleet worker threads for pipeline mode
    /// (`OBFTF_PIPELINE_WORKERS` overrides).
    pub pipeline_workers: usize,
    /// Batches the fleet may score ahead of the training stage
    /// (`OBFTF_PIPELINE_DEPTH` overrides; sync mode pins it to 0).
    pub pipeline_depth: usize,
    /// Loss-cache lock stripes (0 = auto from the worker count;
    /// `OBFTF_PIPELINE_SHARDS` overrides).
    pub cache_shards: usize,
    /// Synchronous stage handoffs — the bit-identical oracle mode
    /// (`OBFTF_PIPELINE_SYNC` overrides).
    pub pipeline_sync: bool,
    /// Multi-process inference fleet: spawn `obftf worker` child
    /// processes over stdin/stdout pipes with distributed loss-cache
    /// shard ownership, instead of in-process threads
    /// (`OBFTF_PIPELINE_PROC` overrides; see README "Multi-process
    /// fleet").
    pub pipeline_proc: bool,
    /// Socket link for the multi-process fleet: "" (stdio pipes),
    /// "unix" (Unix-domain sockets) or "tcp" (loopback TCP). A
    /// non-empty value implies the process fleet
    /// (`OBFTF_PIPELINE_SOCKET` overrides; see README "Socket fleet").
    pub pipeline_socket: String,
    /// Shard-owner affinity routing: `ScoreBatch` work goes to the
    /// worker owning most of the batch's ids, cutting routed
    /// `LossRecords` traffic (`OBFTF_PIPELINE_AFFINITY` overrides).
    pub pipeline_affinity: bool,
    /// Supervised restarts allowed across a fleet run before a worker
    /// death becomes fatal; 0 = strict fail-fast
    /// (`OBFTF_PIPELINE_RESTART_LIMIT` overrides).
    pub pipeline_restart_limit: u32,
    /// Fleet-size floor: a worker whose restart budget is spent is
    /// retired (its shard migrates to the survivors) instead of
    /// aborting the run, as long as at least this many workers remain
    /// (`OBFTF_PIPELINE_MIN_WORKERS` overrides).
    pub pipeline_min_workers: usize,
    /// Mid-run admission directive for the process fleet: "" (none),
    /// "step" (admit one late worker at that step) or "step:count"
    /// (`OBFTF_PIPELINE_JOIN` overrides; see README "Socket fleet").
    pub pipeline_join: String,
    /// Bound on live entries in the sharded loss cache and the
    /// leader's routed-row journal, evicting oldest-stamp-first when
    /// exceeded; 0 = unbounded. Async pipeline only — sync mode
    /// rejects it (`OBFTF_CACHE_MAX_ENTRIES` overrides).
    pub cache_max_entries: u64,
    /// Fleet spawn/connect/handshake/await bound in milliseconds;
    /// 0 = the built-in 30 s stall timeout (`OBFTF_PROC_TIMEOUT_MS`
    /// overrides).
    pub proc_timeout_ms: u64,
    /// Numeric precision of the pipeline fleet's scoring forward:
    /// "f32" (exact, default) or "bf16" (packed bf16 panels with f32
    /// accumulation — async pipeline only; sync mode rejects it to
    /// stay bit-identical to the serial trainer).
    /// (`OBFTF_SCORE_PRECISION` overrides.)
    pub score_precision: String,
    /// Wire precision of the leader's parameter broadcast: "f32"
    /// (exact, default) or "bf16" (half-size `ParamUpdate` frames;
    /// workers expand to f32 on receipt — async pipeline only; sync
    /// mode rejects it to stay bit-identical to the serial trainer).
    /// (`OBFTF_PARAM_PRECISION` overrides.)
    pub param_precision: String,
    /// Overlapped-step leader: prefetch the next step's cache lookup
    /// during backward, fan the parameter broadcast out over all
    /// worker links concurrently, and record step telemetry off the
    /// hot loop. Async pipeline only — sync mode rejects it to keep
    /// the bit-identical oracle byte-for-byte serial
    /// (`OBFTF_PIPELINE_OVERLAP` overrides).
    pub pipeline_overlap: bool,
    /// CLI-layer knob overrides (never read from TOML; populated only
    /// by the `obftf` flag parser — a `Some` beats env and config).
    pub overrides: PipelineOverrides,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp".to_string(),
            flavour: "auto".to_string(),
            dataset: None,
            method: Method::Obftf,
            sampling_ratio: 0.25,
            gamma: 1.0,
            epochs: 5,
            lr: 0.1,
            seed: 42,
            eval_every: 1,
            workers: 1,
            n_train: None,
            n_test: None,
            label_noise: 0.0,
            checkpoint: None,
            metrics_out: None,
            stream_steps: 0,
            prefetch_depth: 4,
            drift: 0.0,
            status_addr: None,
            reuse_losses: false,
            loss_max_age: 0,
            masked_backward: false,
            pipeline: false,
            pipeline_workers: 2,
            pipeline_depth: 4,
            cache_shards: 0,
            pipeline_sync: false,
            pipeline_proc: false,
            pipeline_socket: String::new(),
            pipeline_affinity: true,
            pipeline_restart_limit: 2,
            pipeline_min_workers: 1,
            pipeline_join: String::new(),
            cache_max_entries: 0,
            proc_timeout_ms: 0,
            score_precision: "f32".to_string(),
            param_precision: "f32".to_string(),
            pipeline_overlap: false,
            overrides: PipelineOverrides::default(),
        }
    }
}

impl TrainConfig {
    pub fn from_toml_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing config {path:?}"))
    }

    pub fn from_toml_str(text: &str) -> Result<TrainConfig> {
        let map = toml_min::parse(text)?;
        let mut cfg = TrainConfig::default();
        for (key, val) in &map {
            cfg.apply_kv(key, val)
                .with_context(|| format!("config key {key:?}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_kv(&mut self, key: &str, val: &TomlValue) -> Result<()> {
        match key {
            "model" => self.model = val.as_str()?.to_string(),
            "flavour" => self.flavour = val.as_str()?.to_string(),
            "dataset" => self.dataset = Some(val.as_str()?.to_string()),
            "method" => self.method = val.as_str()?.parse()?,
            "sampling_ratio" => self.sampling_ratio = val.as_f64()?,
            "gamma" => self.gamma = val.as_f32()?,
            "epochs" => self.epochs = val.as_usize()?,
            "lr" => self.lr = val.as_f32()?,
            "seed" => self.seed = val.as_u64()?,
            "eval_every" => self.eval_every = val.as_usize()?,
            "workers" => self.workers = val.as_usize()?,
            "n_train" => self.n_train = Some(val.as_usize()?),
            "n_test" => self.n_test = Some(val.as_usize()?),
            "label_noise" => self.label_noise = val.as_f32()?,
            "checkpoint" => self.checkpoint = Some(val.as_str()?.to_string()),
            "metrics_out" => self.metrics_out = Some(val.as_str()?.to_string()),
            "stream_steps" => self.stream_steps = val.as_usize()?,
            "prefetch_depth" => self.prefetch_depth = val.as_usize()?,
            "drift" => self.drift = val.as_f32()?,
            "status_addr" => self.status_addr = Some(val.as_str()?.to_string()),
            "masked_backward" => self.masked_backward = val.as_bool()?,
            "reuse_losses" => self.reuse_losses = val.as_bool()?,
            "loss_max_age" => self.loss_max_age = val.as_u64()?,
            "pipeline" => self.pipeline = val.as_bool()?,
            "pipeline_workers" => self.pipeline_workers = val.as_usize()?,
            "pipeline_depth" => self.pipeline_depth = val.as_usize()?,
            "cache_shards" => self.cache_shards = val.as_usize()?,
            "pipeline_sync" => self.pipeline_sync = val.as_bool()?,
            "pipeline_proc" => self.pipeline_proc = val.as_bool()?,
            "pipeline_socket" => self.pipeline_socket = val.as_str()?.to_string(),
            "pipeline_affinity" => self.pipeline_affinity = val.as_bool()?,
            "pipeline_restart_limit" => {
                self.pipeline_restart_limit = u32::try_from(val.as_u64()?)
                    .map_err(|_| anyhow::anyhow!("pipeline_restart_limit too large"))?
            }
            "pipeline_min_workers" => self.pipeline_min_workers = val.as_usize()?,
            "pipeline_join" => self.pipeline_join = val.as_str()?.to_string(),
            "cache_max_entries" => self.cache_max_entries = val.as_u64()?,
            "proc_timeout_ms" => self.proc_timeout_ms = val.as_u64()?,
            "score_precision" => self.score_precision = val.as_str()?.to_string(),
            "param_precision" => self.param_precision = val.as_str()?.to_string(),
            "pipeline_overlap" => self.pipeline_overlap = val.as_bool()?,
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// The dataset to use (explicit or conventional pairing).
    pub fn dataset_name(&self) -> String {
        self.dataset
            .clone()
            .unwrap_or_else(|| crate::data::default_dataset_for(&self.model).to_string())
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.sampling_ratio) {
            bail!("sampling_ratio {} outside [0, 1]", self.sampling_ratio);
        }
        if self.lr <= 0.0 || !self.lr.is_finite() {
            bail!("lr must be positive and finite, got {}", self.lr);
        }
        if self.workers == 0 {
            bail!("workers must be ≥ 1");
        }
        if self.epochs == 0 && self.stream_steps == 0 {
            bail!("either epochs or stream_steps must be > 0");
        }
        if !(0.0..1.0).contains(&self.label_noise) {
            bail!("label_noise {} outside [0, 1)", self.label_noise);
        }
        if self.gamma <= 0.0 {
            bail!("gamma must be positive");
        }
        if self.prefetch_depth == 0 {
            bail!("prefetch_depth must be ≥ 1");
        }
        if self.pipeline && self.stream_steps == 0 {
            bail!("pipeline mode requires stream_steps > 0 (it is a streaming driver)");
        }
        if self.pipeline_workers == 0 {
            bail!("pipeline_workers must be ≥ 1");
        }
        if self.pipeline_depth == 0 {
            bail!("pipeline_depth must be ≥ 1");
        }
        if self.pipeline_proc && !self.pipeline {
            bail!("pipeline_proc requires pipeline = true (it selects the fleet transport)");
        }
        if !self.pipeline_socket.is_empty() && !self.pipeline {
            bail!("pipeline_socket requires pipeline = true (it selects the fleet link)");
        }
        match self.pipeline_socket.as_str() {
            "" | "none" | "pipes" | "unix" | "tcp" => {}
            other => bail!("unknown pipeline_socket {other:?} (want unix | tcp | none)"),
        }
        if self.pipeline_min_workers == 0 {
            bail!("pipeline_min_workers must be ≥ 1");
        }
        if !self.pipeline_join.is_empty() && !self.pipeline {
            bail!("pipeline_join requires pipeline = true (it admits fleet workers)");
        }
        if self.pipeline_overlap && !self.pipeline {
            bail!("pipeline_overlap requires pipeline = true (it overlaps the leader loop)");
        }
        options::parse_join(&self.pipeline_join)?;
        match self.score_precision.as_str() {
            "f32" | "bf16" => {}
            other => bail!("unknown score_precision {other:?} (expected f32 | bf16)"),
        }
        match self.param_precision.as_str() {
            "f32" | "bf16" => {}
            other => bail!("unknown param_precision {other:?} (expected f32 | bf16)"),
        }
        match self.flavour.as_str() {
            "auto" | "native" | "pallas" | "jnp" => {}
            other => bail!("unknown flavour {other:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::field_reassign_with_default)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_partial_file_overlays_defaults() {
        let cfg = TrainConfig::from_toml_str(
            r#"
model = "linreg"
method = "mink"
sampling_ratio = 0.1
epochs = 2
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "linreg");
        assert_eq!(cfg.method, Method::MinK);
        assert_eq!(cfg.sampling_ratio, 0.1);
        assert_eq!(cfg.lr, 0.1); // default preserved
        assert_eq!(cfg.dataset_name(), "regression");
    }

    #[test]
    fn unknown_key_rejected() {
        let err = TrainConfig::from_toml_str("modle = \"mlp\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("unknown config key"));
    }

    #[test]
    fn invalid_values_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.sampling_ratio = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.lr = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.epochs = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.flavour = "cuda".into();
        assert!(cfg.validate().is_err());
        assert!(TrainConfig::from_toml_str("method = \"bogus\"").is_err());
    }

    #[test]
    fn native_and_auto_flavours_accepted() {
        for fl in ["auto", "native", "pallas", "jnp"] {
            let mut cfg = TrainConfig::default();
            cfg.flavour = fl.to_string();
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn stream_mode_allows_zero_epochs() {
        let cfg = TrainConfig::from_toml_str("epochs = 0\nstream_steps = 100").unwrap();
        assert_eq!(cfg.stream_steps, 100);
    }

    #[test]
    fn pipeline_keys_parse_and_validate() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_workers = 4\n\
             pipeline_depth = 8\ncache_shards = 16\npipeline_sync = true\n",
        )
        .unwrap();
        assert!(cfg.pipeline && cfg.pipeline_sync);
        assert_eq!(cfg.pipeline_workers, 4);
        assert_eq!(cfg.pipeline_depth, 8);
        assert_eq!(cfg.cache_shards, 16);
        // pipeline without streaming is rejected
        assert!(TrainConfig::from_toml_str("pipeline = true").is_err());
        // proc transport parses, but demands pipeline mode
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_proc = true\n",
        )
        .unwrap();
        assert!(cfg.pipeline_proc);
        assert!(TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline_proc = true\n"
        )
        .is_err());
        let mut cfg = TrainConfig::default();
        cfg.stream_steps = 10;
        cfg.pipeline = true;
        cfg.pipeline_workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.pipeline_depth = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn socket_fleet_keys_parse_and_validate() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_socket = \"unix\"\n\
             pipeline_affinity = false\npipeline_restart_limit = 3\nproc_timeout_ms = 500\n",
        )
        .unwrap();
        assert_eq!(cfg.pipeline_socket, "unix");
        assert!(!cfg.pipeline_affinity);
        assert_eq!(cfg.pipeline_restart_limit, 3);
        assert_eq!(cfg.proc_timeout_ms, 500);
        assert!(cfg.overrides.is_empty(), "TOML never populates CLI overrides");
        // socket without pipeline mode is rejected, as is a bogus link
        assert!(TrainConfig::from_toml_str("pipeline_socket = \"unix\"").is_err());
        assert!(TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_socket = \"smoke\"\n"
        )
        .is_err());
    }

    #[test]
    fn reshard_keys_parse_and_validate() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_socket = \"unix\"\n\
             pipeline_min_workers = 2\npipeline_join = \"10:1\"\ncache_max_entries = 4096\n",
        )
        .unwrap();
        assert_eq!(cfg.pipeline_min_workers, 2);
        assert_eq!(cfg.pipeline_join, "10:1");
        assert_eq!(cfg.cache_max_entries, 4096);
        // defaults: floor 1, no join, unbounded cache
        let d = TrainConfig::default();
        assert_eq!(d.pipeline_min_workers, 1);
        assert!(d.pipeline_join.is_empty());
        assert_eq!(d.cache_max_entries, 0);
        // floor 0 and malformed join directives are rejected
        let mut cfg = TrainConfig::default();
        cfg.pipeline_min_workers = 0;
        assert!(cfg.validate().is_err());
        assert!(TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_join = \"soon\"\n"
        )
        .is_err());
        // a join directive without pipeline mode is rejected
        assert!(TrainConfig::from_toml_str("pipeline_join = \"10\"").is_err());
    }

    #[test]
    fn score_precision_parses_and_rejects_junk() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\nscore_precision = \"bf16\"\n",
        )
        .unwrap();
        assert_eq!(cfg.score_precision, "bf16");
        assert_eq!(TrainConfig::default().score_precision, "f32");
        let err = TrainConfig::from_toml_str("score_precision = \"f16\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("f32 | bf16"), "err: {err:#}");
    }

    #[test]
    fn param_precision_parses_and_rejects_junk() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\nparam_precision = \"bf16\"\n",
        )
        .unwrap();
        assert_eq!(cfg.param_precision, "bf16");
        assert_eq!(TrainConfig::default().param_precision, "f32");
        let err = TrainConfig::from_toml_str("param_precision = \"f16\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("f32 | bf16"), "err: {err:#}");
    }

    #[test]
    fn pipeline_overlap_parses_and_demands_pipeline_mode() {
        let cfg = TrainConfig::from_toml_str(
            "epochs = 0\nstream_steps = 50\npipeline = true\npipeline_overlap = true\n",
        )
        .unwrap();
        assert!(cfg.pipeline_overlap);
        assert!(!TrainConfig::default().pipeline_overlap, "defaults off");
        assert!(TrainConfig::from_toml_str("pipeline_overlap = true").is_err());
    }

    #[test]
    fn wrong_type_rejected() {
        assert!(TrainConfig::from_toml_str("epochs = \"five\"").is_err());
        assert!(TrainConfig::from_toml_str("model = 3").is_err());
    }
}
