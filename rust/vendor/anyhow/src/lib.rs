//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The hermetic build ships no crates.io dependencies (CI and the tier-1
//! verify must pass on an offline, fresh checkout), so this in-tree
//! package provides the slice of `anyhow` the workspace actually uses:
//!
//! * [`Error`] — a context-chained, boxed error value;
//! * [`Result`] — `Result<T, Error>` alias with a default type param;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Formatting matches upstream where it matters to callers: `{}` prints
//! the outermost message only, `{:#}` prints the whole context chain
//! separated by `": "`, and `{:?}` prints the message plus a
//! `Caused by:` list.

use std::fmt;

/// A context-chained error value.
///
/// Deliberately does **not** implement `std::error::Error`: that keeps
/// the blanket `From<E: std::error::Error>` conversion below coherent,
/// exactly as in upstream `anyhow`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>`: `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

/// Iterator over an [`Error`]'s context chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(&cur.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        fn sources(e: &dyn std::error::Error) -> Option<Box<Error>> {
            e.source().map(|s| Box::new(Error { msg: s.to_string(), source: sources(s) }))
        }
        Error { msg: e.to_string(), source: sources(&e) }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(e.to_string(), "reading config");
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");

        let ok: Option<u8> = Some(3);
        assert_eq!(ok.context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);

        fn failing() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(4).unwrap(), 4);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("root").context("mid").context("top");
        let parts: Vec<&str> = e.chain().collect();
        assert_eq!(parts, vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
